"""L2 facade: re-exports the model zoo + entry machinery.

Kept for the architecture's canonical layout (python/compile/model.py is the
documented L2 entrypoint); the real definitions live in compile.models.*.
"""

from .models.common import (ModelDef, example_args, make_entries,  # noqa: F401
                            make_init)
from .models.registry import GROUPS, REGISTRY, groups_for  # noqa: F401
