"""AOT driver: lower every L2 entry to HLO *text* + write the manifest.

This is the only place Python touches the build: `make artifacts` runs this
module once; the Rust coordinator then loads `artifacts/*.hlo.txt` through
the PJRT CPU client and never imports Python again.

Interchange is HLO text, NOT `.serialize()` / StableHLO bytes: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--only fig4 vit_d8 ...]
                          [--force] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.svgd import svgd_update
from .models import registry
from .models.common import ModelDef, example_args, make_entries

_DTYPE_NAMES = {
    jnp.float32.dtype: "f32",
    jnp.int32.dtype: "i32",
    jnp.uint32.dtype: "u32",
}


def dtype_name(dt) -> str:
    try:
        return _DTYPE_NAMES[jnp.dtype(dt)]
    except KeyError:
        raise ValueError(f"dtype {dt} not part of the L2/L3 contract") from None


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def sig_of(fn, args) -> Tuple[List[dict], List[dict]]:
    """(arg specs, output specs) for the manifest."""
    outs = jax.eval_shape(fn, *args)
    spec = lambda s: {"shape": list(s.shape), "dtype": dtype_name(s.dtype)}  # noqa: E731
    return [spec(a) for a in args], [spec(o) for o in outs]


def lower_entry(fn, args, path: str, force: bool) -> bool:
    """Lower fn(*args) to HLO text at `path`. Returns True if (re)built."""
    if os.path.exists(path) and not force:
        return False
    text = to_hlo_text(jax.jit(fn).lower(*args))
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return True


def build_model(name: str, model: ModelDef, out_dir: str,
                force: bool) -> dict:
    entries = make_entries(model)
    ex = example_args(model)
    entry_manifest = {}
    for ename, fn in entries.items():
        args, outs = sig_of(fn, ex[ename])
        fname = f"{name}.{ename}.hlo.txt"
        t0 = time.time()
        built = lower_entry(fn, ex[ename], os.path.join(out_dir, fname), force)
        status = f"lowered in {time.time() - t0:.1f}s" if built else "cached"
        print(f"  {name}.{ename}: {status}", flush=True)
        entry_manifest[ename] = {"file": fname, "args": args, "outs": outs}
    return {
        "param_count": model.param_count,
        "task": model.task,
        "x_shape": list(model.x_shape),
        "y_shape": list(model.y_shape),
        "y_dtype": model.y_dtype,
        "meta": {k: v for k, v in model.meta.items()},
        "entries": entry_manifest,
    }


def build_svgd(n: int, d: int, out_dir: str, force: bool) -> dict:
    """svgd_update artifact for n particles with d flat params each."""
    def entry(p, g, h):
        return (svgd_update(p, g, h),)

    f32 = jnp.float32
    args = (jax.ShapeDtypeStruct((n, d), f32),
            jax.ShapeDtypeStruct((n, d), f32),
            jax.ShapeDtypeStruct((), f32))
    fname = f"svgd_n{n}_d{d}.hlo.txt"
    t0 = time.time()
    built = lower_entry(entry, args, os.path.join(out_dir, fname), force)
    status = f"lowered in {time.time() - t0:.1f}s" if built else "cached"
    print(f"  svgd n={n} d={d}: {status}", flush=True)
    aspec, ospec = sig_of(entry, args)
    return {"n": n, "d": d, "file": fname, "args": aspec, "outs": ospec}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="model or group names (default: everything)")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    ap.add_argument("--no-svgd", action="store_true")
    ap.add_argument("--list", action="store_true")
    opts = ap.parse_args()

    names = registry.groups_for(opts.only) if opts.only \
        else list(registry.REGISTRY)
    if opts.list:
        for g, ms in registry.GROUPS.items():
            print(f"{g}: {' '.join(ms)}")
        return

    os.makedirs(opts.out_dir, exist_ok=True)
    manifest_path = os.path.join(opts.out_dir, "manifest.json")
    manifest = {"models": {}, "svgd": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    t0 = time.time()
    for name in names:
        print(f"[aot] model {name}", flush=True)
        model = registry.REGISTRY[name]()
        manifest["models"][name] = build_model(name, model, opts.out_dir,
                                               opts.force)

    if not opts.no_svgd:
        seen = {(s["n"], s["d"]) for s in manifest["svgd"]}
        dims = {}
        for mname in registry.SVGD_MODELS:
            if mname in manifest["models"]:
                dims[mname] = manifest["models"][mname]["param_count"]
        for mname, d in dims.items():
            print(f"[aot] svgd for {mname} (d={d})", flush=True)
            for n in registry.SVGD_NS:
                entry = build_svgd(n, d, opts.out_dir, opts.force)
                if (n, d) not in seen:
                    manifest["svgd"].append(entry)
                    seen.add((n, d))

    tmp = manifest_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, manifest_path)
    n_art = len(os.listdir(opts.out_dir)) - 1
    print(f"[aot] done: {n_art} artifacts, manifest at {manifest_path} "
          f"({time.time() - t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
