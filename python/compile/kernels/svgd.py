"""SVGD RBF kernel-matrix update as Pallas kernels (L1).

This is the paper's stated bottleneck: "at higher particle counts, the SVGD
algorithm is fundamentally bottlenecked by the computation of the kernel
matrix" (§5.1). The update for particle i given particles P[n,d] and loss
gradients G[n,d] is

    k_ij = exp(-0.5 ||p_i - p_j||^2 / h^2)
    U_i  = (1/n) sum_j [ k_ij g_j + k_ij (p_j - p_i) / h^2 ]

(descent form of canonical SVGD; the paper's Appendix-B listing has the
repulsion sign flipped — see ref.svgd_update_ref and DESIGN.md §SVGD-sign).
We restructure the paper's O(n^2 d) elementwise loop (their Fig. 6 leader
code) into matmul form so it maps onto the MXU systolic array:

    D    = pairwise squared distances              (Gram-style, pass 1)
    K    = exp(-0.5 D / h^2)                        (tiny [n,n], host jnp)
    U    = (K @ G + (K @ P - rowsum(K) * P)/h^2)/n  (pass 2)

Pass 1 tiles the d axis: ||p_i - p_j||^2 decomposes blockwise as
sum_blk ||p_i_blk - p_j_blk||^2, so the [n,n] output block stays resident in
VMEM as the accumulator across the d grid axis. Pass 2 streams d-blocks of P
and G through VMEM while K ([n,n], n <= 64 here, i.e. <= 16 KiB) stays
resident. Both passes are bandwidth-bound in d with MXU-shaped inner matmuls.

Lowered with interpret=True for CPU-PJRT execution (DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import pick_block

# d-axis block: 512 floats/row keeps the pass-2 working set
# (3 * n * bd + n * n floats; n=32, bd=512 -> ~200 KiB) well inside VMEM with
# double-buffering headroom, while keeping the streamed matmul K-dim a
# multiple of the 128-lane register width.
DEFAULT_BD = 512


def _sq_dists_kernel(p_ref, d_ref, *, nsteps):
    """Accumulate blockwise pairwise squared distances into d_ref[n,n]."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)

    blk = p_ref[...]                                    # [n, bd]
    sq = jnp.sum(blk * blk, axis=1)                     # [n]
    gram = jnp.dot(blk, blk.T, preferred_element_type=jnp.float32)
    d_ref[...] += sq[:, None] + sq[None, :] - 2.0 * gram
    del nsteps  # grid length only needed by the caller


def pairwise_sq_dists(p: jnp.ndarray, bd: int = DEFAULT_BD,
                      interpret: bool = True) -> jnp.ndarray:
    """D[i,j] = ||p_i - p_j||^2 for p[n,d], tiled over d."""
    n, d = p.shape
    bd = pick_block(d, bd)
    nsteps = d // bd
    return pl.pallas_call(
        functools.partial(_sq_dists_kernel, nsteps=nsteps),
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((n, bd), lambda k: (0, k))],
        out_specs=pl.BlockSpec((n, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(p)


def _apply_kernel(k_ref, rs_ref, p_ref, g_ref, h2_ref, o_ref):
    """One d-block of U = (K @ G + (rowsum(K) * P - K @ P)/h^2)/n."""
    kmat = k_ref[...]                                   # [n, n] resident
    p_blk = p_ref[...]                                  # [n, bd]
    g_blk = g_ref[...]                                  # [n, bd]
    h2 = h2_ref[0]
    n = kmat.shape[0]
    kg = jnp.dot(kmat, g_blk, preferred_element_type=jnp.float32)
    kp = jnp.dot(kmat, p_blk, preferred_element_type=jnp.float32)
    o_ref[...] = (kg + (kp - rs_ref[...][:, None] * p_blk) / h2) / n


def svgd_update(p: jnp.ndarray, g: jnp.ndarray, lengthscale: jnp.ndarray,
                bd: int = DEFAULT_BD, interpret: bool = True) -> jnp.ndarray:
    """Full SVGD update U[n,d] (see module docstring). lengthscale: f32[]."""
    n, d = p.shape
    assert g.shape == (n, d)
    h2 = (lengthscale * lengthscale).reshape((1,)).astype(jnp.float32)
    d2 = pairwise_sq_dists(p, bd=bd, interpret=interpret)
    # The Gram-form distance loses ~|p|^2 * eps of absolute precision in f32:
    # clamp negatives and pin the diagonal to exactly 0 so k_ii == 1 (the
    # paper's elementwise loop gets this for free from diff = p_i - p_i).
    d2 = jnp.maximum(d2, 0.0) * (1.0 - jnp.eye(p.shape[0], dtype=p.dtype))
    kmat = jnp.exp(-0.5 * d2 / h2[0])                   # [n,n]: tiny, host op
    rowsum = jnp.sum(kmat, axis=1)                      # [n]

    bd = pick_block(d, bd)
    return pl.pallas_call(
        _apply_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((n, n), lambda k: (0, 0)),     # K resident
            pl.BlockSpec((n,), lambda k: (0,)),         # rowsum resident
            pl.BlockSpec((n, bd), lambda k: (0, k)),    # P streamed
            pl.BlockSpec((n, bd), lambda k: (0, k)),    # G streamed
            pl.BlockSpec((1,), lambda k: (0,)),         # h^2 scalar
        ],
        out_specs=pl.BlockSpec((n, bd), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(kmat, rowsum, p, g, h2)
