"""Analytical TPU cost model for the L1 kernels (EXPERIMENTS.md §Perf).

interpret=True executes kernels as numpy on CPU, so wall-clock there says
nothing about TPU behaviour. What IS determined by the kernel source — and
what this module computes from the same block parameters the kernels use —
is the structural performance story:

* VMEM working set per grid cell (must fit ~16 MiB with double-buffering),
* MXU tile occupancy of the inner matmuls (128x128 systolic array),
* HBM traffic vs the algorithmic lower bound (bandwidth-bound kernels).

`push bench`'s §Perf numbers and DESIGN.md cite these estimates; the pytest
suite pins them so a kernel/block-shape change that regresses the structure
fails CI.
"""

from __future__ import annotations

import dataclasses
import math

from .fused_linear import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, pick_block
from .svgd import DEFAULT_BD

VMEM_BYTES = 16 * 1024 * 1024       # per-TensorCore VMEM
MXU = 128                           # systolic array dimension
F32 = 4


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    name: str
    grid_cells: int
    vmem_bytes_per_cell: int
    mxu_m_occupancy: float          # fraction of the 128 MXU rows used
    mxu_n_occupancy: float
    hbm_traffic_bytes: int          # total bytes moved for one call
    hbm_optimal_bytes: int          # algorithmic lower bound

    @property
    def fits_vmem(self) -> bool:
        # x2 for double-buffering the streamed inputs
        return 2 * self.vmem_bytes_per_cell <= VMEM_BYTES

    @property
    def mxu_tile_occupancy(self) -> float:
        return self.mxu_m_occupancy * self.mxu_n_occupancy

    @property
    def traffic_efficiency(self) -> float:
        """optimal / actual HBM bytes (1.0 = reads/writes each element once)."""
        return self.hbm_optimal_bytes / max(1, self.hbm_traffic_bytes)


def fused_linear_estimate(m: int, k: int, n: int,
                          bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                          bk: int = DEFAULT_BK) -> KernelEstimate:
    """y[M,N] = act(x[M,K] @ w[K,N] + b) with the kernel's blocking."""
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)
    cells = (m // bm) * (n // bn) * (k // bk)
    vmem = F32 * (bm * bk + bk * bn + bm * bn + bn)
    # every (i, j) output block streams the full K axis of x and w once:
    traffic = F32 * ((m // bm) * (n // bn) * (bm * k + k * bn) + m * n)
    optimal = F32 * (m * k + k * n + n + m * n)
    return KernelEstimate(
        name=f"fused_linear[{m}x{k}x{n}/bm{bm},bn{bn},bk{bk}]",
        grid_cells=cells,
        vmem_bytes_per_cell=vmem,
        mxu_m_occupancy=min(1.0, bm / MXU),
        mxu_n_occupancy=min(1.0, bn / MXU),
        hbm_traffic_bytes=traffic,
        hbm_optimal_bytes=optimal,
    )


def svgd_estimate(n: int, d: int, bd: int = DEFAULT_BD) -> KernelEstimate:
    """Two-pass svgd_update over P[n,d], G[n,d] -> U[n,d]."""
    bd = pick_block(d, bd)
    cells = 2 * (d // bd)           # pass 1 + pass 2 share the d grid
    # pass 2 working set dominates: K resident + P/G/U blocks + rowsum
    vmem = F32 * (n * n + 3 * n * bd + n + 1)
    # pass 1 reads P once; pass 2 reads P and G once and writes U:
    traffic = F32 * (2 * n * d + n * d + n * d + 2 * n * n)
    optimal = F32 * (3 * n * d)     # read P, G; write U
    return KernelEstimate(
        name=f"svgd_update[n{n},d{d}/bd{bd}]",
        grid_cells=cells,
        vmem_bytes_per_cell=vmem,
        mxu_m_occupancy=min(1.0, n / MXU),
        mxu_n_occupancy=min(1.0, n / MXU),
        hbm_traffic_bytes=traffic,
        hbm_optimal_bytes=optimal,
    )


def attention_estimate(bh: int, t: int, d: int, bq: int = 128) -> KernelEstimate:
    """Fused softmax(QK^T)V per (bh, q-block) cell; K/V resident."""
    bq = pick_block(t, bq)
    cells = bh * (t // bq)
    vmem = F32 * (bq * d + 2 * t * d + bq * t + bq * d)
    # every q block revisits K and V in full:
    traffic = F32 * (bh * (t * d + (t // bq) * 2 * t * d + t * d))
    optimal = F32 * (bh * 4 * t * d)     # read Q, K, V; write O
    return KernelEstimate(
        name=f"attention[bh{bh},t{t},d{d}/bq{bq}]",
        grid_cells=cells,
        vmem_bytes_per_cell=vmem,
        mxu_m_occupancy=min(1.0, bq / MXU),
        mxu_n_occupancy=min(1.0, max(t, d) / MXU),
        hbm_traffic_bytes=traffic,
        hbm_optimal_bytes=optimal,
    )


def report(estimates) -> str:
    """Human table, printed by `python -m compile.kernels.analysis`."""
    lines = [
        f"{'kernel':<46} {'cells':>6} {'VMEM/cell':>10} {'fits':>5} "
        f"{'MXU occ':>8} {'HBM eff':>8}"
    ]
    for e in estimates:
        lines.append(
            f"{e.name:<46} {e.grid_cells:>6} "
            f"{e.vmem_bytes_per_cell / 1024:>8.1f}KB "
            f"{'yes' if e.fits_vmem else 'NO':>5} "
            f"{100 * e.mxu_tile_occupancy:>7.1f}% "
            f"{100 * e.traffic_efficiency:>7.1f}%"
        )
    return "\n".join(lines)


def _default_suite():
    """The shapes the shipped models actually lower (registry-aligned)."""
    return [
        # vit_fig4 FFN: (batch*tokens, hidden, mlp) = (640, 64, 128)
        fused_linear_estimate(640, 64, 128),
        # vit_e2e FFN: (320, 128, 256)
        fused_linear_estimate(320, 128, 256),
        # paper-scale FFN for reference: (65536, 768, 3072)
        fused_linear_estimate(65536, 768, 3072),
        # svgd over mlp_small and vit_fig4 parameter vectors
        svgd_estimate(8, 5313),
        svgd_estimate(32, 206346),
        # vit attention: bh = batch*heads, t = tokens+1
        attention_estimate(512, 5, 16),
        attention_estimate(512, 256, 64, bq=128),  # long-seq reference
    ]


if __name__ == "__main__":
    print(report(_default_suite()))
