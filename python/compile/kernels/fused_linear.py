"""Fused linear + bias + GELU Pallas kernel (L1).

The transformer/MLP feed-forward path is the per-particle compute hotspot for
the ensemble/multi-SWAG workloads (paper §5: Push benefits most when compute
per particle is high). On TPU this kernel tiles the matmul for the MXU
(128x128 systolic array) and revisits a resident f32 output block in VMEM
across the K-dimension grid axis, applying bias + GELU on the final K step so
the activation never round-trips to HBM.

Lowered with interpret=True so the CPU PJRT client can execute it (real-TPU
Mosaic lowering is compile-only on this testbed — see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes, chosen for the MXU: the (bm, bn) output tile matches
# the 128x128 systolic array when the problem is large enough; bk=128 keeps
# the VMEM working set (bm*bk + bk*bn + bm*bn floats ~= 192 KiB at f32)
# comfortably inside a TensorCore's ~16 MiB VMEM with room to double-buffer
# the x/w input streams.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps_k, activation):
    """One (i, j, k) grid step: o += x_blk @ w_blk; epilogue on last k.

    The output BlockSpec maps every k to the same (i, j) block, so o_ref is
    revisited (stays resident in VMEM) across the K axis and doubles as the
    accumulator — no separate scratch needed, which also keeps the kernel
    portable across interpret/Mosaic lowerings.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == nsteps_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...][None, :]
        if activation == "gelu":
            y = _gelu(y)
        o_ref[...] = y


def pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (so small shapes still tile)."""
    b = max(1, min(dim, want))
    while dim % b != 0:
        b -= 1
    return b


def fused_linear_raw(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                     activation: str = "gelu",
                     bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                     interpret: bool = True) -> jnp.ndarray:
    """y = activation(x @ w + b) with x[M,K], w[K,N], b[N]."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(kdim, bk)
    nsteps_k = kdim // bk

    kernel = functools.partial(
        _fused_linear_kernel, nsteps_k=nsteps_k, activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b)


def _gelu_grad(z):
    """d/dz gelu(z) for the tanh approximation used in the kernel."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
    inner = c * (z + 0.044715 * z**3)
    t = jnp.tanh(inner)
    dinner = c * (1.0 + 3.0 * 0.044715 * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * dinner


# Pallas kernels have no automatic transpose rule (the grid/program_id
# machinery is not differentiable), so the backward pass is hand-written —
# and itself routed through the Pallas matmul so the L1 kernel stays on the
# bwd hot path too. The pre-activation z is REMATERIALIZED in bwd (one extra
# fused matmul) instead of saved, trading FLOPs for activation memory — the
# same remat-over-store choice the L2 design doc calls out.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 activation: str = "gelu") -> jnp.ndarray:
    """Differentiable y = activation(x @ w + b); Pallas fwd AND bwd."""
    return fused_linear_raw(x, w, b, activation=activation)


def _fused_linear_fwd(x, w, b, activation):
    return fused_linear_raw(x, w, b, activation=activation), (x, w, b)


def _fused_linear_bwd(activation, res, dy):
    x, w, b = res
    if activation == "gelu":
        z = fused_linear_raw(x, w, b, activation="none")
        dz = dy * _gelu_grad(z)
    else:
        dz = dy
    zn = jnp.zeros((w.shape[0],), x.dtype)   # dx cols = K
    zm = jnp.zeros((w.shape[1],), x.dtype)   # dw cols = N
    # dx = dz @ w.T ; dw = x.T @ dz — both through the Pallas matmul path.
    dx = fused_linear_raw(dz, w.T, zn, activation="none")
    dw = fused_linear_raw(x.T, dz, zm, activation="none")
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
