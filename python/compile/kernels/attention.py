"""Fused single-head attention Pallas kernel (L1).

softmax(Q K^T / sqrt(d)) V computed per (batch*head, q-block) grid cell with
K/V resident in VMEM — the logits tile never round-trips to HBM, which is
the attention analogue of fused_linear's epilogue fusion. The paper's ViT
workloads run at tiny token counts (28x28 / patch 14 -> 5 tokens), so K/V
fit VMEM whole; the BlockSpec still tiles the query axis so the same kernel
shape scales to longer sequences on a real TPU (DESIGN.md
§Hardware-Adaptation).

Differentiation: Pallas kernels have no transpose rule, so `attention` is a
custom_vjp whose backward pass is the VJP of the pure-jnp reference — the
forward hot path stays fused while the backward reuses XLA's fusion of the
standard attention graph.

Lowered with interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_linear import pick_block

DEFAULT_BQ = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    """One (bh, q-block) cell: o = softmax(q k^T / sqrt(d)) v.

    Block shapes carry a leading singleton bh axis ((1, bq, d) etc.);
    index it away so the matmuls are plain 2-D MXU shapes."""
    q = q_ref[0]                                     # [bq, d]
    k = k_ref[0]                                     # [t, d]
    v = v_ref[0]                                     # [t, d]
    d = q.shape[-1]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(d))
    m = jnp.max(logits, axis=-1, keepdims=True)      # numerical stability
    p = jnp.exp(logits - m)
    z = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = (jnp.dot(p, v, preferred_element_type=jnp.float32) / z).astype(
        o_ref.dtype)


def attention_raw(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  bq: int = DEFAULT_BQ, interpret: bool = True) -> jnp.ndarray:
    """q[bh, t, d], k[bh, t, d], v[bh, t, d] -> [bh, t, d]."""
    bh, t, d = q.shape
    assert k.shape == (bh, t, d) and v.shape == (bh, t, d)
    bq = pick_block(t, bq)
    return pl.pallas_call(
        _attn_kernel,
        grid=(bh, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),   # K resident
            pl.BlockSpec((1, t, d), lambda b, i: (b, 0, 0)),   # V resident
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _attention_ref(q, k, v):
    d = q.shape[-1]
    logits = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v)


@jax.custom_vjp
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Differentiable fused attention; Pallas fwd, reference-graph bwd."""
    return attention_raw(q, k, v)


def _attention_fwd(q, k, v):
    return attention_raw(q, k, v), (q, k, v)


def _attention_bwd(res, do):
    q, k, v = res
    _, vjp = jax.vjp(_attention_ref, q, k, v)
    return vjp(do)


attention.defvjp(_attention_fwd, _attention_bwd)


def _kernel_blockspec_note() -> str:
    """VMEM accounting used by EXPERIMENTS.md §Perf (L1): per grid cell the
    working set is bq*d (Q tile) + 2*t*d (K/V resident) + bq*t (logits) +
    bq*d (output) floats."""
    return "see EXPERIMENTS.md §Perf"
