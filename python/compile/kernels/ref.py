"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here using
only jax.numpy ops. pytest (python/tests/test_kernels.py) sweeps shapes/dtypes
with deterministic seeds and asserts allclose between the Pallas kernel under
interpret=True and these oracles.
"""

from __future__ import annotations

import jax.numpy as jnp


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the kernel's in-VMEM activation)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fused_linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                     activation: str = "gelu") -> jnp.ndarray:
    """Reference for kernels.fused_linear: y = act(x @ w + b)."""
    y = x @ w + b[None, :]
    if activation == "gelu":
        return gelu_ref(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def pairwise_sq_dists_ref(p: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.svgd.pairwise_sq_dists: D[i,j] = ||p_i - p_j||^2."""
    diff = p[:, None, :] - p[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def svgd_update_ref(p: jnp.ndarray, g: jnp.ndarray,
                    lengthscale: jnp.ndarray) -> jnp.ndarray:
    """Reference SVGD update in descent form (Liu & Wang 2016).

    For particles p[n,d] with LOSS gradients g = -grad log posterior and RBF
    lengthscale h:

        k_ij = exp(-0.5 * ||p_i - p_j||^2 / h^2)
        U_i  = (1/n) * sum_j [ k_ij * g_j + k_ij * (p_j - p_i) / h^2 ]

    The caller applies p_i <- p_i - lr * U_i, which is exactly
    x_i <- x_i + eps * phi*(x_i) of the SVGD paper: the first term is the
    kernel-smoothed score descent, the second the repulsive grad-k term.

    NOTE (DESIGN.md §SVGD-sign): the Push paper's Appendix-B listing applies
    `diff * (-k/h)` with `p.add_(update, alpha=-lr)`, which flips the
    repulsion into attraction. We reproduce the *algorithm* the paper cites
    (canonical SVGD), not the listing's sign.
    """
    n = p.shape[0]
    h2 = lengthscale * lengthscale
    d2 = pairwise_sq_dists_ref(p)
    k = jnp.exp(-0.5 * d2 / h2)                     # [n, n]
    # sum_j k_ij g_j  = K @ G
    term1 = k @ g
    # sum_j k_ij (p_j - p_i)/h^2 = (K @ P - rowsum(K) * p_i) / h^2
    rowsum = jnp.sum(k, axis=1, keepdims=True)      # [n, 1]
    term2 = (k @ p - rowsum * p) / h2
    return (term1 + term2) / n


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.attention: softmax(q k^T / sqrt(d)) v over
    [bh, t, d] tensors."""
    import jax
    d = q.shape[-1]
    logits = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v)
