"""UNet-1D (L2) — the paper's PDE surrogate workload (Figure 4, Advection).

The paper trains PDEBench's UNet on the 1-D Advection dataset (batch 50). We
implement the same operator-learning setup: input field u(x, t) -> evolved
field u(x, t + dt) on a periodic 1-D grid. Encoder/decoder with strided
downsampling, nearest-neighbour upsampling, and skip connections.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelDef, regress_loss, unflatten


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """x[B, C, N] (periodic) conv with w[out, in, k]."""
    k = w.shape[-1]
    pad = k // 2
    x = jnp.concatenate([x[..., -pad:], x, x[..., :pad]], axis=-1)
    return lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))


def _up(x: jnp.ndarray) -> jnp.ndarray:
    """Nearest-neighbour 2x upsample along the grid axis."""
    return jnp.repeat(x, 2, axis=-1)


def param_shapes(c: int, levels: int, k: int = 5) -> List[Tuple[int, ...]]:
    shapes: List[Tuple[int, ...]] = [(c, 1, k), (c,)]            # lift
    ch = c
    for _ in range(levels):                                      # encoder
        shapes += [(2 * ch, ch, k), (2 * ch,)]
        ch *= 2
    shapes += [(ch, ch, k), (ch,)]                               # bottleneck
    for _ in range(levels):                                      # decoder
        # input: upsampled (ch) + skip (ch//2) channels
        shapes += [(ch // 2, ch + ch // 2, k), (ch // 2,)]
        ch //= 2
    shapes += [(1, c, k), (1,)]                                  # project out
    return shapes


def build(name: str, *, nx: int = 64, c: int = 8, levels: int = 2,
          k: int = 5, batch: int = 50) -> ModelDef:
    assert nx % (1 << levels) == 0, (nx, levels)
    shapes = param_shapes(c, levels, k)

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        params = unflatten(flat, shapes)
        it = iter(params)
        nxt = lambda: next(it)  # noqa: E731

        b = x.shape[0]
        h = x.reshape(b, 1, nx)
        w, bias = nxt(), nxt()
        h = jax.nn.gelu(_conv1d(h, w) + bias[None, :, None], approximate=True)

        skips = []
        for _ in range(levels):
            skips.append(h)
            w, bias = nxt(), nxt()
            h = jax.nn.gelu(_conv1d(h, w, stride=2) + bias[None, :, None],
                            approximate=True)

        w, bias = nxt(), nxt()
        h = jax.nn.gelu(_conv1d(h, w) + bias[None, :, None], approximate=True)

        for _ in range(levels):
            h = _up(h)
            h = jnp.concatenate([h, skips.pop()], axis=1)
            w, bias = nxt(), nxt()
            h = jax.nn.gelu(_conv1d(h, w) + bias[None, :, None],
                            approximate=True)

        w, bias = nxt(), nxt()
        out = _conv1d(h, w) + bias[None, :, None]
        return out.reshape(b, nx)

    return ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=regress_loss(apply),
        x_shape=(batch, nx),
        y_shape=(batch, nx),
        y_dtype="f32",
        task="regress",
        meta={"arch": "unet1d", "nx": nx, "channels": c, "levels": levels},
    )
