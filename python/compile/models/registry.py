"""Registry of every model variant the Rust side needs (L2 -> artifact map).

Grouped by experiment (DESIGN.md §5). The paper's models run up to 454M
parameters on 24GB GPUs; this CPU testbed scales every architecture down
uniformly while preserving the sweep *structure* (halve depth or width <->
double particles at constant effective parameter count) — see DESIGN.md
§Hardware-Adaptation.

Each entry is a zero-argument builder so that importing the registry stays
cheap; aot.py instantiates lazily.
"""

from __future__ import annotations

from typing import Callable, Dict, List

from . import cgcnn, mlp, resnet, schnet, unet1d, vit
from .common import ModelDef

Builder = Callable[[], ModelDef]

REGISTRY: Dict[str, Builder] = {}
GROUPS: Dict[str, List[str]] = {}


def _reg(group: str, name: str, builder: Builder) -> None:
    assert name not in REGISTRY, f"duplicate model {name}"
    REGISTRY[name] = builder
    GROUPS.setdefault(group, []).append(name)


# --- core / tests / quickstart ------------------------------------------------
_reg("core", "mlp_tiny",
     lambda: mlp.build("mlp_tiny", in_dim=8, hidden=32, depth=2, out_dim=1,
                       batch=16))
_reg("core", "mlp_small",
     lambda: mlp.build("mlp_small", in_dim=16, hidden=64, depth=2, out_dim=1,
                       batch=32))

# --- end-to-end driver: the largest ViT the CPU testbed trains in minutes ---
# (paper-scale 100M+ params is a GPU budget; DESIGN.md §Hardware-Adaptation)
_reg("e2e", "vit_e2e",
     lambda: vit.build("vit_e2e", hidden=128, depth=6, heads=8, mlp_dim=256,
                       batch=64))

# --- Figure 4: ViT/MNIST, CGCNN/MD17, UNet/Advection -------------------------
_reg("fig4", "vit_fig4",
     lambda: vit.build("vit_fig4", hidden=64, depth=4, heads=4, mlp_dim=128,
                       batch=128))
_reg("fig4", "cgcnn_fig4",
     lambda: cgcnn.build("cgcnn_fig4", atoms=8, species=4, hidden=32,
                         gauss=16, layers=2, batch=20))
_reg("fig4", "unet_fig4",
     lambda: unet1d.build("unet_fig4", nx=64, c=8, levels=2, batch=50))

# --- Figure 7: ResNet, SchNet -------------------------------------------------
_reg("fig7", "resnet_fig7",
     lambda: resnet.build("resnet_fig7", c=8, blocks=2, batch=128))
_reg("fig7", "schnet_fig7",
     lambda: schnet.build("schnet_fig7", atoms=8, species=4, hidden=16,
                          gauss=16, layers=2, batch=20))

# --- Table 1 / Table 3: ViT depth sweep (constant effective param count) ----
# Paper sweeps depth {64..1}; scaled to {8,4,2,1} with hidden 32, mlp 64.
for _d in (8, 4, 2, 1):
    _reg("depth", f"vit_d{_d}",
         lambda d=_d: vit.build(f"vit_d{d}", hidden=32, depth=d, heads=4,
                                mlp_dim=64, batch=64))

# --- Table 2 / Table 4: ViT width sweep (depth fixed, shrink hidden/mlp) -----
# Paper keeps 12 layers and shrinks the MLP + hidden dims; we keep 3 layers.
for _h, _m in ((64, 128), (48, 96), (32, 64), (24, 48), (16, 32), (8, 16)):
    _reg("width", f"vit_w{_h}",
         lambda h=_h, m=_m: vit.build(f"vit_w{h}", hidden=h, depth=3, heads=4,
                                      mlp_dim=m, batch=64))

# --- SVGD kernel artifact specs ----------------------------------------------
# The L1 svgd_update kernel is shape-specialized per (n particles, d params).
# One artifact set per architecture that the SVGD benches/examples drive.
SVGD_NS = (2, 4, 8, 16, 32)
SVGD_MODELS = ("mlp_small", "vit_fig4", "cgcnn_fig4", "unet_fig4",
               "resnet_fig7", "schnet_fig7")


def groups_for(names: List[str]) -> List[str]:
    """Expand group names / model names into a model-name list."""
    out: List[str] = []
    for n in names:
        if n in GROUPS:
            out.extend(GROUPS[n])
        elif n in REGISTRY:
            out.append(n)
        else:
            raise KeyError(f"unknown model or group: {n!r}; "
                           f"groups={sorted(GROUPS)} "
                           f"models={sorted(REGISTRY)}")
    return out
