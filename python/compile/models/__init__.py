"""L2 model zoo: every architecture is a pure function over a flat f32[P]
parameter vector. See common.py for the entry contract and registry.py for
the artifact variants."""
