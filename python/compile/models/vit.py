"""Vision transformer (L2) — the paper's primary scaling workload.

Matches the paper's setup (§C.1): b16-style ViT on 28x28 images, patch size
14, 10 classes, with sweepable depth (Table 1/3), width (Table 2/4) and head
count. The MLP block's first matmul runs through the L1 fused_linear Pallas
kernel (matmul + bias + GELU resident in VMEM), so every fwd/bwd artifact
contains the kernel's lowering.

All parameters live in one flat f32[P] vector (compile.flatten); the shape
list below is the canonical order.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from ..kernels.attention import attention as pallas_attention
from ..kernels.fused_linear import fused_linear
from .common import ModelDef, classify_loss, layer_norm, unflatten


def param_shapes(hidden: int, depth: int, mlp_dim: int, n_tokens: int,
                 patch_dim: int, n_classes: int) -> List[Tuple[int, ...]]:
    """Canonical parameter order for the ViT flat vector."""
    shapes: List[Tuple[int, ...]] = [
        (patch_dim, hidden),        # patch embedding
        (hidden,),                  # patch bias
        (hidden,),                  # cls token
        (n_tokens + 1, hidden),     # positional embedding
    ]
    for _ in range(depth):
        shapes += [
            (hidden,), (hidden,),           # ln1 scale, bias
            (hidden, 3 * hidden),           # qkv
            (3 * hidden,),
            (hidden, hidden),               # attn out proj
            (hidden,),
            (hidden,), (hidden,),           # ln2 scale, bias
            (hidden, mlp_dim),              # mlp in  (fused_linear kernel)
            (mlp_dim,),
            (mlp_dim, hidden),              # mlp out
            (hidden,),
        ]
    shapes += [
        (hidden,), (hidden,),               # final ln
        (hidden, n_classes),                # head
        (n_classes,),
    ]
    return shapes


def build(name: str, *, image: int = 28, patch: int = 14, hidden: int = 64,
          depth: int = 4, heads: int = 4, mlp_dim: int = 128,
          n_classes: int = 10, batch: int = 128,
          use_pallas: bool = True) -> ModelDef:
    assert image % patch == 0, (image, patch)
    grid = image // patch
    n_tokens = grid * grid
    patch_dim = patch * patch
    assert hidden % heads == 0, (hidden, heads)
    head_dim = hidden // heads
    shapes = param_shapes(hidden, depth, mlp_dim, n_tokens, patch_dim, n_classes)

    def patches(x: jnp.ndarray) -> jnp.ndarray:
        """x[B, image*image] -> tokens [B, n_tokens, patch_dim]."""
        b = x.shape[0]
        x = x.reshape(b, grid, patch, grid, patch)
        x = x.transpose(0, 1, 3, 2, 4)
        return x.reshape(b, n_tokens, patch_dim)

    def attention(h: jnp.ndarray, wqkv, bqkv, wproj, bproj) -> jnp.ndarray:
        b, t, _ = h.shape
        qkv = h.reshape(b * t, hidden) @ wqkv + bqkv
        qkv = qkv.reshape(b, t, 3, heads, head_dim)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]     # [b,t,nh,hd]
        if use_pallas:
            # fold (batch, head) into the kernel's leading grid axis
            fold = lambda z: z.transpose(0, 2, 1, 3).reshape(  # noqa: E731
                b * heads, t, head_dim)
            out = pallas_attention(fold(q), fold(k), fold(v))
            out = out.reshape(b, heads, t, head_dim).transpose(0, 2, 1, 3)
            out = out.reshape(b, t, hidden)
        else:
            att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
                jnp.float32(head_dim))
            att = jax.nn.softmax(att, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, hidden)
        return out.reshape(b * t, hidden) @ wproj + bproj

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        params = unflatten(flat, shapes)
        it = iter(params)
        nxt = lambda: next(it)  # noqa: E731 — sequential reader

        pw, pb, cls, pos = nxt(), nxt(), nxt(), nxt()
        b = x.shape[0]
        tok = patches(x).reshape(b * n_tokens, patch_dim) @ pw + pb
        tok = tok.reshape(b, n_tokens, hidden)
        h = jnp.concatenate(
            [jnp.broadcast_to(cls[None, None, :], (b, 1, hidden)), tok], axis=1)
        h = h + pos[None, :, :]
        t = n_tokens + 1

        for _ in range(depth):
            ln1s, ln1b = nxt(), nxt()
            wqkv, bqkv, wproj, bproj = nxt(), nxt(), nxt(), nxt()
            ln2s, ln2b = nxt(), nxt()
            wm1, bm1, wm2, bm2 = nxt(), nxt(), nxt(), nxt()

            # Norm scales are zero-initialized in the flat-vector scheme
            # (fan_in_scales gives 1-D tensors std 0); (1 + s) makes the
            # effective initial scale the identity.
            a = attention(layer_norm(h, 1.0 + ln1s, ln1b), wqkv, bqkv, wproj, bproj)
            h = h + a.reshape(b, t, hidden)
            z = layer_norm(h, 1.0 + ln2s, ln2b).reshape(b * t, hidden)
            if use_pallas:
                m = fused_linear(z, wm1, bm1, "gelu")
            else:
                m = jax.nn.gelu(z @ wm1 + bm1, approximate=True)
            m = m @ wm2 + bm2
            h = h + m.reshape(b, t, hidden)

        lns, lnb = nxt(), nxt()
        hw, hb = nxt(), nxt()
        cls_out = layer_norm(h, 1.0 + lns, lnb)[:, 0, :]
        return cls_out @ hw + hb

    return ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=classify_loss(apply),
        x_shape=(batch, image * image),
        y_shape=(batch,),
        y_dtype="i32",
        task="classify",
        meta={"arch": "vit", "hidden": hidden, "depth": depth, "heads": heads,
              "mlp_dim": mlp_dim, "n_classes": n_classes,
              "use_pallas": use_pallas},
    )
