"""SchNet-like continuous-filter convolution (L2) — Figure 7's extra SciML
architecture.

SchNet (Schuett et al. 2017) models quantum interactions with continuous
filters over interatomic distances: h_i <- h_i + sum_j h_j * W(rbf(d_ij)).
The paper uses it as the "small network" datapoint that exposes Push's
per-particle overhead floor (§C.2), so we keep it deliberately tiny. Energy
regression only (first-order autodiff — contrast with cgcnn.py).

Input x[B, A, 3+S] packs positions and a species one-hot; target y[B] is the
energy.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .common import ModelDef, unflatten


def _ssp(x: jnp.ndarray) -> jnp.ndarray:
    """Shifted softplus, SchNet's activation: ln(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def param_shapes(s: int, h: int, g: int, layers: int) -> List[Tuple[int, ...]]:
    shapes: List[Tuple[int, ...]] = [(s, h), (h,)]          # species embed
    for _ in range(layers):
        shapes += [
            (g, h), (h,),        # filter net layer 1 (rbf -> h)
            (h, h), (h,),        # filter net layer 2
            (h, h), (h,),        # atomwise in
            (h, h), (h,),        # atomwise out
        ]
    shapes += [(h, h // 2), (h // 2,), (h // 2, 1), (1,)]   # readout
    return shapes


def build(name: str, *, atoms: int = 8, species: int = 4, hidden: int = 16,
          gauss: int = 16, layers: int = 2, cutoff: float = 4.0,
          batch: int = 20) -> ModelDef:
    shapes = param_shapes(species, hidden, gauss, layers)
    centers = jnp.linspace(0.0, cutoff, gauss)
    width = cutoff / gauss

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        params = unflatten(flat, shapes)
        it = iter(params)
        nxt = lambda: next(it)  # noqa: E731

        pos, spec = x[..., :3], x[..., 3:]
        a = pos.shape[1]
        ew, eb = nxt(), nxt()
        h = spec @ ew + eb                                   # [B, A, H]

        diff = pos[:, :, None, :] - pos[:, None, :, :]
        d = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-9)   # [B, A, A]
        rbf = jnp.exp(-((d[..., None] - centers) ** 2) / (2 * width**2))
        fcut = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0.0, 1.0)) + 1.0)
        fcut = fcut * (1.0 - jnp.eye(a)[None])

        for _ in range(layers):
            fw1, fb1, fw2, fb2 = nxt(), nxt(), nxt(), nxt()
            aw1, ab1, aw2, ab2 = nxt(), nxt(), nxt(), nxt()
            filt = _ssp(_ssp(rbf @ fw1 + fb1) @ fw2 + fb2)   # [B,A,A,H]
            hin = h @ aw1 + ab1                              # [B,A,H]
            conv = jnp.sum(hin[:, None, :, :] * filt
                           * fcut[..., None], axis=2)        # cfconv
            h = h + _ssp(conv @ aw2 + ab2)

        rw1, rb1, rw2, rb2 = nxt(), nxt(), nxt(), nxt()
        atom_e = _ssp(h @ rw1 + rb1) @ rw2 + rb2             # [B, A, 1]
        return jnp.sum(atom_e[..., 0], axis=1)               # [B]

    def loss(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        return jnp.mean((apply(flat, x) - y) ** 2)

    return ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=loss,
        x_shape=(batch, atoms, 3 + species),
        y_shape=(batch,),
        y_dtype="f32",
        task="regress",
        meta={"arch": "schnet", "atoms": atoms, "species": species,
              "hidden": hidden, "gauss": gauss, "layers": layers,
              "cutoff": cutoff},
    )
