"""CGCNN-like crystal-graph convolution (L2) — Figure 4's SciML workload.

The paper fits CGCNN (Xie & Grossman 2018, OCP variant) to a potential energy
surface on MD17; training "will involve second-order derivatives" (§5.1)
because the force prediction F = -dE/dpos sits inside the loss, so the
parameter gradient differentiates through a positional gradient. That extra
compute per particle is exactly the property the paper highlights (SVGD on
CGCNN still scales because per-particle compute dominates communication) — so
this model preserves it.

Graph encoding: dense all-pairs with a smooth distance cutoff (no ragged
edge lists cross the AOT boundary). Input x[B, A, 3+S] packs positions and a
species one-hot; target y[B, 1+3A] packs energy and forces.

Gated edge messages follow CGCNN: z = [h_i, h_j, rbf(d_ij)] with
m_ij = sigmoid(z @ Wf + bf) * softplus(z @ Ws + bs), summed over j with the
cutoff weight.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .common import ModelDef, unflatten


def param_shapes(s: int, h: int, g: int, layers: int) -> List[Tuple[int, ...]]:
    shapes: List[Tuple[int, ...]] = [(s, h), (h,)]          # species embed
    for _ in range(layers):
        z = 2 * h + g
        shapes += [(z, h), (h,), (z, h), (h,)]              # Wf/bf, Ws/bs
    shapes += [(h, h), (h,), (h, 1), (1,)]                  # readout MLP
    return shapes


def build(name: str, *, atoms: int = 8, species: int = 4, hidden: int = 32,
          gauss: int = 16, layers: int = 2, cutoff: float = 4.0,
          batch: int = 20, force_weight: float = 10.0) -> ModelDef:
    shapes = param_shapes(species, hidden, gauss, layers)
    centers = jnp.linspace(0.0, cutoff, gauss)
    width = cutoff / gauss

    def energy(flat: jnp.ndarray, pos: jnp.ndarray,
               spec: jnp.ndarray) -> jnp.ndarray:
        """pos[B, A, 3], spec[B, A, S] -> E[B]."""
        params = unflatten(flat, shapes)
        it = iter(params)
        nxt = lambda: next(it)  # noqa: E731

        b, a = pos.shape[0], pos.shape[1]
        ew, eb = nxt(), nxt()
        h = spec @ ew + eb                                   # [B, A, H]

        diff = pos[:, :, None, :] - pos[:, None, :, :]       # [B, A, A, 3]
        # epsilon keeps d differentiable at i == j (diagonal is masked out).
        d = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-9)   # [B, A, A]
        rbf = jnp.exp(-((d[..., None] - centers) ** 2) / (2 * width**2))
        # smooth cosine cutoff, zero past `cutoff`, zero on the diagonal
        fcut = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0.0, 1.0)) + 1.0)
        eye = jnp.eye(a)[None]
        fcut = fcut * (1.0 - eye)

        for _ in range(layers):
            wf, bf, ws, bs = nxt(), nxt(), nxt(), nxt()
            hi = jnp.broadcast_to(h[:, :, None, :], (b, a, a, hidden))
            hj = jnp.broadcast_to(h[:, None, :, :], (b, a, a, hidden))
            z = jnp.concatenate([hi, hj, rbf], axis=-1)      # [B,A,A,2H+G]
            gate = jax.nn.sigmoid(z @ wf + bf)
            core = jax.nn.softplus(z @ ws + bs)
            msg = jnp.sum(gate * core * fcut[..., None], axis=2)
            h = jax.nn.softplus(h + msg)

        w1, b1, w2, b2 = nxt(), nxt(), nxt(), nxt()
        atom_e = jax.nn.softplus(h @ w1 + b1) @ w2 + b2      # [B, A, 1]
        return jnp.sum(atom_e[..., 0], axis=1)               # [B]

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        """Predict packed [E, F.flat] — same layout as the target."""
        pos, spec = x[..., :3], x[..., 3:]
        e, vjp = jax.vjp(lambda p: energy(flat, p, spec), pos)
        forces = -vjp(jnp.ones_like(e))[0]                   # [B, A, 3]
        b = x.shape[0]
        return jnp.concatenate([e[:, None], forces.reshape(b, -1)], axis=1)

    def loss(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        pred = apply(flat, x)
        e_err = jnp.mean((pred[:, 0] - y[:, 0]) ** 2)
        f_err = jnp.mean((pred[:, 1:] - y[:, 1:]) ** 2)
        return e_err + force_weight * f_err

    return ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=loss,
        x_shape=(batch, atoms, 3 + species),
        y_shape=(batch, 1 + 3 * atoms),
        y_dtype="f32",
        task="regress",
        meta={"arch": "cgcnn", "atoms": atoms, "species": species,
              "hidden": hidden, "gauss": gauss, "layers": layers,
              "cutoff": cutoff, "force_weight": force_weight,
              "second_order": True},
    )
