"""ResNet-lite (L2) — the extra vision architecture of Figure 7.

A scaled-down He-style residual CNN for 28x28 single-channel images: stem
conv, two stages of residual blocks (second stage strided + channel-doubled),
global average pool, linear head. Convolutions use lax.conv_general_dilated
(XLA fuses these well on its own; the Pallas kernel budget goes to the
transformer/MLP workloads that dominate the paper's evaluation).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelDef, classify_loss, unflatten


def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """NCHW conv with HWIO->OIHW weights stored as [out, in, kh, kw]."""
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _gn(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
        eps: float = 1e-5) -> jnp.ndarray:
    """Per-channel norm over spatial dims (instance-norm flavour; batch-size
    independent so train == eval and no running stats cross the L2/L3
    boundary)."""
    mu = jnp.mean(x, axis=(2, 3), keepdims=True)
    var = jnp.var(x, axis=(2, 3), keepdims=True)
    # scale is zero-initialized in the flat-vector scheme; shift by 1 so the
    # effective initial scale is the identity.
    return (x - mu) / jnp.sqrt(var + eps) * (1.0 + scale[None, :, None, None]) \
        + bias[None, :, None, None]


def param_shapes(c: int, blocks: int) -> List[Tuple[int, ...]]:
    shapes: List[Tuple[int, ...]] = [(c, 1, 3, 3), (c,), (c,)]   # stem + gn
    for stage, ch in ((0, c), (1, 2 * c)):
        for bi in range(blocks):
            cin = ch if not (stage == 1 and bi == 0) else c
            shapes += [
                (ch, cin, 3, 3), (ch,), (ch,),     # conv1 + gn1
                (ch, ch, 3, 3), (ch,), (ch,),      # conv2 + gn2
            ]
            if cin != ch:
                shapes += [(ch, cin, 1, 1)]        # projection shortcut
    shapes += [(2 * c, 10), (10,)]                 # head
    return shapes


def build(name: str, *, image: int = 28, c: int = 8, blocks: int = 2,
          n_classes: int = 10, batch: int = 128) -> ModelDef:
    shapes = param_shapes(c, blocks)

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        params = unflatten(flat, shapes)
        it = iter(params)
        nxt = lambda: next(it)  # noqa: E731

        b = x.shape[0]
        h = x.reshape(b, 1, image, image)
        h = jax.nn.relu(_gn(_conv(h, nxt()), nxt(), nxt()))

        for stage, ch in ((0, c), (1, 2 * c)):
            for bi in range(blocks):
                cin = h.shape[1]
                stride = 2 if (stage == 1 and bi == 0) else 1
                w1, s1, b1 = nxt(), nxt(), nxt()
                w2, s2, b2 = nxt(), nxt(), nxt()
                y = jax.nn.relu(_gn(_conv(h, w1, stride), s1, b1))
                y = _gn(_conv(y, w2), s2, b2)
                if cin != ch:
                    sc = _conv(h, nxt(), stride)
                else:
                    sc = h
                h = jax.nn.relu(sc + y)

        hw, hb = nxt(), nxt()
        pooled = jnp.mean(h, axis=(2, 3))
        return pooled @ hw + hb

    return ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=classify_loss(apply),
        x_shape=(batch, image * image),
        y_shape=(batch,),
        y_dtype="i32",
        task="classify",
        meta={"arch": "resnet", "channels": c, "blocks": blocks,
              "n_classes": n_classes},
    )
