"""MLP regression model (quickstart / SVGD workloads).

The hidden layers run through the L1 fused_linear Pallas kernel (matmul +
bias + GELU in one VMEM-resident pass), so this model's fwd/bwd HLO contains
the kernel's lowering — the L2-calls-L1 composition the architecture requires.
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp

from ..kernels.fused_linear import fused_linear
from .common import ModelDef, regress_loss, unflatten


def build(name: str, in_dim: int, hidden: int, depth: int, out_dim: int,
          batch: int, use_pallas: bool = True) -> ModelDef:
    shapes: List[Tuple[int, ...]] = []
    dims = [in_dim] + [hidden] * depth + [out_dim]
    for a, b in zip(dims[:-1], dims[1:]):
        shapes.append((a, b))
        shapes.append((b,))

    def apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        params = unflatten(flat, shapes)
        h = x
        n_layers = len(dims) - 1
        for li in range(n_layers):
            w, b = params[2 * li], params[2 * li + 1]
            last = li == n_layers - 1
            if use_pallas and not last:
                h = fused_linear(h, w, b, activation="gelu")
            else:
                h = h @ w + b[None, :]
                if not last:
                    import jax
                    h = jax.nn.gelu(h, approximate=True)
        return h[:, 0] if out_dim == 1 else h

    model = ModelDef(
        name=name,
        shapes=shapes,
        apply=apply,
        loss=None,
        x_shape=(batch, in_dim),
        y_shape=(batch,) if out_dim == 1 else (batch, out_dim),
        y_dtype="f32",
        task="regress",
        meta={"arch": "mlp", "hidden": hidden, "depth": depth,
              "use_pallas": use_pallas},
    )
    return ModelDef(**{**dataclass_asdict(model), "loss": regress_loss(apply)})


def dataclass_asdict(m: ModelDef) -> dict:
    # dataclasses.asdict deep-copies (breaks callables); shallow field dict:
    return {f: getattr(m, f) for f in m.__dataclass_fields__}
