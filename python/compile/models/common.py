"""Shared L2 model machinery: ModelDef, init, losses, artifact entries.

Every model is a pure function over a single flat f32[P] parameter vector (the
particle state the Rust coordinator manages — see compile.flatten). A model
contributes four AOT entries with fixed example shapes:

    init(key u32[2])            -> (flat f32[P],)
    fwd (flat, x)               -> (pred,)
    grad(flat, x, y)            -> (loss f32[], grad f32[P])
    step(flat, x, y, lr f32[])  -> (loss f32[], new_flat f32[P])
    adam(flat, m, v, t, x, y, lr) -> (loss, new_flat, new_m, new_v)

`step` is plain SGD; `adam` carries its first/second-moment state as extra
flat vectors owned by the Rust coordinator (the paper's Tables 3/4 protocol
trains with Adam, lr 1e-3). Richer schemes (SWAG moment tracking, SVGD
transport) are composed by the coordinator from these plus the svgd_update
kernel artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..flatten import flatten, shape_size, total_size, unflatten


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A lowered-shape-complete description of one model config."""

    name: str
    shapes: List[Tuple[int, ...]]            # canonical parameter order
    apply: Callable                          # (flat, x) -> pred
    loss: Callable                           # (flat, x, y) -> scalar
    x_shape: Tuple[int, ...]
    y_shape: Tuple[int, ...]
    y_dtype: str                             # "f32" | "i32"
    task: str                                # "classify" | "regress"
    init_scales: List[float] = None          # per-tensor init std (None -> fan-in)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def param_count(self) -> int:
        return total_size(self.shapes)


def fan_in_scales(shapes: Sequence[Tuple[int, ...]]) -> List[float]:
    """He-style per-tensor init std: sqrt(2 / fan_in); biases/1-d tensors 0."""
    scales = []
    for s in shapes:
        if len(s) <= 1:
            scales.append(0.0)
        else:
            fan_in = shape_size(s[:-1]) if len(s) == 2 else shape_size(s[:-1])
            scales.append((2.0 / max(1, fan_in)) ** 0.5)
    return scales


def make_init(model: ModelDef):
    """init(key) -> flat params, with per-tensor scaling."""
    scales = model.init_scales or fan_in_scales(model.shapes)

    def init(key: jnp.ndarray) -> jnp.ndarray:
        # A single draw over the whole flat vector, scaled piecewise. The
        # u32[2] entry argument is folded into a PRNG key so the artifact
        # signature stays plain (no jax key types cross the L2/L3 boundary).
        k = jax.random.fold_in(jax.random.PRNGKey(0), key[0])
        k = jax.random.fold_in(k, key[1])
        flat = jax.random.normal(k, (model.param_count,), jnp.float32)
        segs = []
        idx = 0
        for s, sc in zip(model.shapes, scales):
            n = shape_size(s)
            segs.append(flat[idx:idx + n] * jnp.float32(sc))
            idx += n
        return jnp.concatenate(segs) if segs else flat

    return init


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32[B]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def mse(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred - target) ** 2)


def classify_loss(model_apply):
    def loss(flat, x, y):
        return softmax_xent(model_apply(flat, x), y)
    return loss


def regress_loss(model_apply):
    def loss(flat, x, y):
        return mse(model_apply(flat, x), y)
    return loss


def make_entries(model: ModelDef):
    """Build the four jittable entry functions for a ModelDef.

    All entries return tuples (the AOT path lowers with return_tuple=True and
    the Rust runtime unpacks positionally).
    """
    init = make_init(model)

    def init_entry(key):
        return (init(key),)

    def fwd_entry(flat, x):
        return (model.apply(flat, x),)

    def grad_entry(flat, x, y):
        loss, g = jax.value_and_grad(model.loss)(flat, x, y)
        return (loss, g)

    def step_entry(flat, x, y, lr):
        loss, g = jax.value_and_grad(model.loss)(flat, x, y)
        return (loss, flat - lr * g)

    def adam_entry(flat, m, v, t, x, y, lr,
                   b1=0.9, b2=0.999, eps=1e-8):
        """Adam (Kingma & Ba 2015) with bias correction; t is the 1-based
        step count as f32[] (passed in by the coordinator)."""
        loss, g = jax.value_and_grad(model.loss)(flat, x, y)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / (1.0 - b1**t)
        vhat = v / (1.0 - b2**t)
        new_flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        return (loss, new_flat, m, v)

    return {
        "init": init_entry,
        "fwd": fwd_entry,
        "grad": grad_entry,
        "step": step_entry,
        "adam": adam_entry,
    }


def example_args(model: ModelDef):
    """ShapeDtypeStructs for lowering each entry of a model."""
    f32 = jnp.float32
    flat = jax.ShapeDtypeStruct((model.param_count,), f32)
    x = jax.ShapeDtypeStruct(model.x_shape, f32)
    ydt = jnp.int32 if model.y_dtype == "i32" else f32
    y = jax.ShapeDtypeStruct(model.y_shape, ydt)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    lr = jax.ShapeDtypeStruct((), f32)
    return {
        "init": (key,),
        "fwd": (flat, x),
        "grad": (flat, x, y),
        "step": (flat, x, y, lr),
        "adam": (flat, flat, flat, lr, x, y, lr),
    }


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


__all__ = [
    "ModelDef", "make_entries", "make_init", "example_args", "fan_in_scales",
    "softmax_xent", "mse", "classify_loss", "regress_loss", "layer_norm",
    "flatten", "unflatten", "total_size",
]
