"""Flat-parameter-vector utilities.

All Push models expose their parameters to the Rust coordinator as a single
flat f32[P] vector (the particle's local state). Inside the jitted graph the
vector is unflattened into the per-layer tensors. Keeping the L2/L3 contract
to one tensor makes the Rust runtime generic over architectures and makes the
SVGD kernel (which operates on stacked flat parameter vectors) trivial to
feed.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax.numpy as jnp


def shape_size(shape: Sequence[int]) -> int:
    """Number of elements of a tensor shape."""
    return math.prod(shape) if shape else 1


def total_size(shapes: Sequence[Tuple[int, ...]]) -> int:
    """Total parameter count across a list of shapes."""
    return sum(shape_size(s) for s in shapes)


def unflatten(flat: jnp.ndarray, shapes: Sequence[Tuple[int, ...]]) -> List[jnp.ndarray]:
    """Split a flat f32[P] vector into tensors with the given shapes.

    The order of `shapes` is the canonical parameter order of the model; the
    Rust side never needs to know it.
    """
    out = []
    idx = 0
    for s in shapes:
        n = shape_size(s)
        out.append(flat[idx : idx + n].reshape(s))
        idx += n
    assert idx == flat.shape[0], f"flat vector has {flat.shape[0]} params, shapes need {idx}"
    return out


def flatten(tensors: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Concatenate tensors into a flat f32[P] vector (inverse of unflatten)."""
    return jnp.concatenate([t.reshape(-1) for t in tensors]) if tensors else jnp.zeros((0,), jnp.float32)
