"""L1 Pallas kernels vs pure-jnp oracles (the CORE correctness signal).

hypothesis sweeps shapes (including MXU-unaligned ones, exercising
pick_block's divisor fallback); assert_allclose against compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import (fused_linear, fused_linear_raw,
                                          pick_block)
from compile.kernels.svgd import pairwise_sq_dists, svgd_update


def rand(rs, *shape):
    return jnp.array(rs.randn(*shape), jnp.float32)


# ---------------------------------------------------------------- fused_linear
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.sampled_from(["gelu", "none"]), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_fused_linear_matches_ref(m, k, n, activation, seed):
    rs = np.random.RandomState(seed)
    x, w, b = rand(rs, m, k), rand(rs, k, n), rand(rs, n)
    got = fused_linear_raw(x, w, b, activation=activation)
    want = ref.fused_linear_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (128, 256, 128),
                                   (7, 13, 3), (1, 1, 1), (64, 512, 32)])
def test_fused_linear_shapes(m, k, n):
    rs = np.random.RandomState(0)
    x, w, b = rand(rs, m, k), rand(rs, k, n), rand(rs, n)
    got = fused_linear_raw(x, w, b)
    want = ref.fused_linear_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_linear_grad_matches_ref():
    rs = np.random.RandomState(7)
    x, w, b = rand(rs, 12, 24), rand(rs, 24, 8), rand(rs, 8)

    def f(x, w, b):
        return jnp.sum(jnp.sin(fused_linear(x, w, b, "gelu")))

    def fr(x, w, b):
        return jnp.sum(jnp.sin(ref.fused_linear_ref(x, w, b, "gelu")))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-4)


def test_fused_linear_grad_none_activation():
    rs = np.random.RandomState(8)
    x, w, b = rand(rs, 6, 10), rand(rs, 10, 4), rand(rs, 4)
    g = jax.grad(lambda x: jnp.sum(fused_linear(x, w, b, "none") ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum((x @ w + b) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(1, 600), st.integers(1, 600))
@settings(max_examples=40, deadline=None)
def test_pick_block_invariants(dim, want):
    b = pick_block(dim, want)
    assert 1 <= b <= dim
    assert dim % b == 0
    assert b <= max(1, min(dim, want))


# ------------------------------------------------------------------------ svgd
@given(st.integers(2, 12), st.integers(4, 200), st.integers(0, 2**31 - 1),
       st.floats(0.3, 5.0))
@settings(max_examples=25, deadline=None)
def test_svgd_update_matches_ref(n, d, seed, lengthscale):
    rs = np.random.RandomState(seed)
    p, g = rand(rs, n, d), rand(rs, n, d)
    h = jnp.float32(lengthscale)
    got = svgd_update(p, g, h)
    want = ref.svgd_update_ref(p, g, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(2, 10), st.integers(1, 128), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pairwise_sq_dists_matches_ref(n, d, seed):
    rs = np.random.RandomState(seed)
    p = rand(rs, n, d)
    got = pairwise_sq_dists(p)
    want = ref.pairwise_sq_dists_ref(p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pairwise_sq_dists_diagonal_zero():
    rs = np.random.RandomState(3)
    p = rand(rs, 6, 33)
    d = np.asarray(pairwise_sq_dists(p))
    np.testing.assert_allclose(np.diag(d), np.zeros(6), atol=1e-4)
    np.testing.assert_allclose(d, d.T, atol=1e-4)


def test_svgd_single_mode_attracts():
    """With zero loss gradient the repulsive term pushes particles APART:
    the update for the closest pair points away from each other."""
    p = jnp.array([[0.0, 0.0], [0.1, 0.0], [3.0, 0.0]], jnp.float32)
    g = jnp.zeros_like(p)
    u = np.asarray(svgd_update(p, g, jnp.float32(1.0)))
    # particle 0 and 1 are nearly coincident: repulsion separates them.
    # Rust applies p -= lr * u, so u must point TOWARD the other particle.
    assert u[0, 0] > 0.0 and u[1, 0] < u[0, 0]


def test_svgd_kernel_identity_when_far():
    """Distant particles -> K ~ I -> update ~ g / n (pure gradient step)."""
    rs = np.random.RandomState(1)
    n, d = 4, 32
    p = jnp.array(rs.randn(n, d) * 100.0, jnp.float32)
    g = rand(rs, n, d)
    u = np.asarray(svgd_update(p, g, jnp.float32(1.0)))
    np.testing.assert_allclose(u, np.asarray(g) / n, rtol=1e-3, atol=1e-3)


def test_svgd_block_size_invariance():
    """The d-axis tiling must not change the result."""
    rs = np.random.RandomState(5)
    p, g = rand(rs, 4, 96), rand(rs, 4, 96)
    h = jnp.float32(1.3)
    u1 = svgd_update(p, g, h, bd=96)
    u2 = svgd_update(p, g, h, bd=32)
    u3 = svgd_update(p, g, h, bd=16)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u3), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------------ attention
from compile.kernels.attention import attention, attention_raw  # noqa: E402


@given(st.integers(1, 6), st.integers(1, 16), st.integers(1, 16),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_attention_matches_ref(bh, t, d, seed):
    rs = np.random.RandomState(seed)
    q, k, v = (rand(rs, bh, t, d) for _ in range(3))
    got = attention_raw(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_attention_softmax_rows_sum_to_one_effect():
    """With v = identity-like constant rows, output equals that constant —
    softmax weights sum to 1."""
    rs = np.random.RandomState(0)
    q, k = rand(rs, 2, 5, 4), rand(rs, 2, 5, 4)
    v = jnp.ones((2, 5, 4), jnp.float32) * 3.25
    out = np.asarray(attention_raw(q, k, v))
    np.testing.assert_allclose(out, 3.25 * np.ones_like(out), rtol=1e-5)


def test_attention_grad_matches_ref():
    rs = np.random.RandomState(4)
    q, k, v = (rand(rs, 2, 4, 8) for _ in range(3))

    def f(q, k, v):
        return jnp.sum(jnp.tanh(attention(q, k, v)))

    def fr(q, k, v):
        return jnp.sum(jnp.tanh(ref.attention_ref(q, k, v)))

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_attention_query_block_invariance():
    """Tiling the query axis must not change the result."""
    rs = np.random.RandomState(5)
    q, k, v = (rand(rs, 3, 8, 4) for _ in range(3))
    a = attention_raw(q, k, v, bq=8)
    b = attention_raw(q, k, v, bq=4)
    c = attention_raw(q, k, v, bq=2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-6)
