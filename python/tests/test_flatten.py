"""flatten/unflatten roundtrip + shape accounting (property-based)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.flatten import flatten, shape_size, total_size, unflatten

shapes_strategy = st.lists(
    st.lists(st.integers(1, 5), min_size=0, max_size=3).map(tuple),
    min_size=1, max_size=6)


@given(shapes_strategy, st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_roundtrip(shapes, seed):
    rs = np.random.RandomState(seed % (2**31))
    tensors = [jnp.array(rs.randn(*s), jnp.float32) for s in shapes]
    flat = flatten(tensors)
    assert flat.shape == (total_size(shapes),)
    back = unflatten(flat, shapes)
    assert len(back) == len(tensors)
    for t, b in zip(tensors, back):
        assert t.shape == b.shape
        np.testing.assert_array_equal(np.asarray(t), np.asarray(b))


@given(shapes_strategy)
@settings(max_examples=50, deadline=None)
def test_total_size_matches_elements(shapes):
    assert total_size(shapes) == sum(int(np.prod(s)) if s else 1 for s in shapes)


def test_shape_size_scalar():
    assert shape_size(()) == 1
    assert shape_size((3, 4)) == 12


def test_empty_tensor_list():
    assert flatten([]).shape == (0,)
