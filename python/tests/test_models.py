"""L2 model zoo: entry signatures, init determinism, learnability.

Heavy numeric checks run only on the tiny configs; everything in the
registry gets an eval_shape pass (no execution) so signature drift against
the manifest contract is caught cheaply.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.model as M
from compile.models.common import example_args, make_entries

ALL_MODELS = sorted(M.REGISTRY)


@pytest.fixture(scope="module")
def tiny():
    md = M.REGISTRY["mlp_tiny"]()
    return md, make_entries(md), example_args(md)


# --------------------------------------------------------------- signatures
@pytest.mark.parametrize("name", ALL_MODELS)
def test_entry_signatures(name):
    md = M.REGISTRY[name]()
    entries = make_entries(md)
    ex = example_args(md)
    # init -> (flat,)
    out = jax.eval_shape(entries["init"], *ex["init"])
    assert len(out) == 1 and out[0].shape == (md.param_count,)
    # fwd -> (pred,) with leading batch dim
    out = jax.eval_shape(entries["fwd"], *ex["fwd"])
    assert len(out) == 1 and out[0].shape[0] == md.x_shape[0]
    # grad -> (scalar loss, flat grad)
    out = jax.eval_shape(entries["grad"], *ex["grad"])
    assert out[0].shape == () and out[1].shape == (md.param_count,)
    # step -> (scalar loss, new flat)
    out = jax.eval_shape(entries["step"], *ex["step"])
    assert out[0].shape == () and out[1].shape == (md.param_count,)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_param_count_matches_shapes(name):
    md = M.REGISTRY[name]()
    assert md.param_count == sum(int(np.prod(s)) if s else 1
                                 for s in md.shapes)


def test_registry_groups_cover_registry():
    covered = {m for ms in M.GROUPS.values() for m in ms}
    assert covered == set(M.REGISTRY)


def test_groups_for_expansion_and_errors():
    assert M.groups_for(["core"]) == ["mlp_tiny", "mlp_small"]
    assert M.groups_for(["mlp_tiny"]) == ["mlp_tiny"]
    with pytest.raises(KeyError):
        M.groups_for(["nonexistent_model"])


# ------------------------------------------------------------ init behaviour
def test_init_deterministic(tiny):
    _, entries, _ = tiny
    k = jnp.array([3, 4], jnp.uint32)
    a, = entries["init"](k)
    b, = entries["init"](k)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_init_key_sensitivity(tiny):
    _, entries, _ = tiny
    a, = entries["init"](jnp.array([0, 0], jnp.uint32))
    b, = entries["init"](jnp.array([0, 1], jnp.uint32))
    c, = entries["init"](jnp.array([1, 0], jnp.uint32))
    assert not np.allclose(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))


def test_init_finite_and_scaled(tiny):
    md, entries, _ = tiny
    flat, = entries["init"](jnp.array([7, 8], jnp.uint32))
    f = np.asarray(flat)
    assert np.isfinite(f).all()
    # biases are zero-initialized; weights are not
    assert (f == 0).sum() > 0 and (f != 0).sum() > 0


# ------------------------------------------------------------- learnability
def test_mlp_learns_linear_target(tiny):
    """A few hundred SGD steps on y = <w*, x> must cut the loss sharply."""
    md, entries, _ = tiny
    rs = np.random.RandomState(0)
    wstar = rs.randn(md.x_shape[1]).astype(np.float32)
    x = jnp.array(rs.randn(*md.x_shape), jnp.float32)
    y = x @ jnp.array(wstar)
    flat, = entries["init"](jnp.array([1, 1], jnp.uint32))
    step = jax.jit(entries["step"])
    loss0 = None
    for i in range(300):
        loss, flat = step(flat, x, y, jnp.float32(5e-3))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < 0.2 * loss0, (loss0, float(loss))


def test_grad_step_consistency(tiny):
    """step(flat, ...) == flat - lr * grad(flat, ...)."""
    md, entries, ex = tiny
    rs = np.random.RandomState(2)
    flat, = entries["init"](jnp.array([5, 6], jnp.uint32))
    x = jnp.array(rs.randn(*md.x_shape), jnp.float32)
    y = jnp.array(rs.randn(*md.y_shape), jnp.float32)
    lr = jnp.float32(0.01)
    l1, g = entries["grad"](flat, x, y)
    l2, newflat = entries["step"](flat, x, y, lr)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
    np.testing.assert_allclose(np.asarray(newflat),
                               np.asarray(flat - lr * g), rtol=1e-5,
                               atol=1e-6)


def test_cgcnn_forces_are_neg_position_grad():
    """apply() must pack F = -dE/dpos (the second-order property §5.1)."""
    md = M.REGISTRY["cgcnn_fig4"]()
    entries = make_entries(md)
    flat, = entries["init"](jnp.array([1, 2], jnp.uint32))
    rs = np.random.RandomState(3)
    x = jnp.array(rs.randn(*md.x_shape), jnp.float32)
    pred, = entries["fwd"](flat, x)
    atoms = md.meta["atoms"]
    assert pred.shape == (md.x_shape[0], 1 + 3 * atoms)

    # finite-difference check on one coordinate of one atom
    eps = 1e-3
    xp = x.at[0, 0, 0].add(eps)
    xm = x.at[0, 0, 0].add(-eps)
    ep, = entries["fwd"](flat, xp)
    em, = entries["fwd"](flat, xm)
    fd = (float(ep[0, 0]) - float(em[0, 0])) / (2 * eps)
    force = float(pred[0, 1])          # F[atom0, x] = -dE/dx
    assert force == pytest.approx(-fd, rel=5e-2, abs=5e-3)


def test_vit_fwd_logit_shape():
    md = M.REGISTRY["vit_d1"]()
    entries = make_entries(md)
    flat, = entries["init"](jnp.array([0, 9], jnp.uint32))
    x = jnp.zeros(md.x_shape, jnp.float32)
    logits, = entries["fwd"](flat, x)
    assert logits.shape == (md.x_shape[0], 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_unet_translation_of_constant_field():
    """A constant input field must produce a constant output (periodic conv,
    no spatial symmetry breaking anywhere in the net)."""
    md = M.REGISTRY["unet_fig4"]()
    entries = make_entries(md)
    flat, = entries["init"](jnp.array([4, 2], jnp.uint32))
    x = jnp.ones(md.x_shape, jnp.float32) * 0.7
    out, = entries["fwd"](flat, x)
    o = np.asarray(out)
    assert o.shape == md.x_shape
    np.testing.assert_allclose(o, np.broadcast_to(o[:, :1], o.shape),
                               rtol=1e-4, atol=1e-5)
