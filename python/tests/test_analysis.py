"""Structural performance pins for the L1 kernels (EXPERIMENTS.md §Perf).

These tests fail if a block-shape change regresses the VMEM fit, MXU
occupancy, or HBM-traffic efficiency the docs claim.
"""

from compile.kernels.analysis import (attention_estimate,
                                      fused_linear_estimate, report,
                                      svgd_estimate)


def test_fused_linear_default_blocks_fit_vmem():
    e = fused_linear_estimate(65536, 768, 3072)   # paper-scale FFN
    assert e.fits_vmem
    assert e.vmem_bytes_per_cell <= 256 * 1024    # ~192 KiB documented
    assert e.mxu_tile_occupancy == 1.0            # full 128x128 tiles


def test_fused_linear_small_shapes_degrade_gracefully():
    e = fused_linear_estimate(640, 64, 128)       # vit_fig4 FFN
    assert e.fits_vmem
    assert e.mxu_tile_occupancy == 1.0            # 640 and 128 tile cleanly
    # vit_e2e FFN: m=320 forces bm=80 -> 62.5% M-occupancy (documented)
    e2 = fused_linear_estimate(320, 128, 256)
    assert 0.55 <= e2.mxu_m_occupancy <= 0.70


def test_svgd_bandwidth_bound_story():
    e = svgd_estimate(32, 206346)
    assert e.fits_vmem
    # two-pass scheme: 75% of optimal traffic (P read twice), documented
    assert 0.70 <= e.traffic_efficiency <= 0.80
    # kernel-matrix output tiles are inherently small: <= (32/128)^2
    assert e.mxu_tile_occupancy <= (32 / 128) ** 2 + 1e-9


def test_svgd_beats_elementwise_loop_traffic():
    # the paper's Figure-6 loop touches P O(n) times; our two-pass scheme
    # must stay within ~4/3 of optimal regardless of n
    for n in (4, 8, 16, 32):
        e = svgd_estimate(n, 50_000)
        assert e.traffic_efficiency >= 0.5, (n, e.traffic_efficiency)


def test_attention_tiny_tokens_fit_and_long_seq_tiles():
    tiny = attention_estimate(512, 5, 16)
    assert tiny.fits_vmem
    long = attention_estimate(512, 256, 64, bq=128)
    assert long.fits_vmem
    assert long.grid_cells == 512 * 2             # query axis tiled


def test_report_renders_all_rows():
    rows = [fused_linear_estimate(128, 128, 128), svgd_estimate(8, 1000)]
    text = report(rows)
    assert "fused_linear" in text and "svgd_update" in text
    assert text.count("\n") == len(rows)
