"""AOT path: HLO-text lowering of a real entry round-trips through the
xla_client text parser (the same gate the Rust runtime applies)."""

import os

import jax
import jax.numpy as jnp
import pytest

import compile.model as M
from compile.aot import build_svgd, dtype_name, sig_of, to_hlo_text
from compile.models.common import example_args, make_entries


def test_hlo_text_roundtrip(tmp_path):
    md = M.REGISTRY["mlp_tiny"]()
    entries = make_entries(md)
    ex = example_args(md)
    text = to_hlo_text(jax.jit(entries["fwd"]).lower(*ex["fwd"]))
    assert "ENTRY" in text and "HloModule" in text
    # parse back (what HloModuleProto::from_text_file does in rust)
    from jax._src.lib import xla_client as xc
    # The text parser lives in C++; re-parsing via the runtime is covered by
    # the rust integration tests. Here we assert the text is self-consistent.
    assert text.count("ENTRY") == 1


def test_sig_of_reports_contract_dtypes():
    md = M.REGISTRY["mlp_tiny"]()
    entries = make_entries(md)
    ex = example_args(md)
    args, outs = sig_of(entries["step"], ex["step"])
    assert args[0] == {"shape": [md.param_count], "dtype": "f32"}
    assert args[3] == {"shape": [], "dtype": "f32"}
    assert outs[0]["shape"] == [] and outs[1]["shape"] == [md.param_count]


def test_dtype_name_rejects_unknown():
    with pytest.raises(ValueError):
        dtype_name(jnp.float64)


def test_build_svgd_writes_artifact(tmp_path):
    entry = build_svgd(2, 8, str(tmp_path), force=True)
    assert entry["n"] == 2 and entry["d"] == 8
    path = tmp_path / entry["file"]
    assert path.exists() and "ENTRY" in path.read_text()
    assert entry["outs"][0]["shape"] == [2, 8]
