//! L3 coordinator micro-benchmarks (criterion-less; see bench::harness).
//!
//! Measures the NEL primitives the perf pass optimizes: future round-trip,
//! message dispatch through a particle control thread, device-job
//! dispatch, context-switch (swap) cost under cache pressure, parameter
//! views, and the native SVGD kernel math.
//!
//! Run: `cargo bench --bench l3_microbench` (needs `make artifacts`).

use push::bench::harness::{bench, bench_header};
use push::device::CostModel;
use push::infer::svgd_update_native;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::runtime::{artifacts_dir, Manifest, Tensor};
use push::util::rng::Rng;
use push::{NelConfig, PushDist};

fn cfg(devices: usize, cache: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::free(),
        seed: 1,
        ..NelConfig::default()
    }
}

fn main() {
    let manifest = Manifest::load(artifacts_dir()).expect("make artifacts first");
    bench_header();

    // ---- pure future round-trip (no NEL) --------------------------------
    bench("pfuture_complete_wait", 100, 1000, || {
        let f = PFuture::new();
        f.complete(Ok(Value::Unit));
        let _ = f.wait();
    });

    // ---- message -> handler -> reply through a control thread -----------
    {
        let pd = PushDist::new(&manifest, "mlp_tiny", cfg(1, 4)).unwrap();
        let noop = handler(|_ctx, _| Ok(Value::Unit));
        let p = pd
            .p_create(CreateOpts {
                receive: [("PING".to_string(), noop)].into_iter().collect(),
                ..CreateOpts::default()
            })
            .unwrap();
        pd.p_launch(p, "PING", vec![]).wait().unwrap();
        bench("message_roundtrip_noop_handler", 100, 1000, || {
            pd.p_launch(p, "PING", vec![]).wait().unwrap();
        });
    }

    // ---- device job dispatch (queue + thread + reply) --------------------
    {
        let pd = PushDist::new(&manifest, "mlp_tiny", cfg(1, 4)).unwrap();
        let p = pd.p_create(CreateOpts::default()).unwrap();
        pd.get(p).wait().unwrap();
        bench("device_job_param_view", 100, 1000, || {
            pd.get(p).wait().unwrap();
        });
    }

    // ---- PJRT execute of the smallest entry ------------------------------
    {
        let pd = PushDist::new(&manifest, "mlp_tiny", cfg(1, 4)).unwrap();
        let p = pd.p_create(CreateOpts::default()).unwrap();
        let model = pd.model().clone();
        let xn: usize = model.x_shape.iter().product();
        let x = Tensor::f32(model.x_shape.clone(), vec![0.1; xn]);
        pd.forward(p, x.clone()).wait().unwrap();
        bench("pjrt_forward_mlp_tiny", 20, 150, || {
            pd.forward(p, x.clone()).wait().unwrap();
        });
    }

    // ---- context switch: alternate two particles in a 1-slot cache ------
    {
        let pd = PushDist::new(&manifest, "mlp_small", cfg(1, 1)).unwrap();
        let pids = pd.p_create_n(2, |_| CreateOpts::default()).unwrap();
        pd.get(pids[0]).wait().unwrap();
        let mut flip = 0usize;
        bench("context_switch_swap_in_out", 50, 500, || {
            // every access misses: swap-out + swap-in of ~21 KB params
            pd.get(pids[flip % 2]).wait().unwrap();
            flip += 1;
        });
        let stats = pd.stats();
        println!(
            "    (cache hits {} misses {} swapped {} MB)",
            stats.devices[0].cache_hits,
            stats.devices[0].cache_misses,
            stats.devices[0].swap_bytes / (1 << 20)
        );
    }

    // ---- cache hit path for comparison -----------------------------------
    {
        let pd = PushDist::new(&manifest, "mlp_small", cfg(1, 2)).unwrap();
        let pids = pd.p_create_n(2, |_| CreateOpts::default()).unwrap();
        pd.get(pids[0]).wait().unwrap();
        pd.get(pids[1]).wait().unwrap();
        let mut flip = 0usize;
        bench("context_switch_cache_hit", 50, 500, || {
            pd.get(pids[flip % 2]).wait().unwrap();
            flip += 1;
        });
    }

    // ---- native SVGD update math (the baseline's kernel path) ------------
    {
        let d = 5000;
        let mut rng = Rng::new(3);
        for n in [4usize, 16] {
            let p: Vec<Tensor> =
                (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
            let g: Vec<Tensor> =
                (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
            bench(&format!("svgd_native_n{n}_d{d}"), 3, 30, || {
                svgd_update_native(&p, &g, 10.0).unwrap();
            });
        }
    }

    // ---- SVGD Pallas artifact vs native (same shapes) ---------------------
    {
        let pd = PushDist::new(&manifest, "mlp_small", cfg(1, 4)).unwrap();
        let d = pd.model().param_count;
        let mut rng = Rng::new(4);
        for n in [4usize, 16] {
            let path = pd.svgd_artifact(n).expect("svgd artifact");
            let p = Tensor::f32(vec![n, d], rng.normal_vec(n * d));
            let g = Tensor::f32(vec![n, d], rng.normal_vec(n * d));
            let h = Tensor::scalar_f32(10.0);
            pd.nel()
                .run_artifact(0, path.clone(), vec![p.clone(), g.clone(), h.clone()])
                .wait()
                .unwrap();
            bench(&format!("svgd_artifact_n{n}_d{d}"), 5, 50, || {
                pd.nel()
                    .run_artifact(0, path.clone(), vec![p.clone(), g.clone(), h.clone()])
                    .wait()
                    .unwrap();
            });
        }
    }

    // ---- tensor stacking (leader-side gather cost) ------------------------
    {
        let d = 50_000;
        let mut rng = Rng::new(5);
        let rows: Vec<Tensor> = (0..16).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        bench("stack_rows_16x50k", 20, 500, || {
            let refs: Vec<&Tensor> = rows.iter().collect();
            let _ = Tensor::stack_rows(&refs);
        });
    }
}
