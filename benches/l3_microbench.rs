//! L3 coordinator micro-benchmarks (criterion-less; see bench::harness).
//!
//! Measures the NEL primitives the perf pass optimizes: future round-trip,
//! message dispatch through the M:N scheduler, particle creation at 1k
//! scale (vs a thread-per-particle control), broadcast fan-out (vs serial
//! sends), the PD fabric seam (single-node InProc vs the raw NEL path, and
//! a 2-node TCP-loopback broadcast over real sockets, the same broadcast
//! on the evented poll-reactor transport, and a 256-idle-link connection
//! scaling pair: thread-per-link vs the fixed poll-shard pool), wire-codec
//! encode/decode throughput, device-job dispatch, context-switch (swap)
//! cost under cache pressure, parameter views, the native SVGD kernel
//! math, the SGMCMC chain-step body (SGLD update + native linear
//! gradient), the native model zoo's fused MLP and 1-D conv grad/forward
//! bodies, the prefetching data pipeline (a 40-batch epoch with the
//! gathers overlapped vs synchronous), posterior serving under training
//! load (SGLD rounds with vs without hammering readers), and the
//! heartbeat monitor's tax on a 2-node training loop (SGLD rounds over
//! TCP loopback with the liveness monitor at an aggressive 2ms cadence
//! vs no monitor).
//!
//! Hermetic by default: the zero-copy-plane cases (params_view, SVGD
//! stacking round, send-label interning) need no artifacts and no PJRT.
//! The artifact-backed cases run only when `make artifacts` has produced a
//! manifest (and the build has `--features pjrt`).
//!
//! Run: `cargo bench --bench l3_microbench`. Set `PUSH_BENCH_JSON=<path>`
//! to also write the summaries as JSON (used to produce BENCH_l3.json).

use std::collections::BTreeMap;
use std::hint::black_box;
use std::sync::Arc;

use push::bench::harness::{bench, bench_header};
use push::data::{Batch, BatchSource, DataLoader, Dataset, PrefetchLoader};
use push::device::stats::DeviceStats;
use push::device::{CostModel, HostStore, ResidentCache};
use push::nel::trace::Trace;
use push::nel::CreateOpts;
use push::particle::{handler, PFuture, Value};
use push::pd::{wire, FabricConfig, SpecOpts, Topology, TransportKind};
use push::runtime::tensor::ops;
use push::runtime::{artifacts_dir, DType, Manifest, ModelSpec, Tensor};
use push::util::json::Json;
use push::util::rng::Rng;
use push::util::stats::Summary;
use push::{Nel, NelConfig, Pid, PushDist};

fn cfg(devices: usize, cache: usize) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::free(),
        seed: 1,
        ..NelConfig::default()
    }
}

/// A parameter-less model spec for NEL-only benches (no artifacts).
fn dummy_model() -> Arc<ModelSpec> {
    Arc::new(ModelSpec {
        name: "bench_dummy".to_string(),
        param_count: 0,
        task: "regress".to_string(),
        x_shape: vec![1],
        y_shape: vec![1],
        y_dtype: DType::F32,
        arch: "none".to_string(),
        meta: BTreeMap::new(),
        entries: BTreeMap::new(),
    })
}

/// The same dummy model wrapped as a manifest, for PD-fabric benches.
fn dummy_manifest() -> Manifest {
    Manifest {
        dir: std::path::PathBuf::from("."),
        models: [("bench_dummy".to_string(), (*dummy_model()).clone())]
            .into_iter()
            .collect(),
        svgd: Vec::new(),
    }
}

fn run(
    results: &mut Vec<(String, Summary)>,
    name: &str,
    warmup: usize,
    iters: usize,
    f: impl FnMut(),
) {
    let s = bench(name, warmup, iters, f);
    results.push((name.to_string(), s));
}

fn main() {
    let manifest = Manifest::load(artifacts_dir()).ok();
    let mut results: Vec<(String, Summary)> = Vec::new();
    bench_header();

    // ---- pure future round-trip (no NEL) --------------------------------
    run(&mut results, "pfuture_complete_wait", 100, 1000, || {
        let f = PFuture::new();
        f.complete(Ok(Value::Unit));
        let _ = f.wait();
    });

    // ---- message -> handler -> reply through a scheduler worker ---------
    // The label is interned into one Arc<str> per send and shared with
    // every trace event (previously three String clones per send).
    {
        const LABEL: &str = "SEND_LABEL_INTERNING_BENCH_MESSAGE";
        let mk_nel = |trace: bool| {
            let nel = Nel::new(NelConfig { trace, ..cfg(1, 4) }).unwrap();
            let noop = handler(|_ctx, _| Ok(Value::Unit));
            let p = nel
                .p_create(
                    dummy_model(),
                    CreateOpts {
                        no_params: true,
                        receive: [(LABEL.to_string(), noop)].into_iter().collect(),
                        ..CreateOpts::default()
                    },
                )
                .unwrap();
            nel.send(None, p, LABEL, vec![]).wait().unwrap();
            (nel, p)
        };
        let (nel, p) = mk_nel(false);
        run(&mut results, "send_label_interning", 100, 2000, || {
            nel.send(None, p, LABEL, vec![]).wait().unwrap();
        });
        let (nel, p) = mk_nel(true);
        run(&mut results, "send_label_interning_traced", 100, 2000, || {
            nel.send(None, p, LABEL, vec![]).wait().unwrap();
        });
    }

    // ---- M:N scheduler: 1k particle creation ----------------------------
    // sched: Nel::new (fixed worker pool) + 1024 p_creates + teardown —
    // creation is a mailbox alloc and a map insert, no thread spawn.
    // thread_per control: the seed implementation's control plane, one OS
    // thread + channel per particle, same create/teardown shape.
    {
        let model = dummy_model();
        let noop = handler(|_ctx, _| Ok(Value::Unit));
        run(&mut results, "spawn_1k_particles_sched", 1, 10, || {
            let nel = Nel::new(cfg(2, 4)).unwrap();
            for _ in 0..1024 {
                nel.p_create(
                    model.clone(),
                    CreateOpts {
                        no_params: true,
                        receive: [("PING".to_string(), noop.clone())].into_iter().collect(),
                        ..CreateOpts::default()
                    },
                )
                .unwrap();
            }
            black_box(&nel);
        });
        run(&mut results, "spawn_1k_particles_thread_per", 1, 10, || {
            let mut txs = Vec::with_capacity(1024);
            let mut joins = Vec::with_capacity(1024);
            for i in 0..1024 {
                let (tx, rx) = std::sync::mpsc::channel::<()>();
                joins.push(
                    std::thread::Builder::new()
                        .name(format!("particle-{i}"))
                        .spawn(move || while rx.recv().is_ok() {})
                        .unwrap(),
                );
                txs.push(tx);
            }
            drop(txs);
            for j in joins {
                j.join().unwrap();
            }
        });
    }

    // ---- batched fan-out vs per-message sends ---------------------------
    // broadcast: one label intern, one counter bump, one particle-map
    // pass, one scheduling batch for all 256 targets + a join_all barrier.
    // serial control: 256 independent sends + the old serial wait_all.
    {
        const FAN: usize = 256;
        let nel = Nel::new(cfg(2, 4)).unwrap();
        let noop = handler(|_ctx, _| Ok(Value::Unit));
        let model = dummy_model();
        let pids: Vec<Pid> = (0..FAN)
            .map(|_| {
                nel.p_create(
                    model.clone(),
                    CreateOpts {
                        no_params: true,
                        receive: [("FAN".to_string(), noop.clone())].into_iter().collect(),
                        ..CreateOpts::default()
                    },
                )
                .unwrap()
            })
            .collect();
        PFuture::join_all(&nel.broadcast(None, &pids, "FAN", vec![])).wait().unwrap();
        run(&mut results, "broadcast_fanout_256", 20, 200, || {
            let futs = nel.broadcast(None, &pids, "FAN", vec![]);
            PFuture::join_all(&futs).wait().unwrap();
        });
        run(&mut results, "send_fanout_serial_256", 20, 200, || {
            let futs: Vec<PFuture> =
                pids.iter().map(|p| nel.send(None, *p, "FAN", vec![])).collect();
            PFuture::wait_all(&futs).unwrap();
        });
    }

    // ---- PD fabric: seam overhead + real-socket broadcast -----------------
    // broadcast_256_inproc: the SAME 256-wide fan-out as broadcast_fanout_256
    // but through the PD's transport seam (single-node InProc fabric) — the
    // refactor must not tax the single-node hot path (gated at 1.1x).
    // broadcast_256_tcp_loopback: two loopback node servers behind real
    // sockets; one request frame per destination node, one batched response.
    {
        const FAN: usize = 256;
        let mk = |nodes: usize, transport: TransportKind| {
            let pd = PushDist::with_topology(
                &dummy_manifest(),
                "bench_dummy",
                cfg(2, 4),
                &Topology { nodes, transport },
            )
            .unwrap();
            let pids = pd
                .p_create_spec_n(FAN, |_| SpecOpts {
                    program: Some(("echo".to_string(), Value::Unit)),
                    no_params: true,
                    ..SpecOpts::default()
                })
                .unwrap();
            PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
            (pd, pids)
        };
        let (pd, pids) = mk(1, TransportKind::InProc);
        run(&mut results, "broadcast_256_inproc", 20, 200, || {
            PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
        });
        let (pd, pids) = mk(2, TransportKind::TcpLoopback);
        run(&mut results, "broadcast_256_tcp_loopback", 10, 100, || {
            PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
        });
        let frames = pd.transport_counters();
        println!(
            "    (tcp fabric: {} frames out / {} in per node-0 link)",
            frames[0].frames_sent, frames[0].frames_received
        );
        // broadcast_256_tcp_evented: the same 2-node fan-out with every
        // link on the shared poll reactor — parity-gated at ≤1.05x of the
        // threaded flavor in BENCH_l3.json.
        let (pd, pids) = mk(2, TransportKind::TcpLoopbackEvented);
        run(&mut results, "broadcast_256_tcp_evented", 10, 100, || {
            PFuture::join_all(&pd.broadcast(&pids, "PING", vec![])).wait().unwrap();
        });
    }

    // ---- connection scaling: 256 idle links --------------------------------
    // The tentpole win of the evented transport: a threaded client spends a
    // reader thread per link (256 links -> 256 spawned threads, plus the
    // server's per-connection writer threads), while the evented flavor
    // parks every link on the fixed poll-shard pool. Both legs hold 256
    // idle links against the SAME evented server (lazy NELs: an idle
    // connection costs one fd, no NEL); the evented leg asserts the census
    // stays under 8 transport threads.
    {
        use push::pd::poll::{live_transport_threads, resident_transport_threads};
        use push::pd::transport::TcpNode;
        const LINKS: usize = 256;

        let addr =
            push::pd::transport::spawn_loopback_node_evented(cfg(1, 2), dummy_model())
                .unwrap();
        // settle: let reader/writer threads from earlier cases exit so the
        // census reflects this case only (resident = the fixed reactor +
        // offload pools, the floor the per-link claim is measured against)
        let t0 = std::time::Instant::now();
        while live_transport_threads() > resident_transport_threads()
            && t0.elapsed() < std::time::Duration::from_secs(5)
        {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }

        run(&mut results, "connections_256_evented", 2, 10, || {
            let links: Vec<TcpNode> =
                (0..LINKS).map(|_| TcpNode::connect_evented(addr).unwrap()).collect();
            let threads = live_transport_threads();
            assert!(
                threads < 8,
                "evented transport held {LINKS} links on {threads} threads (must be < 8)"
            );
            black_box(&links);
        });
        run(&mut results, "connections_256_threaded", 2, 10, || {
            let links: Vec<TcpNode> =
                (0..LINKS).map(|_| TcpNode::connect(addr).unwrap()).collect();
            black_box(&links);
        });
    }

    // ---- wire codec throughput (encode/decode a 1 MB tensor value) --------
    {
        let mut rng = Rng::new(13);
        let d = 1 << 18; // 256k f32 = 1 MB payload
        let v = Value::List(vec![
            Value::Tensor(Tensor::f32(vec![d], rng.normal_vec(d))),
            Value::Usize(7),
            Value::Str("frame".to_string()),
        ]);
        let mut encoded = Vec::new();
        wire::write_value(&mut encoded, &v, 0).unwrap();
        run(&mut results, "wire_codec_encode_1MB", 5, 100, || {
            let mut buf = Vec::with_capacity(encoded.len());
            wire::write_value(&mut buf, &v, 0).unwrap();
            black_box(&buf);
        });
        run(&mut results, "wire_codec_decode_1MB", 5, 100, || {
            let got = wire::read_value(&mut encoded.as_slice(), 0).unwrap();
            black_box(&got);
        });
    }

    // ---- parameter views at the cache layer ------------------------------
    // zero_copy: what params_view does now — an Arc bump.
    // deep_copy: the pre-refactor behavior — clone + forced detach, i.e. a
    // full 4 MB memcpy per view. The gap is the win of the COW plane.
    {
        let d = 1 << 20; // 1M f32 = 4 MB
        let mut cache = ResidentCache::new(4, 1 << 30, CostModel::free());
        let host = HostStore::default();
        let mut st = DeviceStats::default();
        let tr = Trace::disabled();
        host.insert(Pid(0), Tensor::f32(vec![d], vec![1.0; d]));
        cache.ensure_resident(Pid(0), &host, &mut st, &tr, 0).unwrap();
        run(&mut results, "params_view_zero_copy_4MB", 20, 2000, || {
            let v = cache
                .ensure_resident(Pid(0), &host, &mut st, &tr, 0)
                .unwrap()
                .clone();
            black_box(&v);
        });
        run(&mut results, "params_view_deep_copy_4MB", 20, 200, || {
            let mut v = cache
                .ensure_resident(Pid(0), &host, &mut st, &tr, 0)
                .unwrap()
                .clone();
            black_box(v.as_f32_mut()[0]); // detach: the old memcpy cost
        });
    }

    // ---- SVGD leader round data motion (no kernel math, no artifacts) ----
    // Mirrors infer::svgd's gather/stack/unstack/apply round: zero-copy
    // views in, one [n, d] allocation, row views out, in-place axpy apply.
    {
        let (n, d) = (16usize, 50_000usize);
        let mut rng = Rng::new(5);
        let mut parts: Vec<Tensor> =
            (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        run(&mut results, "svgd_round_stacked_16x50k", 10, 200, || {
            // gather: zero-copy snapshots of every particle
            let views: Vec<Tensor> = parts.iter().map(|t| t.clone()).collect();
            let refs: Vec<&Tensor> = views.iter().collect();
            let stacked = Tensor::stack_rows(&refs); // the one allocation
            drop(refs);
            drop(views); // release snapshots so the apply is in place
            let rows = stacked.unstack_rows(); // zero-copy row views
            for (p, u) in parts.iter_mut().zip(&rows) {
                ops::axpy(p, -0.01, u);
            }
        });
        // the pre-refactor shape of the same round: per-particle deep
        // copies on gather and per-row allocations on unstack
        run(&mut results, "svgd_round_deep_copy_16x50k", 10, 200, || {
            let views: Vec<Tensor> = parts
                .iter()
                .map(|t| Tensor::f32(vec![d], t.as_f32().to_vec()))
                .collect();
            let refs: Vec<&Tensor> = views.iter().collect();
            let stacked = Tensor::stack_rows(&refs);
            drop(refs);
            let rows: Vec<Tensor> = (0..n)
                .map(|i| {
                    let s = stacked.as_f32();
                    Tensor::f32(vec![d], s[i * d..(i + 1) * d].to_vec())
                })
                .collect();
            for (p, u) in parts.iter_mut().zip(&rows) {
                ops::axpy(p, -0.01, u);
            }
        });
    }

    // ---- native SVGD update math (the baseline's kernel path) ------------
    {
        let d = 5000;
        let mut rng = Rng::new(3);
        for n in [4usize, 16] {
            let p: Vec<Tensor> =
                (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
            let g: Vec<Tensor> =
                (0..n).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
            run(&mut results, &format!("svgd_native_n{n}_d{d}"), 3, 30, || {
                push::infer::svgd_update_native(&p, &g, 10.0).unwrap();
            });
        }
    }

    // ---- SGMCMC native update math (hermetic) -----------------------------
    // The per-particle chain-step body: detach the gradient, scale by -eps,
    // inject Gaussian noise, apply in place. Plus the closed-form linear
    // gradient the hermetic tests and the sgmcmc_regression example drive.
    {
        use push::infer::sgmcmc::{linear_native_model, noise_rng, ModelSource};
        let d = 50_000usize;
        let mut rng = Rng::new(9);
        let mut params = Tensor::f32(vec![d], rng.normal_vec(d));
        let grad = Tensor::f32(vec![d], rng.normal_vec(d));
        let mut t = 0u64;
        run(&mut results, "sgld_native_step_50k", 10, 300, || {
            let mut u = grad.clone();
            let s = u.as_f32_mut(); // COW detach, like the handler's grad
            for v in s.iter_mut() {
                *v *= -1e-3;
            }
            let sigma = (2.0f32 * 1e-3 * 1e-4).sqrt();
            let mut nrng = noise_rng(1, 0, t);
            for v in u.as_f32_mut() {
                *v += sigma * nrng.normal();
            }
            ops::axpy(&mut params, 1.0, &u);
            t += 1;
        });

        let (gb, gd) = (16usize, 64usize);
        let model = linear_native_model();
        let ModelSource::Native { grad: gfn, .. } = model else { unreachable!() };
        let mut rng = Rng::new(11);
        let w = Tensor::f32(vec![gd], rng.normal_vec(gd));
        let x = Tensor::f32(vec![gb, gd], rng.normal_vec(gb * gd));
        let y = Tensor::f32(vec![gb, 1], rng.normal_vec(gb));
        run(&mut results, "sgmcmc_linear_grad_16x64", 20, 1000, || {
            let _ = gfn(&w, &x, &y).unwrap();
        });
    }

    // ---- kernel plane: scalar reference vs vectorized dispatch ------------
    // Each pair runs the SAME body; only the dispatch knobs differ —
    // force_backend(Scalar) + 1 thread vs the widest detected backend +
    // auto worker shards. Results are bit-identical either way (the
    // fixed-shape reduction tree, DESIGN.md §14), so the pair isolates
    // pure dispatch-tier speed; BENCH_l3.json gates simd <= 0.6x scalar.
    // Without --features simd both legs run the scalar tier (the pool can
    // still shard), so the gate is only checked on simd builds.
    {
        use push::runtime::kernels::{self, Backend};
        let scalar_knobs = || {
            kernels::force_backend(Some(Backend::Scalar));
            kernels::set_threads(1);
        };
        let simd_knobs = || {
            kernels::force_backend(None);
            kernels::set_threads(0);
        };
        let d = 50_000usize;
        let mut rng = Rng::new(0x51);
        let x = Tensor::f32(vec![d], rng.normal_vec(d));
        let mut y = Tensor::f32(vec![d], rng.normal_vec(d));
        scalar_knobs();
        run(&mut results, "axpy_50k_scalar", 20, 500, || {
            ops::axpy(&mut y, 1e-4, &x);
        });
        simd_knobs();
        run(&mut results, "axpy_50k_simd", 20, 500, || {
            ops::axpy(&mut y, 1e-4, &x);
        });

        // one row of the 16-particle RBF kernel matrix at SVGD's stacked
        // shape: 16 sq_dist reductions + 16 fused kernel/repulsion
        // accumulations over 50k dims (the svgd_update_native inner loop)
        let n = 16usize;
        let ps: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let gs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
        let h2 = 10.0f32;
        let mut rbf_row = move || {
            let mut u = vec![0.0f32; d];
            for j in 0..n {
                let d2 = kernels::sq_dist(&ps[0], &ps[j]);
                let kij = (-d2 / (2.0 * h2)).exp();
                kernels::rbf_accum(&mut u, kij, &gs[j], kij / h2, &ps[j], &ps[0]);
            }
            black_box(&u);
        };
        scalar_knobs();
        run(&mut results, "rbf_kernel_16x50k_scalar", 5, 60, || rbf_row());
        simd_knobs();
        run(&mut results, "rbf_kernel_16x50k_simd", 5, 60, || rbf_row());

        // the full fused MLP grad + drift apply (the per-particle step body
        // every SGLD/SGHMC round pays on the registered mlp_native spec)
        use push::infer::ModelSource;
        let nm = push::infer::native_model("mlp_native").unwrap();
        let b = nm.spec.batch();
        let md: usize = nm.spec.x_shape[1..].iter().product();
        let params = nm.init_params(3, 0);
        let mx = Tensor::f32(vec![b, md], rng.normal_vec(b * md));
        let my = Tensor::i32(vec![b], (0..b).map(|_| rng.below(2) as i32).collect());
        let ModelSource::Native { grad: mgrad, .. } = nm.source.clone() else {
            unreachable!()
        };
        let mut mlp_step = move || {
            let (_, g) = mgrad(&params, &mx, &my).unwrap();
            let mut p = params.clone();
            ops::axpy(&mut p, -0.05, &g);
            black_box(&p);
        };
        scalar_knobs();
        run(&mut results, "mlp_grad_step_scalar", 20, 500, || mlp_step());
        simd_knobs();
        run(&mut results, "mlp_grad_step_simd", 20, 500, || mlp_step());
        // leave the defaults for every case after this block
        simd_knobs();
    }

    // ---- native model zoo: closed-form grad/forward bodies (hermetic) -----
    // The per-step cost the CI accuracy-gate job pays: fused
    // affine+activation layers with post-activation caches (MLP) and the
    // direct-convolution 1-D net, each at its registered spec's batch.
    {
        use push::infer::ModelSource;
        for name in ["mlp_native", "conv1d_native"] {
            let nm = push::infer::native_model(name).unwrap();
            let spec = nm.spec.clone();
            let b = spec.batch();
            let d: usize = spec.x_shape[1..].iter().product();
            let mut rng = Rng::new(0x6e61);
            let params = nm.init_params(3, 0);
            let x = Tensor::f32(vec![b, d], rng.normal_vec(b * d));
            let y = if spec.task == "classify" {
                Tensor::i32(vec![b], (0..b).map(|_| rng.below(2) as i32).collect())
            } else {
                let yn: usize = spec.y_shape[1..].iter().product();
                Tensor::f32(vec![b, yn], rng.normal_vec(b * yn))
            };
            let ModelSource::Native { grad, forward, .. } = nm.source.clone() else {
                unreachable!()
            };
            run(&mut results, &format!("{name}_grad_{b}x{d}"), 20, 500, || {
                let _ = grad(&params, &x, &y).unwrap();
            });
            run(&mut results, &format!("{name}_forward_{b}x{d}"), 20, 500, || {
                let _ = forward(&params, &x).unwrap();
            });
        }
    }

    // ---- pipelined data loading: 40-batch epoch, prefetch vs sync ---------
    // The paper fixes 40 batches/epoch (§5.1). Each batch gather is a
    // B*d-float memcpy (+ the Tensor alloc); the consumer's work here is
    // two O(B*d) reduction passes — comparable cost — so the prefetch
    // pipeline can hide most of the gather behind the consume while the
    // synchronous loader pays gather + consume serially. Batch contents
    // are bit-identical either way (tests/properties.rs pins it).
    {
        let (bsz, d, nb) = (64usize, 4096usize, 40usize);
        let mk_data = || {
            let mut ds = Dataset::new_f32(vec![d], vec![1]);
            let mut row = vec![0.0f32; d];
            for i in 0..bsz * nb {
                for (j, r) in row.iter_mut().enumerate() {
                    *r = ((i * 31 + j) % 997) as f32 * 1e-3;
                }
                ds.push_f32(&row, &[i as f32]);
            }
            ds
        };
        let consume = |b: &Batch| -> f32 {
            let xs = b.x.as_f32();
            let s: f32 = xs.iter().sum();
            let q: f32 = xs.iter().map(|v| v * v).sum();
            s + q
        };
        let mut sync = DataLoader::new(mk_data(), bsz, true, 3);
        run(&mut results, "sync_epoch_40x", 2, 30, || {
            let mut acc = 0.0f32;
            for b in sync.epoch_stream() {
                acc += consume(&b);
            }
            black_box(acc);
        });
        let mut pre = PrefetchLoader::new(DataLoader::new(mk_data(), bsz, true, 3));
        run(&mut results, "prefetch_overlap_40x", 2, 30, || {
            let mut acc = 0.0f32;
            for b in pre.epoch_stream() {
                acc += consume(&b);
            }
            black_box(acc);
        });
    }

    // ---- posterior serving under training load ----------------------------
    // One training round = 20 SGLD chain steps (8 particles, native linear
    // model). The serve case runs the SAME rounds while 2 reader threads
    // drive PosteriorServer::refresh + predict_mean at a ~200us cadence;
    // the gate bounds the serving tax on training wall-clock at 1.15x
    // (BENCH_l3.json, inverted-ratio form like the PR-4 seam gate).
    {
        use push::infer::sgmcmc::{
            linear_native_manifest, linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig,
        };
        use std::sync::atomic::{AtomicBool, Ordering};

        const SD: usize = 32;
        const SB: usize = 16;
        let serve_manifest = linear_native_manifest(SD, SB);
        let chain_cfg = || SgmcmcConfig {
            particles: 8,
            algo: SgmcmcAlgo::Sgld,
            schedule: push::infer::Schedule::Constant { eps: 1e-2 },
            temperature: 0.0,
            burn_in: 0,
            thin: 1,
            max_samples: 8,
            seed: 5,
            model: linear_native_model(),
            init: Some(Arc::new(|i| {
                Tensor::f32(vec![SD], Rng::new(0xbe).fold_in(i as u64).normal_vec(SD))
            })),
            ..SgmcmcConfig::default()
        };
        let mk_algo = || {
            let pd = PushDist::new(
                &serve_manifest,
                "linear_native",
                NelConfig { control_workers: 2, ..cfg(2, 4) },
            )
            .unwrap();
            SgMcmc::new(pd, chain_cfg()).unwrap()
        };
        let mut rng = Rng::new(17);
        let rounds: Vec<(Tensor, Tensor)> = (0..20)
            .map(|_| {
                (
                    Tensor::f32(vec![SB, SD], rng.normal_vec(SB * SD)),
                    Tensor::f32(vec![SB, 1], rng.normal_vec(SB)),
                )
            })
            .collect();

        let algo = mk_algo();
        run(&mut results, "serve_training_no_traffic", 2, 30, || {
            for (x, y) in &rounds {
                algo.step_all(x, y).unwrap();
            }
        });

        let algo = mk_algo();
        let server = Arc::new(algo.serve_handle().unwrap());
        algo.step_all(&rounds[0].0, &rounds[0].1).unwrap(); // fill reservoirs
        server.refresh(0).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..2usize)
            .map(|t| {
                let server = server.clone();
                let stop = stop.clone();
                let x = rounds[0].0.clone();
                std::thread::spawn(move || {
                    let mut stamp = t;
                    while !stop.load(Ordering::Relaxed) {
                        server.refresh(stamp).unwrap();
                        stamp += 2;
                        let _ = server.predict_mean(&x);
                        // Realistic query cadence, not a busy spin: the
                        // gate measures the serving path's cost to
                        // training (locks + snapshot clones), not raw
                        // core stealing on a 2-vCPU CI runner.
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                })
            })
            .collect();
        run(&mut results, "serve_under_training_load", 2, 30, || {
            for (x, y) in &rounds {
                algo.step_all(x, y).unwrap();
            }
        });
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        let (refreshes, queries) = server.stats();
        println!("    (serve load: {refreshes} refreshes, {queries} queries during the case)");
    }

    // ---- batched vs sequential snapshot refresh over a 2-node fabric ------
    // A refresh used to cost one blocking ParticleState round-trip per
    // chain; the batched SnapshotNode protocol costs ONE frame per node
    // with every frame in flight before the first wait. At 16 chains over
    // 2 real loopback nodes the gate requires batched <= 0.6x sequential
    // wall-clock (BENCH_l3.json, min_ratio 1.67 sequential/batched).
    {
        use push::infer::sgmcmc::{
            linear_native_manifest, linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig,
        };

        const SD: usize = 32;
        const SB: usize = 16;
        let manifest = linear_native_manifest(SD, SB);
        let pd = PushDist::with_topology(
            &manifest,
            "linear_native",
            NelConfig { control_workers: 2, ..cfg(2, 4) },
            &Topology { nodes: 2, transport: TransportKind::TcpLoopback },
        )
        .unwrap();
        let algo = SgMcmc::new(
            pd,
            SgmcmcConfig {
                particles: 16,
                algo: SgmcmcAlgo::Sgld,
                schedule: push::infer::Schedule::Constant { eps: 1e-2 },
                temperature: 0.0,
                burn_in: 0,
                thin: 1,
                max_samples: 8,
                seed: 5,
                model: linear_native_model(),
                init: Some(Arc::new(|i| {
                    Tensor::f32(vec![SD], Rng::new(0xbe).fold_in(i as u64).normal_vec(SD))
                })),
                ..SgmcmcConfig::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(23);
        for _ in 0..4 {
            let x = Tensor::f32(vec![SB, SD], rng.normal_vec(SB * SD));
            let y = Tensor::f32(vec![SB, 1], rng.normal_vec(SB));
            algo.step_all(&x, &y).unwrap();
        }
        let server = algo.serve_handle().unwrap();
        run(&mut results, "snapshot_refresh_sequential_2node", 5, 60, || {
            server.refresh_sequential(1).unwrap();
        });
        run(&mut results, "snapshot_refresh_batched_2node", 5, 60, || {
            server.refresh(2).unwrap();
        });
        let full = server.snapshot();
        assert!(full.staleness.is_complete() && full.total_samples() > 0);
    }

    // ---- heartbeat monitor tax on a 2-node training loop ------------------
    // One training round = 20 SGLD chain steps (8 particles, native linear
    // model) over a REAL 2-node TCP-loopback fabric. The monitored case
    // runs the SAME rounds with the liveness monitor probing both links at
    // a 2ms cadence — far hotter than any production setting — and the
    // gate bounds the tax at 1.05x (BENCH_l3.json, inverted-ratio form):
    // heartbeat frames are ~18 bytes, never carry tensors, and bypass the
    // data-path counters, so the only shared cost is socket write
    // interleaving on the link's writer mutex.
    {
        use push::infer::sgmcmc::{
            linear_native_manifest, linear_native_model, SgMcmc, SgmcmcAlgo, SgmcmcConfig,
        };
        const HD: usize = 32;
        const HB: usize = 16;
        let hb_manifest = linear_native_manifest(HD, HB);
        let chain_cfg = || SgmcmcConfig {
            particles: 8,
            algo: SgmcmcAlgo::Sgld,
            schedule: push::infer::Schedule::Constant { eps: 1e-2 },
            temperature: 0.0,
            burn_in: 0,
            thin: 1,
            max_samples: 8,
            seed: 5,
            model: linear_native_model(),
            init: Some(Arc::new(|i| {
                Tensor::f32(vec![HD], Rng::new(0x4b).fold_in(i as u64).normal_vec(HD))
            })),
            ..SgmcmcConfig::default()
        };
        let mk_algo = |fabric: &FabricConfig| {
            let pd = PushDist::with_topology_and_fabric(
                &hb_manifest,
                "linear_native",
                NelConfig { control_workers: 2, ..cfg(2, 4) },
                &Topology { nodes: 2, transport: TransportKind::TcpLoopback },
                fabric,
            )
            .unwrap();
            SgMcmc::new(pd, chain_cfg()).unwrap()
        };
        let mut rng = Rng::new(23);
        let rounds: Vec<(Tensor, Tensor)> = (0..20)
            .map(|_| {
                (
                    Tensor::f32(vec![HB, HD], rng.normal_vec(HB * HD)),
                    Tensor::f32(vec![HB, 1], rng.normal_vec(HB)),
                )
            })
            .collect();

        let algo = mk_algo(&FabricConfig::default()); // no monitor thread
        run(&mut results, "heartbeat_overhead_2node_off", 2, 30, || {
            for (x, y) in &rounds {
                algo.step_all(x, y).unwrap();
            }
        });

        let fabric = FabricConfig {
            heartbeat_every: Some(std::time::Duration::from_millis(2)),
            dead_after: std::time::Duration::from_millis(500),
        };
        let algo = mk_algo(&fabric);
        run(&mut results, "heartbeat_overhead_2node", 2, 30, || {
            for (x, y) in &rounds {
                algo.step_all(x, y).unwrap();
            }
        });
        let counters = algo.pd().transport_counters();
        let probes: u64 = counters.iter().map(|c| c.heartbeats).sum();
        let errors: u64 = counters.iter().map(|c| c.errors).sum();
        println!("    (monitor: {probes} probes sent, {errors} link errors during the case)");
    }

    // ---- tensor stacking (leader-side gather cost) ------------------------
    {
        let d = 50_000;
        let mut rng = Rng::new(5);
        let rows: Vec<Tensor> =
            (0..16).map(|_| Tensor::f32(vec![d], rng.normal_vec(d))).collect();
        run(&mut results, "stack_rows_16x50k", 20, 500, || {
            let refs: Vec<&Tensor> = rows.iter().collect();
            let _ = Tensor::stack_rows(&refs);
        });
    }

    // ---- artifact-backed cases (need `make artifacts` + --features pjrt) --
    if let Some(manifest) = &manifest {
        // message -> handler -> reply over a real model
        {
            let pd = PushDist::new(manifest, "mlp_tiny", cfg(1, 4)).unwrap();
            let noop = handler(|_ctx, _| Ok(Value::Unit));
            let p = pd
                .p_create(CreateOpts {
                    receive: [("PING".to_string(), noop)].into_iter().collect(),
                    ..CreateOpts::default()
                })
                .unwrap();
            pd.p_launch(p, "PING", vec![]).wait().unwrap();
            run(&mut results, "message_roundtrip_noop_handler", 100, 1000, || {
                pd.p_launch(p, "PING", vec![]).wait().unwrap();
            });
        }

        // device job dispatch (queue + thread + zero-copy view reply)
        {
            let pd = PushDist::new(manifest, "mlp_tiny", cfg(1, 4)).unwrap();
            let p = pd.p_create(CreateOpts::default()).unwrap();
            pd.get(p).wait().unwrap();
            run(&mut results, "device_job_param_view", 100, 1000, || {
                pd.get(p).wait().unwrap();
            });
        }

        // PJRT execute of the smallest entry
        {
            let pd = PushDist::new(manifest, "mlp_tiny", cfg(1, 4)).unwrap();
            let p = pd.p_create(CreateOpts::default()).unwrap();
            let model = pd.model().clone();
            let xn: usize = model.x_shape.iter().product();
            let x = Tensor::f32(model.x_shape.clone(), vec![0.1; xn]);
            pd.forward(p, x.clone()).wait().unwrap();
            run(&mut results, "pjrt_forward_mlp_tiny", 20, 150, || {
                pd.forward(p, x.clone()).wait().unwrap();
            });
        }

        // context switch: alternate two particles in a 1-slot cache
        {
            let pd = PushDist::new(manifest, "mlp_small", cfg(1, 1)).unwrap();
            let pids = pd.p_create_n(2, |_| CreateOpts::default()).unwrap();
            pd.get(pids[0]).wait().unwrap();
            let mut flip = 0usize;
            run(&mut results, "context_switch_swap_in_out", 50, 500, || {
                // every access misses: Arc-moving swap-out + swap-in
                pd.get(pids[flip % 2]).wait().unwrap();
                flip += 1;
            });
            let stats = pd.stats();
            println!(
                "    (cache hits {} misses {} swapped {} MB)",
                stats.devices[0].cache_hits,
                stats.devices[0].cache_misses,
                stats.devices[0].swap_bytes / (1 << 20)
            );
        }

        // cache hit path for comparison
        {
            let pd = PushDist::new(manifest, "mlp_small", cfg(1, 2)).unwrap();
            let pids = pd.p_create_n(2, |_| CreateOpts::default()).unwrap();
            pd.get(pids[0]).wait().unwrap();
            pd.get(pids[1]).wait().unwrap();
            let mut flip = 0usize;
            run(&mut results, "context_switch_cache_hit", 50, 500, || {
                pd.get(pids[flip % 2]).wait().unwrap();
                flip += 1;
            });
        }

        // SVGD Pallas artifact vs native (same shapes)
        {
            let pd = PushDist::new(manifest, "mlp_small", cfg(1, 4)).unwrap();
            let d = pd.model().param_count;
            let mut rng = Rng::new(4);
            for n in [4usize, 16] {
                let path = pd.svgd_artifact(n).expect("svgd artifact");
                let p = Tensor::f32(vec![n, d], rng.normal_vec(n * d));
                let g = Tensor::f32(vec![n, d], rng.normal_vec(n * d));
                let h = Tensor::scalar_f32(10.0);
                pd.nel()
                    .run_artifact(0, path.clone(), vec![p.clone(), g.clone(), h.clone()])
                    .wait()
                    .unwrap();
                run(&mut results, &format!("svgd_artifact_n{n}_d{d}"), 5, 50, || {
                    pd.nel()
                        .run_artifact(0, path.clone(), vec![p.clone(), g.clone(), h.clone()])
                        .wait()
                        .unwrap();
                });
            }
        }
    } else {
        println!("    (no artifacts manifest — skipping PJRT-backed cases)");
    }

    if let Ok(path) = std::env::var("PUSH_BENCH_JSON") {
        let mut cases = BTreeMap::new();
        for (name, s) in &results {
            let mut o = BTreeMap::new();
            o.insert("mean_us".to_string(), Json::Num(s.mean * 1e6));
            o.insert("p50_us".to_string(), Json::Num(s.p50 * 1e6));
            o.insert("p90_us".to_string(), Json::Num(s.p90 * 1e6));
            o.insert("max_us".to_string(), Json::Num(s.max * 1e6));
            o.insert("n".to_string(), Json::Num(s.n as f64));
            cases.insert(name.clone(), Json::Obj(o));
        }
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str("l3_microbench".to_string()));
        // compiled feature set: gates in BENCH_l3.json whose
        // `requires_feature` is absent here are skipped by the checker
        // (a non-simd build runs both legs of a scalar/simd pair on the
        // same tier, so its ratio says nothing about the vector path)
        let mut feats = Vec::new();
        for (name, on) in [
            ("simd", cfg!(feature = "simd")),
            ("pjrt", cfg!(feature = "pjrt")),
            ("faultinject", cfg!(feature = "faultinject")),
        ] {
            if on {
                feats.push(Json::Str(name.to_string()));
            }
        }
        top.insert("features".to_string(), Json::Arr(feats));
        top.insert("cases".to_string(), Json::Obj(cases));
        std::fs::write(&path, Json::Obj(top).pretty()).expect("writing bench json");
        println!("\nwrote {path}");
    }
}
