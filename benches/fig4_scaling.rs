//! Figures 4 & 7 regeneration (scaled): particle scaling across simulated
//! devices for {ViT/MNIST-like, CGCNN/MD17-like, UNet/advection} (+ the
//! Figure-7 extras with PUSH_BENCH_FULL=1) under all four algorithm
//! families — ensemble / multi-SWAG / SVGD / SGMCMC (SGLD and SGHMC
//! chains) — plus the handwritten 1-device baselines, so the scaling
//! curves compare every family on the same grid.
//!
//! `cargo bench --bench fig4_scaling` runs a fast grid by default
//! (2 batches/epoch, particles {1,2,4} x devices {1,2,4}); set
//! PUSH_BENCH_FULL=1 for the paper-shaped grid (40 batches, {1,2,4,8}).
//! JSON lands in bench_results/.

use push::bench::report::results_dir;
use push::bench::scaling::{run_figure, ScaleOpts};
use push::bench::Method;
use push::runtime::{artifacts_dir, Manifest};

fn main() {
    let manifest = Manifest::load(artifacts_dir()).expect("make artifacts first");
    let full = std::env::var("PUSH_BENCH_FULL").is_ok();
    let opts = if full {
        ScaleOpts {
            devices: vec![1, 2, 4],
            particles_base: vec![1, 2, 4, 8],
            batches: 40,
            epochs: 3,
            ..ScaleOpts::default()
        }
    } else {
        // fast grid sized for a 1-core CI-style run (~10 min total)
        ScaleOpts {
            devices: vec![1, 2, 4],
            particles_base: vec![1, 2],
            batches: 2,
            epochs: 2,
            ..ScaleOpts::default()
        }
    };

    let rep = run_figure(
        &manifest,
        "fig4_scaling",
        &["vit_fig4", "cgcnn_fig4", "unet_fig4"],
        &Method::all(),
        &opts,
    )
    .expect("fig4");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}");

    let rep = run_figure(
        &manifest,
        "fig7_scaling",
        &["resnet_fig7", "schnet_fig7"],
        &Method::all(),
        &opts,
    )
    .expect("fig7");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}");
    let _ = full;
}
