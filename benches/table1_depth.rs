//! Tables 1 & 2 regeneration (scaled): the depth/width vs particles
//! tradeoff at constant effective parameter count, multi-SWAG on the ViT
//! sweep across 1/2/4 simulated devices.
//!
//! Fast by default (2 batches/epoch); PUSH_BENCH_FULL=1 runs 40 batches
//! and the long width tail (w16/w8 with 32/128 particles).

use push::bench::depth_width::{run, table1_rows, table2_rows};
use push::bench::report::results_dir;
use push::bench::scaling::ScaleOpts;
use push::bench::Method;
use push::runtime::{artifacts_dir, Manifest};

fn main() {
    let manifest = Manifest::load(artifacts_dir()).expect("make artifacts first");
    let full = std::env::var("PUSH_BENCH_FULL").is_ok();
    let opts = ScaleOpts {
        devices: vec![1, 2, 4],
        batches: if full { 40 } else { 2 },
        epochs: if full { 3 } else { 2 },
        cache_size: 8,
        baseline: false,
        ..ScaleOpts::default()
    };

    let rep = run(&manifest, "table1_depth", &table1_rows(), Method::MultiSwag, &[1, 2, 4], &opts)
        .expect("table1");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}\n");

    let mut t2 = table2_rows(full);
    if !full {
        t2.truncate(3);
    }
    let rep = run(&manifest, "table2_width", &t2, Method::MultiSwag, &[1, 2, 4], &opts)
        .expect("table2");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}");
}
