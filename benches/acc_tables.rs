//! Tables 3 & 4 regeneration (scaled): multi-SWAG accuracy vs standard
//! training at constant effective parameter count on the synthetic-MNIST
//! classification task.
//!
//! Fast by default (4 epochs, 4 train batches); PUSH_BENCH_FULL=1 runs the
//! paper protocol (10 epochs, 7 pretrain + 3 SWAG).

use push::bench::accuracy::{run, AccOpts};
use push::bench::depth_width::{table1_rows, table2_rows};
use push::bench::report::results_dir;
use push::runtime::{artifacts_dir, Manifest};

fn main() {
    let manifest = Manifest::load(artifacts_dir()).expect("make artifacts first");
    let full = std::env::var("PUSH_BENCH_FULL").is_ok();
    let opts = if full {
        AccOpts { epochs: 10, pretrain_epochs: 7, batches: 8, ..AccOpts::default() }
    } else {
        AccOpts { epochs: 3, pretrain_epochs: 2, batches: 3, test_batches: 2, ..AccOpts::default() }
    };

    let mut rows3 = table1_rows();
    let mut rows4 = table2_rows(false);
    if !full {
        rows3.truncate(3);
        rows4.truncate(3);
    }
    let rep = run(&manifest, "table3_depth_acc", &rows3, &opts).expect("table3");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}\n");

    let rep = run(&manifest, "table4_width_acc", &rows4, &opts).expect("table4");
    rep.print();
    let p = rep.save(results_dir()).expect("save");
    println!("saved {p:?}");
}
