//! Posterior-predictive serving under training load and partial failure
//! (DESIGN.md §10, §12).
//!
//! The paper's serving half ("statistical models as ordinary, queryable
//! functions" — Tran et al.'s framing) applied to SGMCMC particle chains:
//! a [`PosteriorServer`] snapshots each chain's posterior-sample
//! reservoir (`sgmcmc_samples` / `sgmcmc_seen`) and answers
//! `predict_mean` / `predictive_std` from the snapshot on the CALLER's
//! thread, so queries
//!
//! * never enter the M:N scheduler (no broadcast round, no handler turn,
//!   no device job — training keeps every worker),
//! * never block training: a refresh holds each particle's state mutex
//!   exactly as long as one map clone (tensor values are Arc bumps in
//!   process, owned decodes over a wire transport), and
//! * always see a COMPLETE reservoir version: the chain handler commits
//!   `(samples, seen)` atomically (`state_set_many`), and the state map
//!   is cloned under one lock, so every [`ReservoirSnapshot`] satisfies
//!   `samples.len() == min(seen, cap)` — the no-torn-snapshot invariant
//!   `rust/tests/serve.rs` hammers from 8 threads.
//!
//! Snapshots are versioned by `(pid, sgmcmc_seen)` and stamped with the
//! training epoch that refreshed them ([`PosteriorServer::refresh_at`]
//! refreshes at most once per stamp — the `--serve-every N` cadence).
//! On a multi-node PD a refresh is exactly ONE batched `SnapshotNode`
//! frame per node ([`PushDist::snapshot_chains`]), bounded by the
//! configured deadline and retried with jittered backoff; the serving
//! math is transport-oblivious.
//!
//! Failure posture (DESIGN.md §12): a refresh against a dead or slow
//! node degrades to the freshest complete-or-partial snapshot instead of
//! failing the tier — missing chains are carried forward from the last
//! good snapshot and recorded in [`Staleness`] (surfaced per query via
//! [`PosteriorServer::query_mean`] and in [`ServeStats`]); a refresh
//! after `PushDist::recover` heals back to complete. Published versions
//! only grow, even across degraded refreshes. Overload is explicit: a
//! bounded in-flight admission gate sheds excess queries with a typed
//! [`Overloaded`] error rather than queueing without bound.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::infer::eval;
use crate::infer::sgmcmc::{ModelSource, NativeForwardFn, SgmcmcConfig, K_SAMPLES, K_SEEN};
use crate::particle::Value;
use crate::pd::{LinkHealth, PushDist};
use crate::runtime::tensor::ops;
use crate::runtime::Tensor;
use crate::Pid;

/// One chain's reservoir at a point in time. `seen` is the version: the
/// number of candidates the chain has offered so far — it only grows, so
/// `(pid, seen)` identifies the reservoir state exactly.
#[derive(Debug, Clone)]
pub struct ReservoirSnapshot {
    pub pid: Pid,
    pub seen: usize,
    /// Zero-copy clones of the chain's kept posterior samples (immutable:
    /// the chain COW-detaches on its next update).
    pub samples: Vec<Tensor>,
}

/// What a snapshot is missing and how old its carried-over data is. An
/// empty `missing` list means the snapshot is complete.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Staleness {
    /// Chains whose reservoirs could not be refreshed (dead or slow
    /// node); their entries in the snapshot — if any — are carried
    /// forward from the last snapshot that had them.
    pub missing: Vec<Pid>,
    /// Refresh stamps between this snapshot's stamp and the oldest data
    /// it carries (0 when complete, or when there was nothing to carry).
    pub epoch_lag: usize,
}

impl Staleness {
    pub fn is_complete(&self) -> bool {
        self.missing.is_empty()
    }
}

/// A consistent view over every chain's reservoir, stamped with the
/// training epoch that refreshed it.
#[derive(Debug, Clone)]
pub struct PosteriorSnapshot {
    /// Refresh stamp (`None` = never refreshed).
    pub epoch: Option<usize>,
    pub chains: Vec<ReservoirSnapshot>,
    /// Which chains this snapshot could not refresh (see [`Staleness`]).
    pub staleness: Staleness,
}

impl PosteriorSnapshot {
    fn empty() -> PosteriorSnapshot {
        PosteriorSnapshot { epoch: None, chains: Vec::new(), staleness: Staleness::default() }
    }

    /// Kept samples across all chains.
    pub fn total_samples(&self) -> usize {
        self.chains.iter().map(|c| c.samples.len()).sum()
    }

    /// The `(pid, seen)` version vector of this snapshot.
    pub fn versions(&self) -> Vec<(Pid, usize)> {
        self.chains.iter().map(|c| (c.pid, c.seen)).collect()
    }

    fn epoch_label(&self) -> String {
        match self.epoch {
            Some(e) => format!("epoch stamp {e}"),
            None => "never refreshed".to_string(),
        }
    }
}

/// Serving-tier policy knobs (refresh deadlines/retries and query
/// admission). The defaults reproduce the pre-hardening behavior: wait
/// indefinitely, retry twice, admit everything.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Deadline for one refresh attempt across ALL nodes (the budget is
    /// shared — every node's single `SnapshotNode` frame is in flight
    /// before the first wait). `None` waits until the transport fails,
    /// which against a silent link means the heartbeat monitor's
    /// `dead_after`.
    pub refresh_deadline: Option<Duration>,
    /// How many times a refresh re-asks chains that failed, against
    /// surviving (non-Dead) links only.
    pub refresh_retries: u32,
    /// Base backoff before the first retry; doubles per retry with ±25%
    /// deterministic jitter.
    pub refresh_backoff: Duration,
    /// Maximum queries in flight at once; excess queries are shed with a
    /// typed [`Overloaded`] error. `0` = unbounded (no admission gate).
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            refresh_deadline: None,
            refresh_retries: 2,
            refresh_backoff: Duration::from_millis(50),
            max_inflight: 0,
        }
    }
}

/// The typed shedding error: the admission gate was full. Callers
/// distinguish overload from real failures via
/// `err.downcast_ref::<Overloaded>()` and retry later — an admitted
/// query is never corrupted by shedding (it reads a complete published
/// snapshot version either way).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    /// The configured in-flight limit that was hit.
    pub limit: usize,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "overloaded: query shed ({} queries already in flight)", self.limit)
    }
}

impl std::error::Error for Overloaded {}

/// Fixed log2 latency buckets in microseconds: bucket 0 is sub-µs,
/// bucket `b >= 1` covers `[2^(b-1), 2^b) µs`, and the last bucket
/// absorbs everything slower (~2.1 s and up).
pub const LAT_BUCKETS: usize = 22;

struct LatencyCells {
    buckets: [AtomicU64; LAT_BUCKETS],
}

impl LatencyCells {
    fn new() -> LatencyCells {
        LatencyCells { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// A point-in-time read of the per-query latency histogram.
#[derive(Debug, Clone, Default)]
pub struct LatencySnapshot {
    /// Counts per log2 bucket (see [`LAT_BUCKETS`] for the bucket map).
    pub buckets: Vec<u64>,
}

impl LatencySnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Upper bound (µs) of the bucket holding the `q`-quantile query
    /// (`q` in [0, 1]). Log2 buckets make this a factor-of-two estimate,
    /// which is what an overload dashboard needs.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return 1u64 << i;
            }
        }
        1u64 << (LAT_BUCKETS - 1)
    }

    /// `p50/p99` one-liner for CLI output, e.g. `"p50<=128us p99<=1024us"`.
    pub fn render(&self) -> String {
        if self.count() == 0 {
            return "no queries".to_string();
        }
        format!("p50<={}us p99<={}us", self.quantile_us(0.5), self.quantile_us(0.99))
    }
}

/// Every serving-tier counter in one read (the `(refreshes, queries)`
/// pair of [`PosteriorServer::stats`] plus the failure/overload story).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Snapshots published (complete or degraded).
    pub refreshes: u64,
    /// Published snapshots that were missing at least one chain.
    pub degraded_refreshes: u64,
    /// Refresh retry rounds taken against surviving nodes.
    pub retries: u64,
    /// Queries admitted past the gate.
    pub queries: u64,
    /// Admitted queries answered successfully.
    pub served: u64,
    /// Admitted queries answered from a degraded (stale) snapshot.
    pub stale_served: u64,
    /// Queries shed by the admission gate ([`Overloaded`]).
    pub shed: u64,
    /// Per-query latency histogram over admitted queries.
    pub latency: LatencySnapshot,
}

/// A query answer plus the staleness of the snapshot that produced it —
/// a caller serving "millions of users" needs to know when an answer
/// comes from a degraded view, not just that an answer exists.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub value: Tensor,
    /// Stamp of the snapshot that answered.
    pub epoch: Option<usize>,
    pub staleness: Staleness,
}

/// Serves posterior-predictive queries from reservoir snapshots while the
/// chains keep training. Build one via [`crate::infer::SgMcmc::serve_handle`]
/// (or [`PosteriorServer::new`] / [`PosteriorServer::with_config`] with a
/// PD serve handle directly); share it across query threads — every
/// method takes `&self`.
pub struct PosteriorServer {
    pd: PushDist,
    pids: Vec<Pid>,
    cfg: ServeConfig,
    forward: NativeForwardFn,
    classify: bool,
    snap: RwLock<Arc<PosteriorSnapshot>>,
    /// Serializes PUBLISHES only (the remote snapshot phase runs outside
    /// it, so a stalled node never blocks other refreshers): under the
    /// gate the candidate is merged per-pid against the published
    /// snapshot, keeping every published version monotone. Readers
    /// (`snapshot`/`predict_*`) never touch this lock.
    refresh_gate: Mutex<()>,
    inflight: AtomicUsize,
    refreshes: AtomicU64,
    degraded_refreshes: AtomicU64,
    retries: AtomicU64,
    queries: AtomicU64,
    served: AtomicU64,
    stale_served: AtomicU64,
    shed: AtomicU64,
    latency: LatencyCells,
    /// Decorrelates THIS server's retry backoff from every other server
    /// in the fleet (first pid + a process-wide construction counter). A
    /// constant seed here once made a whole fleet sleep the identical
    /// "jittered" duration and retry in lockstep against a recovering
    /// node — the thundering herd the jitter exists to prevent.
    jitter_nonce: u64,
}

/// Construction counter behind the per-server jitter nonce: two servers
/// over the same pids (process restarts, A/B handles) still decorrelate.
static SERVER_SEQ: AtomicU64 = AtomicU64::new(0);

impl PosteriorServer {
    /// `pd` must be a serve handle onto the fabric that owns `pids`
    /// ([`PushDist::serve_handle`]). The chain config supplies the native
    /// forward closure — serving computes on the caller's thread, outside
    /// the device layer, so an artifact-only model cannot serve. Uses
    /// [`ServeConfig::default`]; see [`PosteriorServer::with_config`].
    pub fn new(pd: PushDist, pids: Vec<Pid>, cfg: &SgmcmcConfig) -> Result<PosteriorServer> {
        Self::with_config(pd, pids, cfg, ServeConfig::default())
    }

    /// [`PosteriorServer::new`] with explicit serving policy (refresh
    /// deadline/retries, admission limit).
    pub fn with_config(
        pd: PushDist,
        pids: Vec<Pid>,
        cfg: &SgmcmcConfig,
        serve_cfg: ServeConfig,
    ) -> Result<PosteriorServer> {
        ensure!(!pids.is_empty(), "a posterior server needs at least one chain");
        let forward = match &cfg.model {
            ModelSource::Native { forward, .. } => forward.clone(),
            ModelSource::Artifact => {
                return Err(anyhow!(
                    "posterior serving needs a native ModelSource (forwards run on the \
                     caller's thread, not the device layer); use e.g. linear_native_model()"
                ))
            }
        };
        let classify = pd.model().task == "classify";
        let jitter_nonce = ((pids[0].0 as u64) << 32)
            | (SERVER_SEQ.fetch_add(1, Ordering::Relaxed) & 0xffff_ffff);
        Ok(PosteriorServer {
            pd,
            pids,
            cfg: serve_cfg,
            forward,
            classify,
            snap: RwLock::new(Arc::new(PosteriorSnapshot::empty())),
            refresh_gate: Mutex::new(()),
            inflight: AtomicUsize::new(0),
            refreshes: AtomicU64::new(0),
            degraded_refreshes: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            served: AtomicU64::new(0),
            stale_served: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            latency: LatencyCells::new(),
            jitter_nonce,
        })
    }

    /// The deterministic backoff this server would sleep before retry
    /// `attempt` (1-based): `2^(attempt-1) * refresh_backoff`, ±25%
    /// jitter keyed by the per-server nonce. Public so tests (and
    /// operators debugging a herd) can audit that two servers in a fleet
    /// retry on DISTINCT schedules.
    pub fn retry_backoff(&self, attempt: u32) -> Duration {
        let attempt = attempt.max(1);
        let base_ms =
            (self.cfg.refresh_backoff.as_millis() as u64).max(1) << (attempt - 1).min(8);
        let mut rng = crate::util::rng::Rng::new(0x5e57_4e5e ^ self.jitter_nonce)
            .fold_in(attempt as u64);
        let jitter = rng.below((base_ms / 2 + 1) as usize) as u64;
        Duration::from_millis(base_ms - base_ms / 4 + jitter)
    }

    /// Chains served.
    pub fn pids(&self) -> &[Pid] {
        &self.pids
    }

    /// The current snapshot (an Arc bump; queries keep using the version
    /// they started with even if a refresh lands mid-query).
    pub fn snapshot(&self) -> Arc<PosteriorSnapshot> {
        self.snap.read().unwrap().clone()
    }

    /// Re-snapshot every chain's reservoir and stamp the result `epoch`.
    ///
    /// The remote phase — ONE batched `SnapshotNode` frame per node,
    /// bounded by the configured deadline, retried against surviving
    /// links with jittered backoff — runs with NO server lock held, so a
    /// stalled node blocks neither training nor other refreshers. The
    /// publish phase then merges the candidate per-pid against the
    /// published snapshot under the gate: chains that could not be
    /// refreshed are carried forward from the last good snapshot and
    /// recorded in [`Staleness`], and a chain for which a racing
    /// refresher already published a fresher `(pid, seen)` version keeps
    /// the fresher one — published versions only grow.
    ///
    /// Only a TOTAL failure with nothing ever published errors; any
    /// partial result degrades loudly (warn log + staleness + counters)
    /// and keeps serving.
    pub fn refresh(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        let (fresh, errs) = self.collect_batched();
        self.finish(epoch, fresh, errs)
    }

    /// The pre-batching refresh path — one blocking `ParticleState`
    /// round-trip per chain — kept callable for the
    /// `snapshot_refresh_{batched,sequential}_2node` microbench pair and
    /// as the degenerate reference; the serving tier itself always
    /// refreshes through the batched protocol.
    pub fn refresh_sequential(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        let mut fresh = BTreeMap::new();
        let mut errs: Vec<(Pid, String)> = Vec::new();
        for pid in &self.pids {
            match self.pd.particle_state_checked(*pid) {
                Ok(Some(entries)) => {
                    fresh.insert(*pid, parse_reservoir(*pid, entries));
                }
                Ok(None) => errs.push((*pid, "unknown particle".to_string())),
                Err(e) => errs.push((*pid, e.msg)),
            }
        }
        self.finish(epoch, fresh, errs)
    }

    /// The remote phase: batched snapshots with deadline + bounded
    /// jittered retry. Returns fresh reservoirs per pid and the last
    /// error per still-missing pid. No locks held.
    fn collect_batched(&self) -> (BTreeMap<Pid, ReservoirSnapshot>, Vec<(Pid, String)>) {
        let mut fresh: BTreeMap<Pid, ReservoirSnapshot> = BTreeMap::new();
        let mut last_err: BTreeMap<Pid, String> = BTreeMap::new();
        let mut want: Vec<Pid> = self.pids.clone();
        for attempt in 0..=self.cfg.refresh_retries {
            if attempt > 0 {
                self.retries.fetch_add(1, Ordering::Relaxed);
                // 2^(attempt-1) * base, ±25% deterministic jitter (the
                // vendored crate set has no rand) — bounded, loud, and
                // reproducible under test, but keyed per-server so a
                // fleet never retries in lockstep (see `retry_backoff`).
                std::thread::sleep(self.retry_backoff(attempt));
            }
            for (pid, res) in self.pd.snapshot_chains(&want, self.cfg.refresh_deadline) {
                match res {
                    Ok(Some(entries)) => {
                        fresh.insert(pid, parse_reservoir(pid, entries));
                        last_err.remove(&pid);
                    }
                    Ok(None) => {
                        last_err.insert(pid, "unknown particle".to_string());
                    }
                    Err(e) => {
                        last_err.insert(pid, e.msg);
                    }
                }
            }
            // Retry only chains on links still worth asking: a Dead link
            // stays dead until migration re-homes its pids.
            let health = self.pd.link_health();
            want = self
                .pids
                .iter()
                .copied()
                .filter(|p| !fresh.contains_key(p))
                .filter(|p| {
                    self.pd
                        .node_of(*p)
                        .map(|n| health.get(n) != Some(&LinkHealth::Dead))
                        .unwrap_or(false)
                })
                .collect();
            if want.is_empty() {
                break;
            }
        }
        (fresh, last_err.into_iter().collect())
    }

    /// The publish phase shared by both refresh paths: merge, degrade,
    /// stamp, publish. Holds the gate only here — never across RPC.
    fn finish(
        &self,
        epoch: usize,
        fresh: BTreeMap<Pid, ReservoirSnapshot>,
        errs: Vec<(Pid, String)>,
    ) -> Result<Arc<PosteriorSnapshot>> {
        if fresh.is_empty() {
            // Total failure: fail over to the last good snapshot instead
            // of publishing an all-stale one — leaving the stamp untouched
            // means `refresh_at` keeps re-trying on later stamps.
            let prev = self.snapshot();
            let detail = errs
                .first()
                .map(|(pid, e)| format!("{pid}: {e}"))
                .unwrap_or_else(|| "no chains".to_string());
            if prev.chains.is_empty() {
                return Err(anyhow!(
                    "posterior refresh failed for every chain ({detail}) and no snapshot \
                     has ever been published"
                ));
            }
            self.degraded_refreshes.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!(
                "posterior refresh failed for every chain ({detail}); serving last good \
                 snapshot ({})",
                prev.epoch_label()
            );
            return Ok(prev);
        }
        for (pid, e) in &errs {
            crate::log_warn!("posterior refresh degraded: {pid} unavailable ({e})");
        }

        let _gate = self.refresh_gate.lock().unwrap();
        let prev = self.snap.read().unwrap().clone();
        let prev_by_pid: BTreeMap<Pid, &ReservoirSnapshot> =
            prev.chains.iter().map(|c| (c.pid, c)).collect();
        let mut chains = Vec::with_capacity(self.pids.len());
        let mut missing = Vec::new();
        let mut carried = false;
        for pid in &self.pids {
            match (fresh.get(pid), prev_by_pid.get(pid)) {
                // A racing refresher already published a fresher version
                // of this chain while our RPC phase ran: keep it —
                // published (pid, seen) versions only grow.
                (Some(f), Some(p)) if p.seen > f.seen => chains.push((*p).clone()),
                (Some(f), _) => chains.push(f.clone()),
                // Unreachable chain with prior data: carry it, stale.
                (None, Some(p)) => {
                    missing.push(*pid);
                    carried = true;
                    chains.push((*p).clone());
                }
                // Unreachable chain that has never been snapshotted.
                (None, None) => missing.push(*pid),
            }
        }
        // Stamps are monotone too: a racing refresher with a later stamp
        // must not be rewound by a slower one publishing afterwards.
        let epoch = prev.epoch.map_or(epoch, |pe| pe.max(epoch));
        let epoch_lag = if carried {
            match prev.epoch {
                Some(pe) => {
                    let compounded =
                        missing.iter().any(|p| prev.staleness.missing.contains(p));
                    epoch.saturating_sub(pe)
                        + if compounded { prev.staleness.epoch_lag } else { 0 }
                }
                None => 0,
            }
        } else {
            0
        };
        let snap = Arc::new(PosteriorSnapshot {
            epoch: Some(epoch),
            chains,
            staleness: Staleness { missing, epoch_lag },
        });
        *self.snap.write().unwrap() = snap.clone();
        self.refreshes.fetch_add(1, Ordering::Relaxed);
        if !snap.staleness.is_complete() {
            self.degraded_refreshes.fetch_add(1, Ordering::Relaxed);
        }
        Ok(snap)
    }

    /// The epoch-stamped refresh policy: refresh at most once per stamp.
    /// Callers on a `--serve-every N` cadence pass the training epoch;
    /// repeated calls with the current stamp return the cached snapshot
    /// without touching the particles. Racing callers with the same new
    /// stamp re-check under the gate before publishing, so the published
    /// snapshot still advances once per stamp (a racer that already paid
    /// for its RPC phase merges harmlessly — versions only grow).
    pub fn refresh_at(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        {
            let cur = self.snap.read().unwrap();
            if cur.epoch == Some(epoch) {
                return Ok(cur.clone());
            }
        }
        self.refresh(epoch)
    }

    /// Admission gate: reserve an in-flight slot or shed. The guard
    /// releases the slot on drop (success and error paths alike).
    fn admit(&self) -> Result<InflightGuard<'_>> {
        let prev = self.inflight.fetch_add(1, Ordering::AcqRel);
        if self.cfg.max_inflight > 0 && prev >= self.cfg.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(Overloaded { limit: self.cfg.max_inflight }));
        }
        Ok(InflightGuard { inflight: &self.inflight })
    }

    /// Posterior-mean prediction at `x` from the current snapshot: each
    /// chain averages its reservoir samples' forwards (vote sums for
    /// classify) via the shared [`eval`] combinators, then chain outputs
    /// average — exactly `SgMcmc::predict_mean`'s math, minus the message
    /// round. Chains whose reservoir is still empty are skipped; an
    /// entirely empty snapshot is an error (refresh after burn-in), never
    /// a silently-wrong answer from pre-posterior parameters.
    pub fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        self.query_mean(x).map(|r| r.value)
    }

    /// [`PosteriorServer::predict_mean`] with the answering snapshot's
    /// stamp and [`Staleness`] attached — the query-side surface of the
    /// degrade-to-stale story.
    pub fn query_mean(&self, x: &Tensor) -> Result<QueryResult> {
        let _guard = self.admit()?;
        let t0 = Instant::now();
        self.queries.fetch_add(1, Ordering::Relaxed);
        let snap = self.snapshot();
        let mut acc: Option<Tensor> = None;
        let mut chains_used = 0usize;
        for chain in &snap.chains {
            if chain.samples.is_empty() {
                continue;
            }
            let mut cacc: Option<Tensor> = None;
            for s in &chain.samples {
                let pred = (self.forward)(s, x).map_err(|e| anyhow!("{e}"))?;
                eval::accumulate_prediction(&mut cacc, pred, self.classify);
            }
            let per_chain = eval::finalize_mean(cacc, chain.samples.len(), self.classify)
                .expect("non-empty chain accumulated");
            // chain outputs are vote sums / means — accumulate raw
            match &mut acc {
                None => acc = Some(per_chain),
                Some(a) => ops::axpy(a, 1.0, &per_chain),
            }
            chains_used += 1;
        }
        let mut out = acc.ok_or_else(|| {
            anyhow!(
                "posterior snapshot holds no samples yet ({}); refresh after burn-in",
                snap.epoch_label()
            )
        })?;
        if !self.classify && chains_used > 1 {
            for v in out.as_f32_mut() {
                *v /= chains_used as f32;
            }
        }
        self.served.fetch_add(1, Ordering::Relaxed);
        if !snap.staleness.is_complete() {
            self.stale_served.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.record(t0.elapsed());
        Ok(QueryResult { value: out, epoch: snap.epoch, staleness: snap.staleness.clone() })
    }

    /// Per-point epistemic std across ALL snapshot samples' forwards
    /// (regression only — vote one-hots have no meaningful std).
    pub fn predictive_std(&self, x: &Tensor) -> Result<Tensor> {
        ensure!(!self.classify, "predictive_std serves regression tasks only");
        let _guard = self.admit()?;
        let t0 = Instant::now();
        self.queries.fetch_add(1, Ordering::Relaxed);
        let snap = self.snapshot();
        let mut preds = Vec::with_capacity(snap.total_samples());
        for chain in &snap.chains {
            for s in &chain.samples {
                preds.push((self.forward)(s, x).map_err(|e| anyhow!("{e}"))?);
            }
        }
        ensure!(
            !preds.is_empty(),
            "posterior snapshot holds no samples yet; refresh after burn-in"
        );
        let out = eval::predictive_std(&preds)?;
        self.served.fetch_add(1, Ordering::Relaxed);
        if !snap.staleness.is_complete() {
            self.stale_served.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.record(t0.elapsed());
        Ok(out)
    }

    /// (refreshes, queries) served so far — the original two counters,
    /// kept for callers that only dashboard throughput. The full story
    /// (degraded/stale/shed/retry + latency) is
    /// [`PosteriorServer::serve_stats`].
    pub fn stats(&self) -> (u64, u64) {
        (
            self.refreshes.load(Ordering::Relaxed),
            self.queries.load(Ordering::Relaxed),
        )
    }

    /// Every serving-tier counter plus the latency histogram.
    pub fn serve_stats(&self) -> ServeStats {
        ServeStats {
            refreshes: self.refreshes.load(Ordering::Relaxed),
            degraded_refreshes: self.degraded_refreshes.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            stale_served: self.stale_served.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            latency: self.latency.snapshot(),
        }
    }
}

struct InflightGuard<'a> {
    inflight: &'a AtomicUsize,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

fn parse_reservoir(pid: Pid, entries: Vec<(String, Value)>) -> ReservoirSnapshot {
    let mut seen = 0usize;
    let mut samples = Vec::new();
    for (k, v) in entries {
        match (k.as_str(), v) {
            (K_SEEN, Value::Usize(n)) => seen = n,
            (K_SAMPLES, Value::List(vs)) => {
                samples = vs.into_iter().filter_map(|s| s.tensor().ok()).collect();
            }
            _ => {}
        }
    }
    ReservoirSnapshot { pid, seen, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::sgmcmc::linear_native_model;

    fn cfg() -> SgmcmcConfig {
        SgmcmcConfig { model: linear_native_model(), ..SgmcmcConfig::default() }
    }

    #[test]
    fn artifact_models_cannot_serve() {
        // A server over an artifact-only source must be refused up front:
        // its forwards live behind the device layer.
        let manifest = crate::infer::sgmcmc::linear_native_manifest(2, 1);
        let pd = PushDist::new(
            &manifest,
            "linear_native",
            crate::NelConfig {
                cost: crate::device::CostModel::free(),
                control_workers: 1,
                ..crate::NelConfig::default()
            },
        )
        .unwrap();
        let artifact_cfg = SgmcmcConfig { model: ModelSource::Artifact, ..cfg() };
        // .err(): PosteriorServer has no Debug impl for unwrap_err
        let err = PosteriorServer::new(pd.serve_handle(), vec![Pid(0)], &artifact_cfg)
            .err()
            .expect("artifact source must be refused");
        assert!(format!("{err:#}").contains("native ModelSource"), "{err:#}");

        let err = PosteriorServer::new(pd.serve_handle(), vec![], &cfg())
            .err()
            .expect("zero chains must be refused");
        assert!(format!("{err:#}").contains("at least one chain"), "{err:#}");
    }

    #[test]
    fn snapshot_versions_and_totals() {
        let snap = PosteriorSnapshot {
            epoch: Some(3),
            chains: vec![
                ReservoirSnapshot {
                    pid: Pid(0),
                    seen: 5,
                    samples: vec![Tensor::zeros(vec![2]); 3],
                },
                ReservoirSnapshot { pid: Pid(1), seen: 0, samples: vec![] },
            ],
            staleness: Staleness::default(),
        };
        assert_eq!(snap.total_samples(), 3);
        assert_eq!(snap.versions(), vec![(Pid(0), 5), (Pid(1), 0)]);
        // Option<usize> replaced the old usize::MAX never-refreshed
        // sentinel: an empty server snapshot simply has no stamp.
        assert_eq!(PosteriorSnapshot::empty().epoch, None);
        assert!(snap.staleness.is_complete());
    }

    #[test]
    fn latency_histogram_buckets_and_quantiles() {
        let cells = LatencyCells::new();
        assert_eq!(cells.snapshot().count(), 0);
        assert_eq!(cells.snapshot().quantile_us(0.5), 0);
        assert_eq!(cells.snapshot().render(), "no queries");
        // 0µs lands in bucket 0; [2^(b-1), 2^b) µs lands in bucket b.
        cells.record(Duration::from_micros(0));
        cells.record(Duration::from_micros(1));
        cells.record(Duration::from_micros(2));
        cells.record(Duration::from_micros(3));
        cells.record(Duration::from_micros(4));
        let snap = cells.snapshot();
        assert_eq!(snap.buckets[0], 1);
        assert_eq!(snap.buckets[1], 1); // 1µs
        assert_eq!(snap.buckets[2], 2); // 2, 3µs
        assert_eq!(snap.buckets[3], 1); // 4µs
        assert_eq!(snap.count(), 5);
        // p50 of {0,1,2,3,4} sits in the [2,4) bucket -> upper bound 4.
        assert_eq!(snap.quantile_us(0.5), 4);
        assert_eq!(snap.quantile_us(1.0), 8);
        // The overflow bucket absorbs multi-second queries.
        cells.record(Duration::from_secs(30));
        assert_eq!(cells.snapshot().buckets[LAT_BUCKETS - 1], 1);
    }

    #[test]
    fn overloaded_error_is_typed_and_displayed() {
        let e = anyhow::Error::new(Overloaded { limit: 4 });
        assert!(e.downcast_ref::<Overloaded>().is_some());
        assert_eq!(e.downcast_ref::<Overloaded>().unwrap().limit, 4);
        assert!(format!("{e}").contains("overloaded"), "{e}");
    }
}
