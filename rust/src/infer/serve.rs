//! Posterior-predictive serving under training load (DESIGN.md §10).
//!
//! The paper's serving half ("statistical models as ordinary, queryable
//! functions" — Tran et al.'s framing) applied to SGMCMC particle chains:
//! a [`PosteriorServer`] snapshots each chain's posterior-sample
//! reservoir (`sgmcmc_samples` / `sgmcmc_seen`) and answers
//! `predict_mean` / `predictive_std` from the snapshot on the CALLER's
//! thread, so queries
//!
//! * never enter the M:N scheduler (no broadcast round, no handler turn,
//!   no device job — training keeps every worker),
//! * never block training: a refresh holds each particle's state mutex
//!   exactly as long as one map clone (tensor values are Arc bumps in
//!   process, owned decodes over a wire transport), and
//! * always see a COMPLETE reservoir version: the chain handler commits
//!   `(samples, seen)` atomically (`state_set_many`), and the state map
//!   is cloned under one lock, so every [`ReservoirSnapshot`] satisfies
//!   `samples.len() == min(seen, cap)` — the no-torn-snapshot invariant
//!   `rust/tests/serve.rs` hammers from 8 threads.
//!
//! Snapshots are versioned by `(pid, sgmcmc_seen)` and stamped with the
//! training epoch that refreshed them ([`PosteriorServer::refresh_at`]
//! refreshes at most once per stamp — the `--serve-every N` cadence).
//! On a multi-node PD the snapshot crosses the fabric as ordinary
//! `ParticleState` wire frames; the serving math is transport-oblivious.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, ensure, Result};

use crate::infer::eval;
use crate::infer::sgmcmc::{ModelSource, NativeForwardFn, SgmcmcConfig, K_SAMPLES, K_SEEN};
use crate::particle::Value;
use crate::pd::PushDist;
use crate::runtime::tensor::ops;
use crate::runtime::Tensor;
use crate::Pid;

/// One chain's reservoir at a point in time. `seen` is the version: the
/// number of candidates the chain has offered so far — it only grows, so
/// `(pid, seen)` identifies the reservoir state exactly.
#[derive(Debug, Clone)]
pub struct ReservoirSnapshot {
    pub pid: Pid,
    pub seen: usize,
    /// Zero-copy clones of the chain's kept posterior samples (immutable:
    /// the chain COW-detaches on its next update).
    pub samples: Vec<Tensor>,
}

/// A consistent view over every chain's reservoir, stamped with the
/// training epoch that refreshed it.
#[derive(Debug, Clone)]
pub struct PosteriorSnapshot {
    /// Refresh stamp (`usize::MAX` = never refreshed).
    pub epoch: usize,
    pub chains: Vec<ReservoirSnapshot>,
}

impl PosteriorSnapshot {
    fn empty() -> PosteriorSnapshot {
        PosteriorSnapshot { epoch: usize::MAX, chains: Vec::new() }
    }

    /// Kept samples across all chains.
    pub fn total_samples(&self) -> usize {
        self.chains.iter().map(|c| c.samples.len()).sum()
    }

    /// The `(pid, seen)` version vector of this snapshot.
    pub fn versions(&self) -> Vec<(Pid, usize)> {
        self.chains.iter().map(|c| (c.pid, c.seen)).collect()
    }
}

/// Serves posterior-predictive queries from reservoir snapshots while the
/// chains keep training. Build one via [`crate::infer::SgMcmc::serve_handle`]
/// (or [`PosteriorServer::new`] with a PD serve handle directly); share it
/// across query threads — every method takes `&self`.
pub struct PosteriorServer {
    pd: PushDist,
    pids: Vec<Pid>,
    forward: NativeForwardFn,
    classify: bool,
    snap: RwLock<Arc<PosteriorSnapshot>>,
    /// Serializes refreshes: the state read and the publish must be one
    /// unit, or a preempted refresh could overwrite a fresher snapshot
    /// with an older one — published versions must only grow. Readers
    /// (`snapshot`/`predict_*`) never touch this lock.
    refresh_gate: Mutex<()>,
    refreshes: AtomicU64,
    queries: AtomicU64,
}

impl PosteriorServer {
    /// `pd` must be a serve handle onto the fabric that owns `pids`
    /// ([`PushDist::serve_handle`]). The chain config supplies the native
    /// forward closure — serving computes on the caller's thread, outside
    /// the device layer, so an artifact-only model cannot serve.
    pub fn new(pd: PushDist, pids: Vec<Pid>, cfg: &SgmcmcConfig) -> Result<PosteriorServer> {
        ensure!(!pids.is_empty(), "a posterior server needs at least one chain");
        let forward = match &cfg.model {
            ModelSource::Native { forward, .. } => forward.clone(),
            ModelSource::Artifact => {
                return Err(anyhow!(
                    "posterior serving needs a native ModelSource (forwards run on the \
                     caller's thread, not the device layer); use e.g. linear_native_model()"
                ))
            }
        };
        let classify = pd.model().task == "classify";
        Ok(PosteriorServer {
            pd,
            pids,
            forward,
            classify,
            snap: RwLock::new(Arc::new(PosteriorSnapshot::empty())),
            refresh_gate: Mutex::new(()),
            refreshes: AtomicU64::new(0),
            queries: AtomicU64::new(0),
        })
    }

    /// Chains served.
    pub fn pids(&self) -> &[Pid] {
        &self.pids
    }

    /// The current snapshot (an Arc bump; queries keep using the version
    /// they started with even if a refresh lands mid-query).
    pub fn snapshot(&self) -> Arc<PosteriorSnapshot> {
        self.snap.read().unwrap().clone()
    }

    /// Re-snapshot every chain's reservoir and stamp the result with
    /// `epoch`. In-process this is per-particle map clones (tensor values
    /// are Arc bumps); on a wire transport it is one `ParticleState`
    /// request per chain, decoded as owned tensors. Transport errors
    /// surface — a serving tier must not silently answer from a node it
    /// can no longer reach. Concurrent refreshes serialize on the gate,
    /// so a slow refresh can never publish over a fresher snapshot.
    pub fn refresh(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        let _gate = self.refresh_gate.lock().unwrap();
        self.refresh_locked(epoch)
    }

    /// The body of [`PosteriorServer::refresh`]; callers hold the gate.
    fn refresh_locked(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        let mut chains = Vec::with_capacity(self.pids.len());
        for pid in &self.pids {
            let entries = self
                .pd
                .particle_state_checked(*pid)
                .map_err(|e| anyhow!("snapshotting {pid}: {e}"))?
                .ok_or_else(|| anyhow!("snapshotting {pid}: unknown particle"))?;
            let mut seen = 0usize;
            let mut samples = Vec::new();
            for (k, v) in entries {
                match (k.as_str(), v) {
                    (K_SEEN, Value::Usize(n)) => seen = n,
                    (K_SAMPLES, Value::List(vs)) => {
                        samples = vs.into_iter().filter_map(|s| s.tensor().ok()).collect();
                    }
                    _ => {}
                }
            }
            chains.push(ReservoirSnapshot { pid: *pid, seen, samples });
        }
        let snap = Arc::new(PosteriorSnapshot { epoch, chains });
        *self.snap.write().unwrap() = snap.clone();
        self.refreshes.fetch_add(1, Ordering::Relaxed);
        Ok(snap)
    }

    /// The epoch-stamped refresh policy: refresh at most once per stamp.
    /// Callers on a `--serve-every N` cadence pass the training epoch;
    /// repeated calls with the current stamp return the cached snapshot
    /// without touching the particles. Racing callers with the same new
    /// stamp are serialized by the gate and re-checked under it, so
    /// exactly one of them performs the snapshot.
    pub fn refresh_at(&self, epoch: usize) -> Result<Arc<PosteriorSnapshot>> {
        if epoch == usize::MAX {
            // usize::MAX is the never-refreshed sentinel stamp: treating
            // it as cached would hand back the empty initial snapshot
            // forever. Always snapshot instead.
            return self.refresh(epoch);
        }
        {
            let cur = self.snap.read().unwrap();
            if cur.epoch == epoch {
                return Ok(cur.clone());
            }
        }
        let _gate = self.refresh_gate.lock().unwrap();
        {
            // re-check under the gate: a racing caller may have refreshed
            // this stamp while we waited
            let cur = self.snap.read().unwrap();
            if cur.epoch == epoch {
                return Ok(cur.clone());
            }
        }
        self.refresh_locked(epoch)
    }

    /// Posterior-mean prediction at `x` from the current snapshot: each
    /// chain averages its reservoir samples' forwards (vote sums for
    /// classify) via the shared [`eval`] combinators, then chain outputs
    /// average — exactly `SgMcmc::predict_mean`'s math, minus the message
    /// round. Chains whose reservoir is still empty are skipped; an
    /// entirely empty snapshot is an error (refresh after burn-in), never
    /// a silently-wrong answer from pre-posterior parameters.
    pub fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let snap = self.snapshot();
        let mut acc: Option<Tensor> = None;
        let mut chains_used = 0usize;
        for chain in &snap.chains {
            if chain.samples.is_empty() {
                continue;
            }
            let mut cacc: Option<Tensor> = None;
            for s in &chain.samples {
                let pred = (self.forward)(s, x).map_err(|e| anyhow!("{e}"))?;
                eval::accumulate_prediction(&mut cacc, pred, self.classify);
            }
            let per_chain = eval::finalize_mean(cacc, chain.samples.len(), self.classify)
                .expect("non-empty chain accumulated");
            // chain outputs are vote sums / means — accumulate raw
            match &mut acc {
                None => acc = Some(per_chain),
                Some(a) => ops::axpy(a, 1.0, &per_chain),
            }
            chains_used += 1;
        }
        let mut out = acc.ok_or_else(|| {
            anyhow!(
                "posterior snapshot holds no samples yet (epoch stamp {}); \
                 refresh after burn-in",
                snap.epoch
            )
        })?;
        if !self.classify && chains_used > 1 {
            for v in out.as_f32_mut() {
                *v /= chains_used as f32;
            }
        }
        Ok(out)
    }

    /// Per-point epistemic std across ALL snapshot samples' forwards
    /// (regression only — vote one-hots have no meaningful std).
    pub fn predictive_std(&self, x: &Tensor) -> Result<Tensor> {
        ensure!(!self.classify, "predictive_std serves regression tasks only");
        self.queries.fetch_add(1, Ordering::Relaxed);
        let snap = self.snapshot();
        let mut preds = Vec::with_capacity(snap.total_samples());
        for chain in &snap.chains {
            for s in &chain.samples {
                preds.push((self.forward)(s, x).map_err(|e| anyhow!("{e}"))?);
            }
        }
        ensure!(
            !preds.is_empty(),
            "posterior snapshot holds no samples yet; refresh after burn-in"
        );
        eval::predictive_std(&preds)
    }

    /// (refreshes, queries) served so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.refreshes.load(Ordering::Relaxed),
            self.queries.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::sgmcmc::linear_native_model;

    fn cfg() -> SgmcmcConfig {
        SgmcmcConfig { model: linear_native_model(), ..SgmcmcConfig::default() }
    }

    #[test]
    fn artifact_models_cannot_serve() {
        // A server over an artifact-only source must be refused up front:
        // its forwards live behind the device layer.
        let manifest = crate::infer::sgmcmc::linear_native_manifest(2, 1);
        let pd = PushDist::new(
            &manifest,
            "linear_native",
            crate::NelConfig {
                cost: crate::device::CostModel::free(),
                control_workers: 1,
                ..crate::NelConfig::default()
            },
        )
        .unwrap();
        let artifact_cfg = SgmcmcConfig { model: ModelSource::Artifact, ..cfg() };
        // .err(): PosteriorServer has no Debug impl for unwrap_err
        let err = PosteriorServer::new(pd.serve_handle(), vec![Pid(0)], &artifact_cfg)
            .err()
            .expect("artifact source must be refused");
        assert!(format!("{err:#}").contains("native ModelSource"), "{err:#}");

        let err = PosteriorServer::new(pd.serve_handle(), vec![], &cfg())
            .err()
            .expect("zero chains must be refused");
        assert!(format!("{err:#}").contains("at least one chain"), "{err:#}");
    }

    #[test]
    fn snapshot_versions_and_totals() {
        let snap = PosteriorSnapshot {
            epoch: 3,
            chains: vec![
                ReservoirSnapshot {
                    pid: Pid(0),
                    seen: 5,
                    samples: vec![Tensor::zeros(vec![2]); 3],
                },
                ReservoirSnapshot { pid: Pid(1), seen: 0, samples: vec![] },
            ],
        };
        assert_eq!(snap.total_samples(), 3);
        assert_eq!(snap.versions(), vec![(Pid(0), 5), (Pid(1), 0)]);
        assert_eq!(PosteriorSnapshot::empty().epoch, usize::MAX);
    }
}
