//! Evaluation metrics and posterior-predictive combinators: classification
//! accuracy (Tables 3/4), regression MSE, the accumulate/finalize pair
//! that multi-SWAG and SGMCMC use to average predictions over posterior
//! samples (sum of one-hot votes for classify, running mean for regress),
//! and cross-chain MCMC diagnostics (split R-hat and a Geyer-truncated
//! effective sample size over the particle-chains' reservoirs).

use anyhow::Result;

use crate::data::{BatchSource, DataLoader, Dataset};
use crate::runtime::kernels;
use crate::runtime::tensor::ops;
use crate::runtime::Tensor;

/// Fraction of rows whose argmax matches the label. `scores` is [B, C]
/// (logits or vote counts — argmax is invariant).
pub fn batch_accuracy(scores: &Tensor, labels: &Tensor) -> f64 {
    assert_eq!(scores.shape.len(), 2);
    let (b, c) = (scores.shape[0], scores.shape[1]);
    assert_eq!(labels.element_count(), b);
    let s = scores.as_f32();
    let l = labels.as_i32();
    let mut correct = 0usize;
    for i in 0..b {
        if kernels::argmax(&s[i * c..(i + 1) * c]) as i32 == l[i] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

/// Mean squared error between a prediction and target batch.
pub fn batch_mse(pred: &Tensor, target: &Tensor) -> f64 {
    let p = pred.as_f32();
    let t = target.as_f32();
    assert_eq!(p.len(), t.len());
    p.iter()
        .zip(t)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / p.len() as f64
}

/// One-hot argmax votes of a [B, C] logit tensor (the §C.4 majority-vote
/// protocol's per-sample ballot).
pub fn one_hot_votes(logits: &Tensor) -> Tensor {
    assert_eq!(logits.shape.len(), 2, "votes need [B, C] logits");
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let l = logits.as_f32();
    let mut v = vec![0.0f32; b * c];
    for i in 0..b {
        let best = kernels::argmax(&l[i * c..(i + 1) * c]);
        v[i * c + best] = 1.0;
    }
    Tensor::f32(vec![b, c], v)
}

/// Fold one posterior-sample prediction into a running accumulator:
/// classify sums one-hot votes, regress sums raw predictions (divide by
/// the count via [`finalize_mean`]). In-place when `acc` is uniquely
/// owned (COW detaches otherwise).
pub fn accumulate_prediction(acc: &mut Option<Tensor>, pred: Tensor, classify: bool) {
    let p = if classify { one_hot_votes(&pred) } else { pred };
    match acc {
        None => *acc = Some(p),
        Some(a) => ops::axpy(a, 1.0, &p),
    }
}

/// Finish an [`accumulate_prediction`] run: vote sums pass through
/// unchanged (argmax-invariant), regression sums become means. None when
/// nothing was accumulated.
pub fn finalize_mean(acc: Option<Tensor>, n: usize, classify: bool) -> Option<Tensor> {
    let mut out = acc?;
    if n == 0 {
        return None;
    }
    if !classify {
        kernels::div_scale(out.as_f32_mut(), n as f32);
    }
    Some(out)
}

/// Per-point standard deviation across a set of predictions — the
/// epistemic-uncertainty readout of a posterior-predictive set.
pub fn predictive_std(preds: &[Tensor]) -> Result<Tensor> {
    let n = preds.len();
    anyhow::ensure!(n > 0, "predictive_std over zero predictions");
    let len = preds[0].element_count();
    let mut out = vec![0.0f32; len];
    for (i, o) in out.iter_mut().enumerate() {
        let m: f64 = preds.iter().map(|p| p.as_f32()[i] as f64).sum::<f64>() / n as f64;
        let v: f64 =
            preds.iter().map(|p| (p.as_f32()[i] as f64 - m).powi(2)).sum::<f64>() / n as f64;
        *o = v.sqrt() as f32;
    }
    Ok(Tensor::f32(preds[0].shape.clone(), out))
}

// ---- cross-chain MCMC diagnostics ---------------------------------------

/// Cross-chain convergence summary of an SGMCMC run (ROADMAP: "chain
/// diagnostics (R-hat / ESS across particle-chains)"). Computed per
/// parameter dimension and reported worst-case: the MAX split R-hat and
/// the MIN effective sample size over dimensions. NaN means "not
/// diagnosable" (fewer than 2 chains, fewer than 4 samples per chain, or
/// zero variance everywhere) and renders as "n/a" downstream.
#[derive(Debug, Clone, Copy)]
pub struct ChainDiag {
    pub r_hat: f64,
    pub ess: f64,
    /// Chains (particles) that contributed samples.
    pub chains: usize,
    /// Samples per chain used (chains are truncated to the shortest).
    pub samples_per_chain: usize,
}

impl ChainDiag {
    pub fn undiagnosable() -> ChainDiag {
        ChainDiag { r_hat: f64::NAN, ess: f64::NAN, chains: 0, samples_per_chain: 0 }
    }
}

/// Split R-hat (Gelman et al.): each chain of scalars is halved, then
/// the potential scale reduction sqrt(((n-1)/n W + B/n) / W) is computed
/// over the 2m half-chains. NaN when undiagnosable (W <= 0 with spread
/// means, < 2 chains, or < 4 samples).
pub fn split_r_hat(chains: &[Vec<f64>]) -> f64 {
    let n_full = chains.iter().map(Vec::len).min().unwrap_or(0);
    if chains.len() < 2 || n_full < 4 {
        return f64::NAN;
    }
    let half = n_full / 2;
    let halves: Vec<&[f64]> = chains
        .iter()
        .flat_map(|c| [&c[..half], &c[n_full - half..n_full]])
        .collect();
    let n = half as f64;
    let m = halves.len() as f64;
    let means: Vec<f64> = halves.iter().map(|h| h.iter().sum::<f64>() / n).collect();
    let grand = means.iter().sum::<f64>() / m;
    let b = n / (m - 1.0) * means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>();
    let w = halves
        .iter()
        .zip(&means)
        .map(|(h, mu)| h.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / (n - 1.0))
        .sum::<f64>()
        / m;
    if w <= 0.0 || w.is_nan() {
        // all half-chains constant: identical means converge trivially
        return if b > 0.0 { f64::INFINITY } else { 1.0 };
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

/// Effective sample size across chains: m*n / (1 + 2 Σρ_t) with combined
/// autocorrelations ρ_t = 1 − (W − mean-autocovariance_t)/var⁺ and the
/// Geyer initial-positive truncation (stop at the first non-positive
/// paired sum). NaN when undiagnosable.
pub fn ess(chains: &[Vec<f64>]) -> f64 {
    let n = chains.iter().map(Vec::len).min().unwrap_or(0);
    let m = chains.len();
    if m < 2 || n < 4 {
        return f64::NAN;
    }
    let nf = n as f64;
    let means: Vec<f64> = chains.iter().map(|c| c[..n].iter().sum::<f64>() / nf).collect();
    let vars: Vec<f64> = chains
        .iter()
        .zip(&means)
        .map(|(c, mu)| c[..n].iter().map(|x| (x - mu).powi(2)).sum::<f64>() / (nf - 1.0))
        .collect();
    let w = vars.iter().sum::<f64>() / m as f64;
    let grand = means.iter().sum::<f64>() / m as f64;
    let b_over_n = means.iter().map(|mu| (mu - grand).powi(2)).sum::<f64>() / (m as f64 - 1.0);
    let var_plus = (nf - 1.0) / nf * w + b_over_n;
    if var_plus <= 0.0 || var_plus.is_nan() {
        return f64::NAN;
    }
    // mean autocovariance at lag t across chains
    let acov = |t: usize| -> f64 {
        chains
            .iter()
            .zip(&means)
            .map(|(c, mu)| {
                c[..n - t]
                    .iter()
                    .zip(&c[t..n])
                    .map(|(a, b)| (a - mu) * (b - mu))
                    .sum::<f64>()
                    / (nf - 1.0)
            })
            .sum::<f64>()
            / m as f64
    };
    let rho = |t: usize| 1.0 - (w - acov(t)) / var_plus;
    let mut sum = 0.0;
    let mut t = 1;
    while t + 1 < n {
        let pair = rho(t) + rho(t + 1);
        if pair <= 0.0 {
            break;
        }
        sum += pair;
        t += 2;
    }
    let total = (m * n) as f64;
    (total / (1.0 + 2.0 * sum)).min(total)
}

/// Dimensions diagnosed at most per call: beyond this, a deterministic
/// stride subsamples the parameter vector. Chains are reservoir-bounded
/// (`max_samples`), but d can be in the tens of thousands for artifact
/// models, and the per-dimension ESS is O(chains * samples^2) — a
/// strided few-hundred-dimension worst case is statistically adequate
/// and keeps post-train diagnostics O(ms), not O(s).
const MAX_DIAG_DIMS: usize = 256;

/// Worst-case-over-dimensions diagnostics of a set of particle-chains,
/// each a sequence of flat parameter snapshots (the SGMCMC reservoirs).
/// Dimensions with non-finite values are skipped (large vectors are
/// sampled at a deterministic stride, see [`MAX_DIAG_DIMS`]); if nothing
/// is diagnosable the result is NaN (rendered "n/a").
pub fn chain_diagnostics(chains: &[Vec<Tensor>]) -> ChainDiag {
    let usable: Vec<&Vec<Tensor>> = chains.iter().filter(|c| !c.is_empty()).collect();
    let n = usable.iter().map(|c| c.len()).min().unwrap_or(0);
    if usable.len() < 2 || n < 4 {
        return ChainDiag::undiagnosable();
    }
    let d = usable[0][0].element_count();
    // ceil(d / MAX_DIAG_DIMS) without div_ceil (MSRV 1.72)
    let stride = ((d + MAX_DIAG_DIMS - 1) / MAX_DIAG_DIMS).max(1);
    let mut worst_r = f64::NAN;
    let mut worst_ess = f64::NAN;
    for dim in (0..d).step_by(stride) {
        let series: Vec<Vec<f64>> = usable
            .iter()
            .map(|c| c[..n].iter().map(|t| t.as_f32()[dim] as f64).collect())
            .collect();
        if series.iter().flatten().any(|v| !v.is_finite()) {
            continue;
        }
        let r = split_r_hat(&series);
        let e = ess(&series);
        if r.is_finite() && (worst_r.is_nan() || r > worst_r) {
            worst_r = r;
        }
        if e.is_finite() && (worst_ess.is_nan() || e < worst_ess) {
            worst_ess = e;
        }
    }
    ChainDiag { r_hat: worst_r, ess: worst_ess, chains: usable.len(), samples_per_chain: n }
}

/// Render a diagnostic value the way reports render NaN: honestly.
pub fn fmt_diag(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "n/a".to_string()
    }
}

/// Dataset-level accuracy of a predictor `f(x) -> scores` evaluated in
/// fixed-size batches (artifacts are shape-specialized).
pub fn dataset_accuracy(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let mut acc = 0.0;
    let mut nb = 0usize;
    for b in loader.epoch_stream() {
        let scores = f(&b.x)?;
        acc += batch_accuracy(&scores, &b.y);
        nb += 1;
    }
    Ok(acc / nb.max(1) as f64)
}

/// Dataset-level MSE of a predictor.
pub fn dataset_mse(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let mut e = 0.0;
    let mut nb = 0usize;
    for b in loader.epoch_stream() {
        let pred = f(&b.x)?;
        e += batch_mse(&pred, &b.y);
        nb += 1;
    }
    Ok(e / nb.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let scores = Tensor::f32(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        let labels = Tensor::i32(vec![3], vec![0, 1, 1]);
        assert!((batch_accuracy(&scores, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mse_basics() {
        let a = Tensor::f32(vec![2], vec![1.0, 3.0]);
        let b = Tensor::f32(vec![2], vec![0.0, 1.0]);
        assert!((batch_mse(&a, &b) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn votes_pick_argmax() {
        let logits = Tensor::f32(vec![2, 3], vec![0.1, 2.0, -1.0, 5.0, 0.0, 4.9]);
        let v = one_hot_votes(&logits);
        assert_eq!(v.as_f32(), &[0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulate_regress_means() {
        let mut acc = None;
        accumulate_prediction(&mut acc, Tensor::f32(vec![2], vec![1.0, 4.0]), false);
        accumulate_prediction(&mut acc, Tensor::f32(vec![2], vec![3.0, 0.0]), false);
        let m = finalize_mean(acc, 2, false).unwrap();
        assert_eq!(m.as_f32(), &[2.0, 2.0]);
    }

    #[test]
    fn accumulate_classify_sums_votes() {
        let mut acc = None;
        // two samples vote class 1, one votes class 0
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![0.0, 1.0]), true);
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![0.2, 0.9]), true);
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![2.0, 0.0]), true);
        let votes = finalize_mean(acc, 3, true).unwrap();
        assert_eq!(votes.as_f32(), &[1.0, 2.0], "vote sums, not means");
    }

    #[test]
    fn finalize_empty_is_none() {
        assert!(finalize_mean(None, 0, false).is_none());
        assert!(finalize_mean(Some(Tensor::zeros(vec![1])), 0, true).is_none());
    }

    #[test]
    fn r_hat_near_one_for_mixed_chains_and_large_for_split_ones() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let mixed: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..200).map(|_| rng.normal() as f64).collect())
            .collect();
        let r = split_r_hat(&mixed);
        assert!(r.is_finite() && (r - 1.0).abs() < 0.1, "mixed chains r_hat {r}");
        let e = ess(&mixed);
        assert!(e.is_finite() && e > 100.0, "mixed chains ess {e}");

        // chains stuck in different modes: r_hat must flag divergence
        let split: Vec<Vec<f64>> = (0..4)
            .map(|c| (0..200).map(|_| rng.normal() as f64 + 10.0 * c as f64).collect())
            .collect();
        let r = split_r_hat(&split);
        assert!(r > 1.5, "split chains r_hat {r}");
        assert!(ess(&split) < e, "split chains must lose effective samples");
    }

    #[test]
    fn diagnostics_are_nan_safe() {
        // too few chains / samples -> NaN, rendered n/a
        assert!(split_r_hat(&[vec![1.0, 2.0, 3.0, 4.0]]).is_nan());
        assert!(split_r_hat(&[vec![1.0], vec![2.0]]).is_nan());
        assert!(ess(&[vec![1.0, 2.0]]).is_nan());
        assert_eq!(fmt_diag(f64::NAN), "n/a");
        assert_eq!(fmt_diag(1.25), "1.250");
        // constant identical chains converge trivially
        let flat = vec![vec![2.0; 8], vec![2.0; 8]];
        assert_eq!(split_r_hat(&flat), 1.0);

        let none = chain_diagnostics(&[]);
        assert!(none.r_hat.is_nan() && none.ess.is_nan());
        let short = chain_diagnostics(&[vec![Tensor::zeros(vec![2])], Vec::new()]);
        assert!(short.r_hat.is_nan());
    }

    #[test]
    fn tensor_chain_diagnostics_report_worst_dimension() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        // dim 0 mixes across chains; dim 1 is split by chain -> worst-case
        // r_hat must reflect dim 1
        let chains: Vec<Vec<Tensor>> = (0..3)
            .map(|c| {
                (0..64)
                    .map(|_| {
                        Tensor::f32(
                            vec![2],
                            vec![rng.normal(), rng.normal() + 8.0 * c as f32],
                        )
                    })
                    .collect()
            })
            .collect();
        let diag = chain_diagnostics(&chains);
        assert_eq!(diag.chains, 3);
        assert_eq!(diag.samples_per_chain, 64);
        assert!(diag.r_hat > 1.5, "worst-dim r_hat {}", diag.r_hat);
        assert!(diag.ess.is_finite() && diag.ess > 0.0);
    }

    #[test]
    fn predictive_std_measures_spread() {
        let preds = vec![
            Tensor::f32(vec![2], vec![1.0, 5.0]),
            Tensor::f32(vec![2], vec![3.0, 5.0]),
        ];
        let s = predictive_std(&preds).unwrap();
        assert!((s.as_f32()[0] - 1.0).abs() < 1e-6);
        assert!(s.as_f32()[1].abs() < 1e-6);
        assert!(predictive_std(&[]).is_err());
    }
}
