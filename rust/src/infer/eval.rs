//! Evaluation metrics: classification accuracy (Tables 3/4) and regression
//! MSE, computed over a dataset in artifact-sized batches.

use anyhow::Result;

use crate::data::{DataLoader, Dataset};
use crate::runtime::Tensor;

/// Fraction of rows whose argmax matches the label. `scores` is [B, C]
/// (logits or vote counts — argmax is invariant).
pub fn batch_accuracy(scores: &Tensor, labels: &Tensor) -> f64 {
    assert_eq!(scores.shape.len(), 2);
    let (b, c) = (scores.shape[0], scores.shape[1]);
    assert_eq!(labels.element_count(), b);
    let s = scores.as_f32();
    let l = labels.as_i32();
    let mut correct = 0usize;
    for i in 0..b {
        let row = &s[i * c..(i + 1) * c];
        let mut best = 0;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best as i32 == l[i] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

/// Mean squared error between a prediction and target batch.
pub fn batch_mse(pred: &Tensor, target: &Tensor) -> f64 {
    let p = pred.as_f32();
    let t = target.as_f32();
    assert_eq!(p.len(), t.len());
    p.iter()
        .zip(t)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / p.len() as f64
}

/// Dataset-level accuracy of a predictor `f(x) -> scores` evaluated in
/// fixed-size batches (artifacts are shape-specialized).
pub fn dataset_accuracy(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let batches = loader.epoch();
    let mut acc = 0.0;
    for b in &batches {
        let scores = f(&b.x)?;
        acc += batch_accuracy(&scores, &b.y);
    }
    Ok(acc / batches.len().max(1) as f64)
}

/// Dataset-level MSE of a predictor.
pub fn dataset_mse(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let batches = loader.epoch();
    let mut e = 0.0;
    for b in &batches {
        let pred = f(&b.x)?;
        e += batch_mse(&pred, &b.y);
    }
    Ok(e / batches.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let scores = Tensor::f32(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        let labels = Tensor::i32(vec![3], vec![0, 1, 1]);
        assert!((batch_accuracy(&scores, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mse_basics() {
        let a = Tensor::f32(vec![2], vec![1.0, 3.0]);
        let b = Tensor::f32(vec![2], vec![0.0, 1.0]);
        assert!((batch_mse(&a, &b) - 2.5).abs() < 1e-12);
    }
}
