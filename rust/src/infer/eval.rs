//! Evaluation metrics and posterior-predictive combinators: classification
//! accuracy (Tables 3/4), regression MSE, and the accumulate/finalize pair
//! that multi-SWAG and SGMCMC use to average predictions over posterior
//! samples (sum of one-hot votes for classify, running mean for regress).

use anyhow::Result;

use crate::data::{DataLoader, Dataset};
use crate::runtime::tensor::ops;
use crate::runtime::Tensor;

/// Fraction of rows whose argmax matches the label. `scores` is [B, C]
/// (logits or vote counts — argmax is invariant).
pub fn batch_accuracy(scores: &Tensor, labels: &Tensor) -> f64 {
    assert_eq!(scores.shape.len(), 2);
    let (b, c) = (scores.shape[0], scores.shape[1]);
    assert_eq!(labels.element_count(), b);
    let s = scores.as_f32();
    let l = labels.as_i32();
    let mut correct = 0usize;
    for i in 0..b {
        let row = &s[i * c..(i + 1) * c];
        let mut best = 0;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best as i32 == l[i] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

/// Mean squared error between a prediction and target batch.
pub fn batch_mse(pred: &Tensor, target: &Tensor) -> f64 {
    let p = pred.as_f32();
    let t = target.as_f32();
    assert_eq!(p.len(), t.len());
    p.iter()
        .zip(t)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / p.len() as f64
}

/// One-hot argmax votes of a [B, C] logit tensor (the §C.4 majority-vote
/// protocol's per-sample ballot).
pub fn one_hot_votes(logits: &Tensor) -> Tensor {
    assert_eq!(logits.shape.len(), 2, "votes need [B, C] logits");
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let l = logits.as_f32();
    let mut v = vec![0.0f32; b * c];
    for i in 0..b {
        let row = &l[i * c..(i + 1) * c];
        let mut best = 0;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        v[i * c + best] = 1.0;
    }
    Tensor::f32(vec![b, c], v)
}

/// Fold one posterior-sample prediction into a running accumulator:
/// classify sums one-hot votes, regress sums raw predictions (divide by
/// the count via [`finalize_mean`]). In-place when `acc` is uniquely
/// owned (COW detaches otherwise).
pub fn accumulate_prediction(acc: &mut Option<Tensor>, pred: Tensor, classify: bool) {
    let p = if classify { one_hot_votes(&pred) } else { pred };
    match acc {
        None => *acc = Some(p),
        Some(a) => ops::axpy(a, 1.0, &p),
    }
}

/// Finish an [`accumulate_prediction`] run: vote sums pass through
/// unchanged (argmax-invariant), regression sums become means. None when
/// nothing was accumulated.
pub fn finalize_mean(acc: Option<Tensor>, n: usize, classify: bool) -> Option<Tensor> {
    let mut out = acc?;
    if n == 0 {
        return None;
    }
    if !classify {
        for v in out.as_f32_mut() {
            *v /= n as f32;
        }
    }
    Some(out)
}

/// Per-point standard deviation across a set of predictions — the
/// epistemic-uncertainty readout of a posterior-predictive set.
pub fn predictive_std(preds: &[Tensor]) -> Result<Tensor> {
    let n = preds.len();
    anyhow::ensure!(n > 0, "predictive_std over zero predictions");
    let len = preds[0].element_count();
    let mut out = vec![0.0f32; len];
    for (i, o) in out.iter_mut().enumerate() {
        let m: f64 = preds.iter().map(|p| p.as_f32()[i] as f64).sum::<f64>() / n as f64;
        let v: f64 =
            preds.iter().map(|p| (p.as_f32()[i] as f64 - m).powi(2)).sum::<f64>() / n as f64;
        *o = v.sqrt() as f32;
    }
    Ok(Tensor::f32(preds[0].shape.clone(), out))
}

/// Dataset-level accuracy of a predictor `f(x) -> scores` evaluated in
/// fixed-size batches (artifacts are shape-specialized).
pub fn dataset_accuracy(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let batches = loader.epoch();
    let mut acc = 0.0;
    for b in &batches {
        let scores = f(&b.x)?;
        acc += batch_accuracy(&scores, &b.y);
    }
    Ok(acc / batches.len().max(1) as f64)
}

/// Dataset-level MSE of a predictor.
pub fn dataset_mse(
    data: &Dataset,
    batch_size: usize,
    mut f: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let mut loader = DataLoader::new(data.clone(), batch_size, false, 0);
    let batches = loader.epoch();
    let mut e = 0.0;
    for b in &batches {
        let pred = f(&b.x)?;
        e += batch_mse(&pred, &b.y);
    }
    Ok(e / batches.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let scores = Tensor::f32(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        let labels = Tensor::i32(vec![3], vec![0, 1, 1]);
        assert!((batch_accuracy(&scores, &labels) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mse_basics() {
        let a = Tensor::f32(vec![2], vec![1.0, 3.0]);
        let b = Tensor::f32(vec![2], vec![0.0, 1.0]);
        assert!((batch_mse(&a, &b) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn votes_pick_argmax() {
        let logits = Tensor::f32(vec![2, 3], vec![0.1, 2.0, -1.0, 5.0, 0.0, 4.9]);
        let v = one_hot_votes(&logits);
        assert_eq!(v.as_f32(), &[0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulate_regress_means() {
        let mut acc = None;
        accumulate_prediction(&mut acc, Tensor::f32(vec![2], vec![1.0, 4.0]), false);
        accumulate_prediction(&mut acc, Tensor::f32(vec![2], vec![3.0, 0.0]), false);
        let m = finalize_mean(acc, 2, false).unwrap();
        assert_eq!(m.as_f32(), &[2.0, 2.0]);
    }

    #[test]
    fn accumulate_classify_sums_votes() {
        let mut acc = None;
        // two samples vote class 1, one votes class 0
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![0.0, 1.0]), true);
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![0.2, 0.9]), true);
        accumulate_prediction(&mut acc, Tensor::f32(vec![1, 2], vec![2.0, 0.0]), true);
        let votes = finalize_mean(acc, 3, true).unwrap();
        assert_eq!(votes.as_f32(), &[1.0, 2.0], "vote sums, not means");
    }

    #[test]
    fn finalize_empty_is_none() {
        assert!(finalize_mean(None, 0, false).is_none());
        assert!(finalize_mean(Some(Tensor::zeros(vec![1])), 0, true).is_none());
    }

    #[test]
    fn predictive_std_measures_spread() {
        let preds = vec![
            Tensor::f32(vec![2], vec![1.0, 5.0]),
            Tensor::f32(vec![2], vec![3.0, 5.0]),
        ];
        let s = predictive_std(&preds).unwrap();
        assert!((s.as_f32()[0] - 1.0).abs() < 1e-6);
        assert!(s.as_f32()[1].abs() < 1e-6);
        assert!(predictive_std(&[]).is_err());
    }
}
