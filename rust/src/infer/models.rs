//! Native model zoo: closed-form forwards and backprops that run with no
//! PJRT, no artifacts, and no Python — the hermetic counterpart of
//! `python/compile/models/`.
//!
//! Two architectures beyond `linear_native`:
//!
//! * **MLP** ([`MlpSpec`]): depth/width-configurable, ReLU or tanh, with
//!   the fused affine+activation layer of
//!   `python/compile/kernels/fused_linear.py` — each layer computes
//!   `act(x @ W + b)` in one pass over the output row, and the backward
//!   pass consumes the cached POST-activation outputs (ReLU' = [a > 0],
//!   tanh' = 1 − a²), so no pre-activation buffer is ever materialized.
//! * **1-D conv net** ([`Conv1dSpec`]): valid convolution (stride 1) →
//!   fused activation → mean-pool per channel → linear head. Small enough
//!   to backprop in closed form, nonlinear enough to learn signal-energy
//!   tasks a linear model cannot.
//!
//! Both speak the [`ModelSource::Native`] contract of `sgmcmc.rs`:
//! `grad(params, x, y) → (loss, flat gradient)` and
//! `forward(params, x) → prediction`. The LOSS is part of the model and is
//! keyed by the label dtype: i32 labels mean softmax cross-entropy (mean
//! over the batch, predictions are logits `[B, C]`); f32 targets mean MSE
//! (mean over all `B·O` elements) — the convention `linear_native`
//! established for `O = 1`.
//!
//! **Wire-name registry invariant.** A `ModelSource` crosses the PD wire
//! as a NAME only; the receiving node rebuilds the closures through
//! `model_source_by_name`. A registered name therefore denotes one FIXED
//! architecture (`MLP_NATIVE`, `CONV1D_NATIVE`, `LINEAR_SPIRAL`) — two
//! nodes resolving the same name MUST build bit-identical math, or
//! placement invariance dies silently. Arbitrary [`MlpSpec`] /
//! [`Conv1dSpec`] configs are still constructible ([`mlp_model`],
//! [`conv1d_model`]) but carry the empty name and are rejected at the
//! wire seam (in-process only — the gradcheck property tests use these).

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

use crate::infer::sgmcmc::{
    linear_native_manifest, linear_native_model, ModelSource, NativeForwardFn, NativeGradFn,
};
use crate::nel::ParticleCtx;
use crate::particle::{PushError, Value};
use crate::runtime::kernels;
use crate::runtime::{DType, Manifest, ModelSpec, Tensor};
use crate::util::rng::Rng;

/// Salt folded into every per-(seed, particle) init stream. The exact
/// value `linear_native` has always used (`rust/src/main.rs` since PR 2) —
/// changing it would silently re-seed every pinned trajectory.
pub const INIT_SALT: u64 = 0x1217;

/// `linear_native`'s canonical dimensions (moved here from `main.rs` so
/// every consumer shares one definition).
pub const LINEAR_D: usize = 8;
pub const LINEAR_BATCH: usize = 16;

// ---- activations ---------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
}

impl Activation {
    pub fn name(&self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
        }
    }

    #[inline]
    pub fn apply(&self, z: f32) -> f32 {
        match self {
            Activation::Relu => z.max(0.0),
            Activation::Tanh => z.tanh(),
        }
    }

    /// Derivative as a function of the ACTIVATED output `a = act(z)` —
    /// the property that lets backprop run off the post-activation cache.
    #[inline]
    pub fn grad_from_output(&self, a: f32) -> f32 {
        match self {
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => 1.0 - a * a,
        }
    }
}

// ---- the shared loss head ------------------------------------------------

/// Loss and dL/dpred for a `[b, o]` prediction block, keyed by `y`'s
/// dtype: i32 labels → softmax cross-entropy (mean over batch, numerically
/// stabilized by the row max); f32 targets → MSE (mean over `b·o`).
fn loss_and_delta(
    pred: &[f32],
    b: usize,
    o: usize,
    y: &Tensor,
) -> Result<(f32, Vec<f32>), PushError> {
    let mut delta = vec![0.0f32; b * o];
    let mut loss = 0.0f32;
    match y.dtype() {
        DType::I32 => {
            if y.element_count() != b {
                return Err(PushError::new(format!(
                    "classify loss: {b} rows but {} labels",
                    y.element_count()
                )));
            }
            let labels = y.as_i32();
            let inv_b = 1.0 / b as f32;
            for i in 0..b {
                let row = &pred[i * o..(i + 1) * o];
                let label = labels[i];
                if label < 0 || label as usize >= o {
                    return Err(PushError::new(format!(
                        "classify loss: label {label} outside 0..{o}"
                    )));
                }
                // softmax through the kernel plane: the row lands in the
                // delta buffer, is normalized in place, then scaled to the
                // batch-mean gradient
                let drow = &mut delta[i * o..(i + 1) * o];
                drow.copy_from_slice(row);
                let (max, z) = kernels::softmax(drow);
                loss += z.ln() + max - row[label as usize];
                kernels::scale(drow, inv_b);
                drow[label as usize] -= inv_b;
            }
            loss /= b as f32;
        }
        DType::F32 => {
            if y.element_count() != b * o {
                return Err(PushError::new(format!(
                    "regress loss: pred [{b}, {o}] vs y {:?}",
                    y.shape
                )));
            }
            let ys = y.as_f32();
            let inv = 1.0 / (b * o) as f32;
            for ((d, &p), &t) in delta.iter_mut().zip(pred).zip(ys) {
                let err = p - t;
                loss += err * err;
                *d = 2.0 * err * inv;
            }
            loss *= inv;
        }
        other => {
            return Err(PushError::new(format!(
                "native loss: unsupported target dtype {other:?}"
            )))
        }
    }
    Ok((loss, delta))
}

// ---- MLP -----------------------------------------------------------------

/// A depth/width-configurable MLP. `depth` counts HIDDEN layers: depth 0
/// is a single affine map (the "linear control" of the spiral gate),
/// depth d stacks d fused `act(x @ W + b)` layers before the affine head.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpSpec {
    pub in_dim: usize,
    pub hidden: usize,
    pub depth: usize,
    pub out_dim: usize,
    pub activation: Activation,
}

impl MlpSpec {
    /// Layer widths `[in] + [hidden] * depth + [out]` (the layout
    /// `python/compile/models/mlp.py` uses).
    pub fn dims(&self) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.depth + 2);
        d.push(self.in_dim);
        d.resize(self.depth + 1, self.hidden);
        d.push(self.out_dim);
        d
    }

    /// Flat parameter count: per layer a row-major `[da, db]` weight block
    /// followed by a `[db]` bias block.
    pub fn param_count(&self) -> usize {
        self.dims().windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Smallest |pre-activation| over every HIDDEN unit of the batch —
    /// the finite-difference gradcheck uses this to certify that no ReLU
    /// kink lies within the probe step (see `tests/properties.rs`).
    pub fn min_abs_preactivation(&self, params: &Tensor, x: &Tensor) -> Result<f32, PushError> {
        let b = self.check_shapes(params, x)?;
        let (_, margin) = mlp_forward_acts(self, params.as_f32(), x.as_f32(), b);
        Ok(margin)
    }

    fn check_shapes(&self, params: &Tensor, x: &Tensor) -> Result<usize, PushError> {
        let b = x.shape.first().copied().unwrap_or(0);
        if b == 0 || x.element_count() != b * self.in_dim {
            return Err(PushError::new(format!(
                "mlp: x {:?} incompatible with in_dim {}",
                x.shape, self.in_dim
            )));
        }
        if params.element_count() != self.param_count() {
            return Err(PushError::new(format!(
                "mlp: {} params given, spec {:?} needs {}",
                params.element_count(),
                self,
                self.param_count()
            )));
        }
        Ok(b)
    }
}

/// Fused forward: returns every layer's POST-activation output
/// (`acts[0]` is the input copy, `acts[L]` the affine network output) plus
/// the smallest |pre-activation| seen on any hidden unit.
fn mlp_forward_acts(spec: &MlpSpec, params: &[f32], x: &[f32], b: usize) -> (Vec<Vec<f32>>, f32) {
    let dims = spec.dims();
    let n_layers = dims.len() - 1;
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
    acts.push(x.to_vec());
    let mut margin = f32::INFINITY;
    let mut off = 0usize;
    for l in 0..n_layers {
        let (da, db) = (dims[l], dims[l + 1]);
        let w = &params[off..off + da * db];
        let bias = &params[off + da * db..off + da * db + db];
        off += da * db + db;
        let last = l + 1 == n_layers;
        let out = {
            let prev = &acts[l];
            let mut out = vec![0.0f32; b * db];
            for i in 0..b {
                let row = &prev[i * da..(i + 1) * da];
                let orow = &mut out[i * db..(i + 1) * db];
                orow.copy_from_slice(bias);
                kernels::gemv_scatter(orow, row, w);
                if !last {
                    // fused affine + activation: the pre-activation never
                    // leaves this row buffer
                    let m = kernels::act_margin(orow, |v| spec.activation.apply(v));
                    margin = margin.min(m);
                }
            }
            out
        };
        acts.push(out);
    }
    (acts, margin)
}

/// Closed-form backprop: `delta` starts as dL/dpred from the loss head and
/// walks the layers in reverse; layer `l`'s weight gradient is
/// `a_{l}ᵀ delta` and the incoming delta is `(delta Wᵀ) ⊙ act'(a_l)`.
fn mlp_loss_grad(
    spec: &MlpSpec,
    params: &Tensor,
    x: &Tensor,
    y: &Tensor,
) -> Result<(f32, Tensor), PushError> {
    let b = spec.check_shapes(params, x)?;
    let p = params.as_f32();
    let dims = spec.dims();
    let n_layers = dims.len() - 1;
    let (acts, _) = mlp_forward_acts(spec, p, x.as_f32(), b);
    let (loss, mut delta) = loss_and_delta(&acts[n_layers], b, spec.out_dim, y)?;

    let mut offsets = Vec::with_capacity(n_layers);
    let mut off = 0usize;
    for w in dims.windows(2) {
        offsets.push(off);
        off += w[0] * w[1] + w[1];
    }
    let mut g = vec![0.0f32; spec.param_count()];
    for l in (0..n_layers).rev() {
        let (da, db) = (dims[l], dims[l + 1]);
        let a_prev = &acts[l];
        {
            let layer = &mut g[offsets[l]..offsets[l] + da * db + db];
            let (gw, gb) = layer.split_at_mut(da * db);
            for i in 0..b {
                let drow = &delta[i * db..(i + 1) * db];
                let arow = &a_prev[i * da..(i + 1) * da];
                // outer-product accumulate: row k of the weight grad gains
                // a_k · delta (axpy per input unit)
                for (k, &ak) in arow.iter().enumerate() {
                    kernels::axpy(&mut gw[k * db..(k + 1) * db], ak, drow);
                }
                kernels::axpy(gb, 1.0, drow);
            }
        }
        if l > 0 {
            let w = &p[offsets[l]..offsets[l] + da * db];
            let mut dprev = vec![0.0f32; b * da];
            for i in 0..b {
                let drow = &delta[i * db..(i + 1) * db];
                let arow = &a_prev[i * da..(i + 1) * da];
                let dp = &mut dprev[i * da..(i + 1) * da];
                for (k, dk) in dp.iter_mut().enumerate() {
                    let wrow = &w[k * db..(k + 1) * db];
                    let s = kernels::dot(wrow, drow);
                    *dk = s * spec.activation.grad_from_output(arow[k]);
                }
            }
            delta = dprev;
        }
    }
    Ok((loss, Tensor::f32(vec![g.len()], g)))
}

fn mlp_forward(spec: &MlpSpec, params: &Tensor, x: &Tensor) -> Result<Tensor, PushError> {
    let b = spec.check_shapes(params, x)?;
    let (mut acts, _) = mlp_forward_acts(spec, params.as_f32(), x.as_f32(), b);
    let out = acts.pop().expect("forward always yields an output layer");
    Ok(Tensor::f32(vec![b, spec.out_dim], out))
}

/// An MLP source under an explicit wire name. Registered names must map to
/// one fixed spec (see the registry invariant in the module docs); use
/// [`mlp_model`] for anonymous in-process sources.
pub fn mlp_model_named(name: &'static str, spec: MlpSpec) -> ModelSource {
    let grad: NativeGradFn = Arc::new(move |p, x, y| mlp_loss_grad(&spec, p, x, y));
    let forward: NativeForwardFn = Arc::new(move |p, x| mlp_forward(&spec, p, x));
    ModelSource::Native { name, grad, forward }
}

/// An anonymous (in-process only) MLP source for an arbitrary spec.
pub fn mlp_model(spec: MlpSpec) -> ModelSource {
    mlp_model_named("", spec)
}

// ---- 1-D conv net --------------------------------------------------------

/// Valid 1-D convolution (stride 1) → fused activation → mean-pool per
/// channel → affine head. Parameters, flat:
/// `[w_conv (C·K)] [b_conv (C)] [w_head (C·O)] [b_head (O)]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv1dSpec {
    pub nx: usize,
    pub channels: usize,
    pub kernel: usize,
    pub out_dim: usize,
    pub activation: Activation,
}

impl Conv1dSpec {
    /// Output positions of the valid convolution.
    pub fn positions(&self) -> usize {
        self.nx + 1 - self.kernel
    }

    pub fn param_count(&self) -> usize {
        self.channels * self.kernel + self.channels + self.channels * self.out_dim + self.out_dim
    }

    /// Smallest |pre-activation| over the conv units of the batch (the
    /// gradcheck margin twin of [`MlpSpec::min_abs_preactivation`]).
    pub fn min_abs_preactivation(&self, params: &Tensor, x: &Tensor) -> Result<f32, PushError> {
        let b = self.check_shapes(params, x)?;
        let fwd = conv_forward_full(self, params.as_f32(), x.as_f32(), b);
        Ok(fwd.margin)
    }

    fn check_shapes(&self, params: &Tensor, x: &Tensor) -> Result<usize, PushError> {
        if self.kernel == 0 || self.kernel > self.nx {
            return Err(PushError::new(format!(
                "conv1d: kernel {} does not fit nx {}",
                self.kernel, self.nx
            )));
        }
        let b = x.shape.first().copied().unwrap_or(0);
        if b == 0 || x.element_count() != b * self.nx {
            return Err(PushError::new(format!(
                "conv1d: x {:?} incompatible with nx {}",
                x.shape, self.nx
            )));
        }
        if params.element_count() != self.param_count() {
            return Err(PushError::new(format!(
                "conv1d: {} params given, spec {:?} needs {}",
                params.element_count(),
                self,
                self.param_count()
            )));
        }
        Ok(b)
    }
}

struct ConvForward {
    /// Network output, `[b, out_dim]` flattened.
    out: Vec<f32>,
    /// Post-activation conv maps, `[b, C, P]` flattened.
    act: Vec<f32>,
    /// Mean-pooled channels, `[b, C]` flattened.
    pooled: Vec<f32>,
    /// Smallest |pre-activation| over every conv unit.
    margin: f32,
}

fn conv_forward_full(spec: &Conv1dSpec, p: &[f32], x: &[f32], b: usize) -> ConvForward {
    let (c, k, o, nx) = (spec.channels, spec.kernel, spec.out_dim, spec.nx);
    let np = spec.positions();
    let w_conv = &p[..c * k];
    let b_conv = &p[c * k..c * k + c];
    let w_head = &p[c * k + c..c * k + c + c * o];
    let b_head = &p[c * k + c + c * o..];
    let mut act = vec![0.0f32; b * c * np];
    let mut pooled = vec![0.0f32; b * c];
    let mut out = vec![0.0f32; b * o];
    let mut margin = f32::INFINITY;
    let inv_np = 1.0 / np as f32;
    for i in 0..b {
        let sig = &x[i * nx..(i + 1) * nx];
        for ch in 0..c {
            let kern = &w_conv[ch * k..(ch + 1) * k];
            let amap = &mut act[(i * c + ch) * np..(i * c + ch + 1) * np];
            // conv at each position: bias + tap dot through the kernel
            // plane, then one fused activation pass over the channel map
            for (pos, a) in amap.iter_mut().enumerate() {
                *a = b_conv[ch] + kernels::dot(kern, &sig[pos..pos + k]);
            }
            let m = kernels::act_margin(amap, |v| spec.activation.apply(v));
            margin = margin.min(m);
            pooled[i * c + ch] = kernels::sum(amap) * inv_np;
        }
        let orow = &mut out[i * o..(i + 1) * o];
        orow.copy_from_slice(b_head);
        kernels::gemv_scatter(orow, &pooled[i * c..(i + 1) * c], w_head);
    }
    ConvForward { out, act, pooled, margin }
}

fn conv_loss_grad(
    spec: &Conv1dSpec,
    params: &Tensor,
    x: &Tensor,
    y: &Tensor,
) -> Result<(f32, Tensor), PushError> {
    let b = spec.check_shapes(params, x)?;
    let p = params.as_f32();
    let xs = x.as_f32();
    let (c, k, o, nx) = (spec.channels, spec.kernel, spec.out_dim, spec.nx);
    let np = spec.positions();
    let fwd = conv_forward_full(spec, p, xs, b);
    let (loss, delta) = loss_and_delta(&fwd.out, b, o, y)?;

    let w_head = &p[c * k + c..c * k + c + c * o];
    let mut g = vec![0.0f32; spec.param_count()];
    let inv_np = 1.0 / np as f32;
    for i in 0..b {
        let drow = &delta[i * o..(i + 1) * o];
        let sig = &xs[i * nx..(i + 1) * nx];
        for ch in 0..c {
            // head gradient and the pooled delta for this channel
            let pv = fwd.pooled[i * c + ch];
            let wrow = &w_head[ch * o..(ch + 1) * o];
            kernels::axpy(&mut g[c * k + c + ch * o..c * k + c + (ch + 1) * o], pv, drow);
            let dpool = kernels::dot(drow, wrow);
            // mean-pool spreads the delta uniformly over positions
            let df = dpool * inv_np;
            let amap = &fwd.act[(i * c + ch) * np..(i * c + ch + 1) * np];
            for (pos, &a) in amap.iter().enumerate() {
                let dz = df * spec.activation.grad_from_output(a);
                g[c * k + ch] += dz;
                kernels::axpy(&mut g[ch * k..(ch + 1) * k], dz, &sig[pos..pos + k]);
            }
        }
        kernels::axpy(&mut g[c * k + c + c * o..], 1.0, drow);
    }
    Ok((loss, Tensor::f32(vec![g.len()], g)))
}

fn conv_forward(spec: &Conv1dSpec, params: &Tensor, x: &Tensor) -> Result<Tensor, PushError> {
    let b = spec.check_shapes(params, x)?;
    let fwd = conv_forward_full(spec, params.as_f32(), x.as_f32(), b);
    Ok(Tensor::f32(vec![b, spec.out_dim], fwd.out))
}

/// A conv source under an explicit wire name (see the registry invariant).
pub fn conv1d_model_named(name: &'static str, spec: Conv1dSpec) -> ModelSource {
    let grad: NativeGradFn = Arc::new(move |p, x, y| conv_loss_grad(&spec, p, x, y));
    let forward: NativeForwardFn = Arc::new(move |p, x| conv_forward(&spec, p, x));
    ModelSource::Native { name, grad, forward }
}

/// An anonymous (in-process only) conv source for an arbitrary spec.
pub fn conv1d_model(spec: Conv1dSpec) -> ModelSource {
    conv1d_model_named("", spec)
}

// ---- the native optimizer step -------------------------------------------

/// One plain SGD step through a native grad closure: θ ← θ − lr·∇U; the
/// minibatch loss comes back for the STEP protocol's scalar-tensor reply.
/// Shared by the native DeepEnsemble and MultiSwag handlers (the native
/// families always take plain SGD steps — there is no native Adam).
pub fn native_sgd_step(
    ctx: &ParticleCtx,
    grad: &NativeGradFn,
    x: &Tensor,
    y: &Tensor,
    lr: f32,
) -> Result<f32, PushError> {
    let params = ctx.own_params().wait()?.tensor()?;
    let (loss, mut u) = grad(&params, x, y)?;
    // Release the snapshot BEFORE the apply so axpy_params mutates the
    // resident parameters in place instead of COW-detaching.
    drop(params);
    kernels::scale(u.as_f32_mut(), -lr);
    ctx.axpy_params(1.0, u).wait()?;
    Ok(loss)
}

/// Fold a fan-out of per-particle PREDICT replies into the family vote:
/// summed one-hot class votes (classify — ready for `argmax` accuracy) or
/// the mean prediction (regress). The caller must drop the reply futures
/// first so the first tensor is uniquely owned and the axpy chain runs in
/// place. Shared by every native `predict_mean` (ensemble, SWAG, SVGD) —
/// the same vote protocol the MCMC reservoir uses.
pub fn fold_predictions(preds: Vec<Value>, classify: bool) -> anyhow::Result<Tensor> {
    let n = preds.len();
    let mut acc: Option<Tensor> = None;
    for p in preds {
        let t = p.tensor().map_err(|e| anyhow::anyhow!("{e}"))?;
        match &mut acc {
            None => acc = Some(t),
            Some(a) => crate::runtime::tensor::ops::axpy(a, 1.0, &t),
        }
    }
    let mut out = acc.ok_or_else(|| anyhow::anyhow!("predict over zero particles"))?;
    if !classify {
        kernels::div_scale(out.as_f32_mut(), n as f32);
    }
    Ok(out)
}

// ---- the registry --------------------------------------------------------

/// The fixed architecture behind the wire name `mlp_native`: a 2→16→16→2
/// ReLU classifier sized for the two-class spiral task.
pub const MLP_NATIVE: MlpSpec =
    MlpSpec { in_dim: 2, hidden: 16, depth: 2, out_dim: 2, activation: Activation::Relu };

/// The fixed architecture behind `linear_spiral_native`: the depth-0
/// (single affine map) softmax classifier on the same spiral inputs — the
/// linear CONTROL of the CI accuracy gate. A linear decision boundary
/// provably cannot separate interleaved spiral arms, so this model's
/// accuracy bounds what any linear method can do on the task.
pub const LINEAR_SPIRAL: MlpSpec =
    MlpSpec { in_dim: 2, hidden: 0, depth: 0, out_dim: 2, activation: Activation::Relu };

/// The fixed architecture behind `conv1d_native`: 6 channels of kernel-5
/// valid conv over 32 samples, ReLU, mean-pool, affine head — sized for
/// the `wave_energy` regression (ReLU pairs can represent |u|, which a
/// purely linear map cannot).
pub const CONV1D_NATIVE: Conv1dSpec =
    Conv1dSpec { nx: 32, channels: 6, kernel: 5, out_dim: 1, activation: Activation::Relu };

const MLP_NATIVE_BATCH: usize = 32;
const SPIRAL_BATCH: usize = 32;
const CONV1D_BATCH: usize = 16;

pub fn mlp_native_model() -> ModelSource {
    mlp_model_named("mlp_native", MLP_NATIVE)
}

pub fn linear_spiral_model() -> ModelSource {
    mlp_model_named("linear_spiral_native", LINEAR_SPIRAL)
}

pub fn conv1d_native_model() -> ModelSource {
    conv1d_model_named("conv1d_native", CONV1D_NATIVE)
}

/// One registered native model: wire name, closed-form source, the
/// shape/task contract the data plane and serving tier read, and the
/// deterministic per-(seed, particle) initializer that makes creation
/// hermetic (no AOT `init` artifact).
#[derive(Clone)]
pub struct NativeModel {
    pub name: &'static str,
    pub source: ModelSource,
    pub spec: ModelSpec,
    init: Arc<dyn Fn(u64, usize) -> Tensor + Send + Sync>,
}

impl fmt::Debug for NativeModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("NativeModel")
            .field("name", &self.name)
            .field("spec", &self.spec.name)
            .finish()
    }
}

impl NativeModel {
    /// Initial parameters for particle `i` under `seed`.
    pub fn init_params(&self, seed: u64, i: usize) -> Tensor {
        (self.init)(seed, i)
    }

    /// The initializer curried over a run seed — the exact closure shape
    /// `SgmcmcConfig::init` and the native family constructors take.
    pub fn seeded_init(&self, seed: u64) -> Arc<dyn Fn(usize) -> Tensor + Send + Sync> {
        let f = self.init.clone();
        Arc::new(move |i| f(seed, i))
    }
}

/// Every registered native model name, in CLI-listing order.
pub const NATIVE_MODEL_NAMES: [&str; 4] =
    ["linear_native", "mlp_native", "conv1d_native", "linear_spiral_native"];

fn mlp_spec_for(name: &str, spec: MlpSpec, batch: usize, task: &str, arch: &str) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        param_count: spec.param_count(),
        task: task.to_string(),
        x_shape: vec![batch, spec.in_dim],
        y_shape: if task == "classify" { vec![batch] } else { vec![batch, spec.out_dim] },
        y_dtype: if task == "classify" { DType::I32 } else { DType::F32 },
        arch: arch.to_string(),
        meta: BTreeMap::new(),
        entries: BTreeMap::new(),
    }
}

/// Per-layer scaled Gaussian weights (std = 1/√fan_in), zero biases, all
/// from the one `(seed ^ INIT_SALT, particle)` stream.
fn mlp_init(spec: MlpSpec, seed: u64, i: usize) -> Tensor {
    let mut rng = Rng::new(seed ^ INIT_SALT).fold_in(i as u64);
    let mut p = Vec::with_capacity(spec.param_count());
    for w in spec.dims().windows(2) {
        let (da, db) = (w[0], w[1]);
        let std = (1.0 / da as f32).sqrt();
        for _ in 0..da * db {
            p.push(std * rng.normal());
        }
        p.resize(p.len() + db, 0.0);
    }
    Tensor::f32(vec![p.len()], p)
}

fn conv1d_init(spec: Conv1dSpec, seed: u64, i: usize) -> Tensor {
    let mut rng = Rng::new(seed ^ INIT_SALT).fold_in(i as u64);
    let mut p = Vec::with_capacity(spec.param_count());
    let conv_std = (1.0 / spec.kernel as f32).sqrt();
    for _ in 0..spec.channels * spec.kernel {
        p.push(conv_std * rng.normal());
    }
    p.resize(p.len() + spec.channels, 0.0);
    let head_std = (1.0 / spec.channels as f32).sqrt();
    for _ in 0..spec.channels * spec.out_dim {
        p.push(head_std * rng.normal());
    }
    p.resize(p.len() + spec.out_dim, 0.0);
    Tensor::f32(vec![p.len()], p)
}

/// Look a registered native model up by its wire/CLI name.
pub fn native_model(name: &str) -> Option<NativeModel> {
    match name {
        "linear_native" => Some(NativeModel {
            name: "linear_native",
            source: linear_native_model(),
            spec: linear_native_manifest(LINEAR_D, LINEAR_BATCH)
                .model("linear_native")
                .expect("seed manifest always carries linear_native")
                .clone(),
            // the exact stream `main.rs` has always used for linear chains
            init: Arc::new(|seed, i| {
                Tensor::f32(
                    vec![LINEAR_D],
                    Rng::new(seed ^ INIT_SALT).fold_in(i as u64).normal_vec(LINEAR_D),
                )
            }),
        }),
        "mlp_native" => Some(NativeModel {
            name: "mlp_native",
            source: mlp_native_model(),
            spec: mlp_spec_for("mlp_native", MLP_NATIVE, MLP_NATIVE_BATCH, "classify", "spiral"),
            init: Arc::new(|seed, i| mlp_init(MLP_NATIVE, seed, i)),
        }),
        "conv1d_native" => Some(NativeModel {
            name: "conv1d_native",
            source: conv1d_native_model(),
            spec: ModelSpec {
                name: "conv1d_native".to_string(),
                param_count: CONV1D_NATIVE.param_count(),
                task: "regress".to_string(),
                x_shape: vec![CONV1D_BATCH, CONV1D_NATIVE.nx],
                y_shape: vec![CONV1D_BATCH, CONV1D_NATIVE.out_dim],
                y_dtype: DType::F32,
                arch: "wave1d".to_string(),
                meta: BTreeMap::new(),
                entries: BTreeMap::new(),
            },
            init: Arc::new(|seed, i| conv1d_init(CONV1D_NATIVE, seed, i)),
        }),
        "linear_spiral_native" => Some(NativeModel {
            name: "linear_spiral_native",
            source: linear_spiral_model(),
            spec: mlp_spec_for(
                "linear_spiral_native",
                LINEAR_SPIRAL,
                SPIRAL_BATCH,
                "classify",
                "spiral",
            ),
            init: Arc::new(|seed, i| mlp_init(LINEAR_SPIRAL, seed, i)),
        }),
        _ => None,
    }
}

/// A manifest holding EVERY registered native model spec — the hermetic
/// stand-in for `artifacts/manifest.json` wherever a native model name is
/// given (`push train/serve/bench`, node workers, examples).
pub fn native_manifest() -> Manifest {
    let mut models = BTreeMap::new();
    for name in NATIVE_MODEL_NAMES {
        let nm = native_model(name).expect("registry names resolve");
        models.insert(name.to_string(), nm.spec);
    }
    Manifest { dir: PathBuf::from("."), models, svgd: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_dims_and_param_count() {
        let spec = MLP_NATIVE;
        assert_eq!(spec.dims(), vec![2, 16, 16, 2]);
        assert_eq!(spec.param_count(), 2 * 16 + 16 + 16 * 16 + 16 + 16 * 2 + 2);
        // depth 0 is a single affine map
        assert_eq!(LINEAR_SPIRAL.dims(), vec![2, 2]);
        assert_eq!(LINEAR_SPIRAL.param_count(), 6);
        assert_eq!(CONV1D_NATIVE.param_count(), 6 * 5 + 6 + 6 + 1);
        assert_eq!(CONV1D_NATIVE.positions(), 28);
    }

    #[test]
    fn registry_resolves_every_name_consistently() {
        for name in NATIVE_MODEL_NAMES {
            let nm = native_model(name).unwrap();
            assert_eq!(nm.name, name);
            assert_eq!(nm.spec.name, name);
            assert_eq!(nm.spec.param_count, nm.init_params(7, 0).element_count());
            // init is deterministic in (seed, particle) and differs across
            // particles
            assert_eq!(nm.init_params(7, 3), nm.init_params(7, 3));
            assert_ne!(nm.init_params(7, 0), nm.init_params(7, 1));
        }
        assert!(native_model("resnet_native").is_none());
        let m = native_manifest();
        assert_eq!(m.models.len(), NATIVE_MODEL_NAMES.len());
    }

    #[test]
    fn linear_native_init_stream_is_preserved() {
        // the pinned stream every trajectory test and CI smoke depends on
        let nm = native_model("linear_native").unwrap();
        let want =
            Tensor::f32(vec![LINEAR_D], Rng::new(42 ^ 0x1217).fold_in(5).normal_vec(LINEAR_D));
        assert_eq!(nm.init_params(42, 5), want);
    }

    #[test]
    fn mlp_forward_shapes_and_loss_heads() {
        let nm = native_model("mlp_native").unwrap();
        let ModelSource::Native { grad, forward, .. } = nm.source else {
            panic!("native")
        };
        let params = nm.init_params(3, 0);
        let b = 5;
        let x = Tensor::f32(vec![b, 2], Rng::new(9).normal_vec(b * 2));
        let pred = forward(&params, &x).unwrap();
        assert_eq!(pred.shape, vec![b, 2]);
        // classify labels: finite CE loss, gradient matches param count
        let y = Tensor::i32(vec![b], vec![0, 1, 1, 0, 1]);
        let (loss, g) = grad(&params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(g.element_count(), MLP_NATIVE.param_count());
        // out-of-range labels are a model error, not UB
        let bad = Tensor::i32(vec![b], vec![0, 1, 2, 0, 1]);
        assert!(grad(&params, &x, &bad).is_err());
        // shape mismatches error cleanly
        let wide = Tensor::f32(vec![b, 3], vec![0.0; b * 3]);
        assert!(forward(&params, &wide).is_err());
    }

    #[test]
    fn uniform_logits_give_ln_c_loss() {
        // depth-0 map with zero params: logits all 0 → CE = ln 2 and the
        // per-row delta sums to zero (softmax minus one-hot property)
        let spec = LINEAR_SPIRAL;
        let params = Tensor::zeros(vec![spec.param_count()]);
        let x = Tensor::f32(vec![4, 2], Rng::new(1).normal_vec(8));
        let y = Tensor::i32(vec![4], vec![0, 1, 0, 1]);
        let (loss, _) = mlp_loss_grad(&spec, &params, &x, &y).unwrap();
        assert!((loss - (2.0f32).ln()).abs() < 1e-6, "uniform CE is ln 2, got {loss}");
    }

    #[test]
    fn conv_forward_shapes_and_regress_loss() {
        let nm = native_model("conv1d_native").unwrap();
        let ModelSource::Native { grad, forward, .. } = nm.source else {
            panic!("native")
        };
        let params = nm.init_params(11, 2);
        let b = 3;
        let x = Tensor::f32(vec![b, 32], Rng::new(4).normal_vec(b * 32));
        let pred = forward(&params, &x).unwrap();
        assert_eq!(pred.shape, vec![b, 1]);
        let y = Tensor::f32(vec![b, 1], vec![0.5, 0.1, 0.9]);
        let (loss, g) = grad(&params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss >= 0.0);
        assert_eq!(g.element_count(), CONV1D_NATIVE.param_count());
    }

    #[test]
    fn activation_derivatives_come_from_outputs() {
        assert_eq!(Activation::Relu.apply(-2.0), 0.0);
        assert_eq!(Activation::Relu.grad_from_output(0.0), 0.0);
        assert_eq!(Activation::Relu.grad_from_output(1.5), 1.0);
        let a = Activation::Tanh.apply(0.7);
        assert!((Activation::Tanh.grad_from_output(a) - (1.0 - a * a)).abs() < 1e-7);
    }

    #[test]
    fn preactivation_margin_reports_kink_distance() {
        // a single positive weight and bias pushes every ReLU unit well
        // away from its kink; the margin must see that
        let spec =
            MlpSpec { in_dim: 1, hidden: 2, depth: 1, out_dim: 1, activation: Activation::Relu };
        let params = Tensor::f32(vec![spec.param_count()], vec![1.0, 1.0, 5.0, 5.0, 1.0, 1.0, 0.0]);
        let x = Tensor::f32(vec![1, 1], vec![0.5]);
        let margin = spec.min_abs_preactivation(&params, &x).unwrap();
        assert!((margin - 5.5).abs() < 1e-6, "margin {margin}");
    }
}
