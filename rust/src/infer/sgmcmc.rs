//! Stochastic-gradient MCMC on particles: SGLD (Welling & Teh 2011) and
//! SGHMC (Chen et al. 2014), with the cyclical step-size schedule of
//! cSG-MCMC (Zhang et al. 2020).
//!
//! This is the sampling end of the paper's algorithm spectrum (§3.4 calls
//! the particle abstraction out as expressing "a variety of parameter
//! updates, including common BDL algorithms"): every particle runs an
//! independent chain — one MCMC trajectory per particle, no cross-particle
//! communication — so the encoding is ensemble-shaped (broadcast fan-out +
//! join_all barrier per batch) while the per-particle state is richer:
//!
//! * **Chain clock** (`sgmcmc_t`): the step count driving the schedule.
//! * **Momentum** (`sgmcmc_mom`, SGHMC only): carried in particle-local
//!   state exactly like `run_adam` carries its moments.
//! * **Posterior-sample reservoir** (`sgmcmc_samples` / `sgmcmc_seen`):
//!   a bounded, uniformly-subsampled set of post-burn-in parameter
//!   snapshots (Vitter's Algorithm R over the thinned chain). Snapshots
//!   are zero-copy `Tensor` Arc clones of the resident parameters; the
//!   next update COW-detaches, so captured samples are immutable for free
//!   (DESIGN.md §SGMCMC chain state).
//!
//! Updates (U = minibatch loss, optionally + the Gaussian prior's score
//! term θ/σ², mirroring SVGD's Appendix-B.1 treatment; T = temperature):
//!
//! ```text
//! SGLD:   θ ← θ − ε ∇U(θ) + N(0, 2 ε T)
//! SGHMC:  v ← (1−α) v − ε ∇U(θ) + N(0, 2 α T ε);   θ ← θ + v
//! ```
//!
//! With `temperature = 0` no noise is drawn at all, so SGLD is *exactly*
//! SGD and SGHMC is heavy-ball momentum SGD — the deterministic-seed
//! equivalence the hermetic tests pin down.
//!
//! Gradients come from the model's AOT `grad` artifact by default; a
//! [`ModelSource::Native`] plugs in closed-form (loss, grad) and forward
//! closures instead, which keeps the entire subsystem — training,
//! reservoir, posterior prediction, checkpointing — runnable in the
//! hermetic no-PJRT build (see [`linear_native_model`]).

use std::fmt;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::BatchSource;
use crate::infer::{eval, Infer, TrainReport};
use crate::nel::{CreateOpts, ParticleCtx};
use crate::particle::{handler, PFuture, PushError, Value};
use crate::pd::checkpoint::Checkpoint;
use crate::pd::PushDist;
use crate::runtime::kernels;
use crate::runtime::tensor::ops;
use crate::runtime::Tensor;
use crate::util::rng::Rng;
use crate::Pid;

/// Particle-state keys of one chain. Public so checkpoint-aware tests and
/// tools can interpret a PD snapshot (pd::checkpoint serializes the whole
/// state map generically and needs no knowledge of these).
pub const K_STEP: &str = "sgmcmc_t";
pub const K_SEEN: &str = "sgmcmc_seen";
pub const K_MOM: &str = "sgmcmc_mom";
pub const K_SAMPLES: &str = "sgmcmc_samples";

/// Salt folded into the per-step noise stream (vs data/init streams).
const NOISE_SALT: u64 = 0x5347_4d43_6e6f;
/// Salt folded into the reservoir's acceptance stream.
const RESERVOIR_SALT: u64 = 0x5347_4d43_7265;

/// The per-(seed, chain, step) Gaussian-noise stream. Shared by the
/// particle handler and the sequential baseline so that 1-device
/// trajectories are comparable when chain ids align with pids.
pub fn noise_rng(seed: u64, chain: u64, t: u64) -> Rng {
    Rng::new(seed ^ NOISE_SALT).fold_in(chain).fold_in(t)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SgmcmcAlgo {
    Sgld,
    Sghmc,
}

impl SgmcmcAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            SgmcmcAlgo::Sgld => "sgld",
            SgmcmcAlgo::Sghmc => "sghmc",
        }
    }
}

/// Step-size / temperature schedule. One config covers constant chains,
/// polynomially decayed chains (Welling & Teh's ε_t = a (b + t)^−γ), and
/// cSG-MCMC warm restarts (cosine within a cycle, samples collected only
/// in the low-step-size tail of each cycle).
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Constant { eps: f32 },
    /// ε_t = a · (b + t)^(−γ)
    PolyDecay { a: f32, b: f32, gamma: f32 },
    /// ε_t = ε₀/2 · (cos(π · (t mod M)/M) + 1) with cycle length M;
    /// sampling is enabled only in the final `sample_frac` of each cycle
    /// (the "sampling stage" of cSG-MCMC).
    Cyclical { eps0: f32, cycle_len: usize, sample_frac: f32 },
}

impl Schedule {
    pub fn step_size(&self, t: usize) -> f32 {
        match self {
            Schedule::Constant { eps } => *eps,
            Schedule::PolyDecay { a, b, gamma } => a * (b + t as f32).powf(-gamma),
            Schedule::Cyclical { eps0, cycle_len, .. } => {
                let m = (*cycle_len).max(1);
                let pos = (t % m) as f32 / m as f32;
                eps0 / 2.0 * ((std::f32::consts::PI * pos).cos() + 1.0)
            }
        }
    }

    /// Whether step `t` is inside a sampling phase. Always true except for
    /// the exploration stage of a cyclical schedule.
    pub fn samples_at(&self, t: usize) -> bool {
        match self {
            Schedule::Cyclical { cycle_len, sample_frac, .. } => {
                let m = (*cycle_len).max(1);
                (t % m) as f32 >= (1.0 - sample_frac.clamp(0.0, 1.0)) * m as f32
            }
            _ => true,
        }
    }
}

/// (loss, flat gradient) of the minibatch potential at `params`.
pub type NativeGradFn =
    Arc<dyn Fn(&Tensor, &Tensor, &Tensor) -> Result<(f32, Tensor), PushError> + Send + Sync>;
/// Prediction at `x` under `params`.
pub type NativeForwardFn =
    Arc<dyn Fn(&Tensor, &Tensor) -> Result<Tensor, PushError> + Send + Sync>;

/// Where gradients and forwards come from: the model's AOT artifacts
/// (`grad`/`fwd` entries through PJRT) or native closures — the latter
/// keeps SGMCMC fully functional in the hermetic no-PJRT build and is what
/// the deterministic equivalence tests drive.
///
/// Native sources carry a `name` so a chain config can cross the PD wire:
/// closures never serialize — the NAME does, and the receiving node
/// rebuilds the same source via [`model_source_by_name`]. An empty name
/// marks an ad-hoc closure source that is in-process only.
#[derive(Clone)]
pub enum ModelSource {
    Artifact,
    Native { name: &'static str, grad: NativeGradFn, forward: NativeForwardFn },
}

impl fmt::Debug for ModelSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelSource::Artifact => write!(f, "Artifact"),
            ModelSource::Native { name, .. } => write!(f, "Native({name:?})"),
        }
    }
}

/// Resolve a wire-named model source node-locally ("artifact" or a
/// registered native source). The inverse of the name carried by
/// [`SgmcmcConfig::to_wire`].
pub fn model_source_by_name(name: &str) -> Option<ModelSource> {
    match name {
        "artifact" => Some(ModelSource::Artifact),
        "linear" => Some(linear_native_model()),
        "mlp_native" => Some(crate::infer::models::mlp_native_model()),
        "conv1d_native" => Some(crate::infer::models::conv1d_native_model()),
        "linear_spiral_native" => Some(crate::infer::models::linear_spiral_model()),
        _ => None,
    }
}

#[derive(Clone)]
pub struct SgmcmcConfig {
    pub particles: usize,
    pub algo: SgmcmcAlgo,
    pub schedule: Schedule,
    /// Posterior temperature T. 0 disables noise entirely (SGLD ≡ SGD,
    /// SGHMC ≡ momentum SGD); 1 is the Bayesian posterior; small values
    /// (cold posteriors) are the common BDL practice.
    pub temperature: f32,
    /// SGHMC friction α (momentum decay). Ignored by SGLD.
    pub friction: f32,
    /// Steps before the reservoir starts collecting.
    pub burn_in: usize,
    /// Keep every `thin`-th post-burn-in step as a sample candidate.
    pub thin: usize,
    /// Reservoir capacity per particle (bounded memory regardless of chain
    /// length; Algorithm R keeps the kept set uniform over candidates).
    pub max_samples: usize,
    /// Gaussian prior std; adds the score term θ/σ² to the gradient.
    pub prior_std: Option<f32>,
    pub seed: u64,
    pub model: ModelSource,
    /// Per-particle initial parameters (index → tensor). None uses the
    /// model's AOT `init` artifact; Some makes creation hermetic.
    pub init: Option<Arc<dyn Fn(usize) -> Tensor + Send + Sync>>,
}

// Manual Debug: `init` holds an Arc'd closure, which has no Debug impl.
impl fmt::Debug for SgmcmcConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SgmcmcConfig")
            .field("particles", &self.particles)
            .field("algo", &self.algo)
            .field("schedule", &self.schedule)
            .field("temperature", &self.temperature)
            .field("friction", &self.friction)
            .field("burn_in", &self.burn_in)
            .field("thin", &self.thin)
            .field("max_samples", &self.max_samples)
            .field("prior_std", &self.prior_std)
            .field("seed", &self.seed)
            .field("model", &self.model)
            .field("init", &self.init.as_ref().map(|_| "<fn>"))
            .finish()
    }
}

impl Default for SgmcmcConfig {
    fn default() -> Self {
        SgmcmcConfig {
            particles: 4,
            algo: SgmcmcAlgo::Sgld,
            schedule: Schedule::Constant { eps: 1e-2 },
            temperature: 1e-4,
            friction: 0.1,
            burn_in: 10,
            thin: 2,
            max_samples: 32,
            prior_std: None,
            seed: 0,
            model: ModelSource::Artifact,
            init: None,
        }
    }
}

/// True when completing step `t` (0-based, pre-increment) should offer the
/// post-update parameters to the reservoir.
pub fn is_sample_step(schedule: &Schedule, t: usize, burn_in: usize, thin: usize) -> bool {
    let thin = thin.max(1);
    t >= burn_in && (t - burn_in) % thin == 0 && schedule.samples_at(t)
}

/// Number of reservoir candidates after `steps` completed steps, for
/// schedules without a sampling-phase gate (constant / poly decay).
pub fn expected_candidates(steps: usize, burn_in: usize, thin: usize) -> usize {
    let thin = thin.max(1);
    if steps <= burn_in {
        0
    } else {
        // ceil((steps - burn_in) / thin) without usize::div_ceil (MSRV 1.72)
        (steps - burn_in + thin - 1) / thin
    }
}

/// u += N(0, sigma²) elementwise; no-op (and no RNG draws) at sigma == 0.
fn add_noise(u: &mut Tensor, sigma: f32, rng: &mut Rng) {
    if sigma <= 0.0 {
        return;
    }
    for v in u.as_f32_mut() {
        *v += sigma * rng.normal();
    }
}

/// Offer `snap` to the particle's bounded reservoir (Algorithm R over the
/// thinned post-burn-in chain). Deterministic in (seed, pid, candidate #).
///
/// The new `(samples, seen)` pair is committed in ONE `state_set_many`,
/// and `samples` is read (cloned — Arc bumps) rather than taken, so the
/// state map ALWAYS holds a consistent reservoir version:
/// `samples.len() == min(seen, cap)`. A concurrent snapshot
/// (`PosteriorServer::refresh`, which clones the map under the same lock)
/// can therefore never observe a torn reservoir — the invariant
/// `rust/tests/serve.rs` hammers.
fn reservoir_add(ctx: &ParticleCtx, snap: Tensor, seed: u64, cap: usize) {
    if cap == 0 {
        return;
    }
    let seen = match ctx.state_get(K_SEEN) {
        Some(Value::Usize(n)) => n,
        _ => 0,
    };
    let mut samples = match ctx.state_get(K_SAMPLES) {
        Some(Value::List(v)) => v,
        _ => Vec::new(),
    };
    if samples.len() < cap {
        samples.push(Value::Tensor(snap));
    } else {
        let j = Rng::new(seed ^ RESERVOIR_SALT)
            .fold_in(ctx.pid.0 as u64)
            .fold_in(seen as u64)
            .below(seen + 1);
        if j < cap {
            samples[j] = Value::Tensor(snap);
        }
    }
    ctx.state_set_many(vec![
        (K_SAMPLES.to_string(), Value::List(samples)),
        (K_SEEN.to_string(), Value::Usize(seen + 1)),
    ]);
}

/// A read-only snapshot of one particle's chain (for tests, tools, and the
/// example's reporting). Tensors are zero-copy clones.
#[derive(Debug, Clone, Default)]
pub struct ChainSnapshot {
    pub step: usize,
    pub seen: usize,
    pub momentum: Option<Tensor>,
    pub samples: Vec<Tensor>,
}

impl SgmcmcConfig {
    /// Serialize the chain config to a wire `Value` so a remote node can
    /// rebuild the exact same handlers (`pd::programs` program "sgmcmc").
    /// The model source crosses as a NAME (closures never serialize);
    /// anonymous native sources are in-process only and error here. The
    /// per-particle `init` closure is not carried either — initial
    /// parameters ship per particle in the `CreateSpec`.
    pub fn to_wire(&self) -> Result<Value, PushError> {
        let model = match &self.model {
            ModelSource::Artifact => "artifact",
            ModelSource::Native { name, .. } if !name.is_empty() => *name,
            ModelSource::Native { .. } => {
                return Err(PushError::new(
                    "anonymous native ModelSource cannot cross the wire; \
                     use a named source (see model_source_by_name)",
                ))
            }
        };
        let schedule = match &self.schedule {
            Schedule::Constant { eps } => {
                Value::List(vec![Value::Usize(0), Value::F32(*eps)])
            }
            Schedule::PolyDecay { a, b, gamma } => Value::List(vec![
                Value::Usize(1),
                Value::F32(*a),
                Value::F32(*b),
                Value::F32(*gamma),
            ]),
            Schedule::Cyclical { eps0, cycle_len, sample_frac } => Value::List(vec![
                Value::Usize(2),
                Value::F32(*eps0),
                Value::Usize(*cycle_len),
                Value::F32(*sample_frac),
            ]),
        };
        Ok(Value::List(vec![
            Value::Str(model.to_string()),
            Value::Str(self.algo.name().to_string()),
            schedule,
            Value::F32(self.temperature),
            Value::F32(self.friction),
            Value::Usize(self.burn_in),
            Value::Usize(self.thin),
            Value::Usize(self.max_samples),
            match self.prior_std {
                Some(s) => Value::F32(s),
                None => Value::Unit,
            },
            Value::Usize(self.seed as usize),
        ]))
    }

    /// Decode a [`SgmcmcConfig::to_wire`] value. `particles` is set to 1
    /// and `init` to None: neither matters to the handlers — placement
    /// and initial parameters are the fabric's business.
    pub fn from_wire(v: &Value) -> Result<SgmcmcConfig, PushError> {
        let items = match v {
            Value::List(vs) if vs.len() == 10 => vs,
            other => {
                return Err(PushError::new(format!(
                    "malformed sgmcmc wire config: {other:?}"
                )))
            }
        };
        let str_at = |i: usize| -> Result<&str, PushError> {
            match &items[i] {
                Value::Str(s) => Ok(s),
                other => Err(PushError::new(format!("wire config [{i}]: {other:?}"))),
            }
        };
        let model = model_source_by_name(str_at(0)?).ok_or_else(|| {
            PushError::new(format!("unknown wire model source {:?}", str_at(0).unwrap()))
        })?;
        let algo = match str_at(1)? {
            "sgld" => SgmcmcAlgo::Sgld,
            "sghmc" => SgmcmcAlgo::Sghmc,
            other => return Err(PushError::new(format!("unknown sgmcmc algo {other:?}"))),
        };
        // Tags are validated explicitly: a future schedule variant (or a
        // version-skewed peer) must fail cleanly, never silently decode
        // as a different schedule with reinterpreted fields.
        let schedule = match &items[2] {
            Value::List(s) if s.len() == 2 && s[0] == Value::Usize(0) => {
                Schedule::Constant { eps: s[1].f32()? }
            }
            Value::List(s) if s.len() == 4 && s[0] == Value::Usize(1) => Schedule::PolyDecay {
                a: s[1].f32()?,
                b: s[2].f32()?,
                gamma: s[3].f32()?,
            },
            Value::List(s) if s.len() == 4 && s[0] == Value::Usize(2) => Schedule::Cyclical {
                eps0: s[1].f32()?,
                cycle_len: s[2].usize()?,
                sample_frac: s[3].f32()?,
            },
            other => {
                return Err(PushError::new(format!("malformed wire schedule: {other:?}")))
            }
        };
        Ok(SgmcmcConfig {
            particles: 1,
            algo,
            schedule,
            temperature: items[3].f32()?,
            friction: items[4].f32()?,
            burn_in: items[5].usize()?,
            thin: items[6].usize()?,
            max_samples: items[7].usize()?,
            prior_std: match &items[8] {
                Value::Unit => None,
                other => Some(other.f32()?),
            },
            seed: items[9].usize()? as u64,
            model,
            init: None,
        })
    }
}

pub struct SgMcmc {
    pd: PushDist,
    pids: Vec<Pid>,
    pub cfg: SgmcmcConfig,
    /// Node-death recovery budget of [`Infer::train`]: how many rounds may
    /// be replayed-after-migration before the run fails loudly. 0 (the
    /// default) disables the checkpoint-and-retry wrapper entirely — the
    /// driver behaves exactly as before this field existed.
    recover_rounds: usize,
}

/// Build the `MCMC_STEP` / `MCMC_PREDICT` handler table for one chain
/// config. Shared by the in-process constructor and the node-local
/// "sgmcmc" program (`pd::programs`), so a particle created over the wire
/// runs EXACTLY the handlers a local one does — the algorithm is
/// transport-oblivious by construction.
pub fn chain_handler_table(cfg: &SgmcmcConfig) -> crate::particle::HandlerTable {
    let scfg = cfg.clone();
    let step = handler(move |ctx, args| {
        let x = args[0].as_tensor()?.clone();
        let y = args[1].as_tensor()?.clone();
        let t = match ctx.state_get(K_STEP) {
            Some(Value::Usize(t)) => t,
            _ => 0,
        };
        let eps = scfg.schedule.step_size(t);

        // 1. gradient of the minibatch potential. One parameter
        //    snapshot serves both the native gradient and the prior
        //    term (it is a zero-copy Arc clone either way).
        let needs_params =
            matches!(&scfg.model, ModelSource::Native { .. }) || scfg.prior_std.is_some();
        let params = if needs_params {
            Some(ctx.own_params().wait()?.tensor()?)
        } else {
            None
        };
        let (loss, mut grad) = match &scfg.model {
            ModelSource::Artifact => {
                let mut lg = ctx.grad(x, y).wait()?.list()?;
                let loss = lg[0].as_tensor()?.scalar();
                (loss, lg.remove(1).tensor()?)
            }
            ModelSource::Native { grad, .. } => {
                grad(params.as_ref().expect("fetched above"), &x, &y)?
            }
        };
        // Gaussian prior score term (Appendix B.1's treatment):
        // ∇U gains θ/σ². In place — the gradient is uniquely owned.
        if let Some(std) = scfg.prior_std {
            ops::axpy(&mut grad, 1.0 / (std * std), params.as_ref().expect("fetched above"));
        }
        // Release the snapshot BEFORE the apply so axpy_params mutates
        // the resident parameters in place instead of COW-detaching.
        drop(params);

        // 2. the update, with noise from a per-(seed, pid, t) stream so
        //    trajectories are reproducible under any scheduling order.
        //    SGHMC builds the new momentum WITHOUT mutating the stored
        //    one (u = −ε g + noise, then u += (1−α) v), so a failed
        //    apply below can put the old momentum back untouched.
        let mut rng = noise_rng(scfg.seed, ctx.pid.0 as u64, t as u64);
        let mut u = grad;
        ops::scale(&mut u, -eps);
        let old_momentum = match scfg.algo {
            SgmcmcAlgo::Sgld => {
                // u = −ε g + N(0, 2 ε T)
                add_noise(&mut u, (2.0 * eps * scfg.temperature).sqrt(), &mut rng);
                None
            }
            SgmcmcAlgo::Sghmc => {
                // v' = −ε g + N(0, 2 α T ε) + (1−α) v
                add_noise(
                    &mut u,
                    (2.0 * scfg.friction * scfg.temperature * eps).sqrt(),
                    &mut rng,
                );
                let v_old = match ctx.state_take(K_MOM) {
                    Some(Value::Tensor(t)) => t,
                    _ => Tensor::zeros(vec![u.element_count()]),
                };
                ops::scale_add(&mut u, 1.0, 1.0 - scfg.friction, &v_old);
                Some(v_old)
            }
        };
        let update = u;

        // 3. θ += update on the particle's device; chain state only
        //    advances if the apply succeeded (run_adam discipline): a
        //    failed apply restores the momentum it took.
        if let Err(e) = ctx.axpy_params(1.0, update.clone()).wait() {
            if let Some(v_old) = old_momentum {
                ctx.state_set(K_MOM, Value::Tensor(v_old));
            }
            return Err(e);
        }
        if scfg.algo == SgmcmcAlgo::Sghmc {
            ctx.state_set(K_MOM, Value::Tensor(update));
        }
        ctx.state_set(K_STEP, Value::Usize(t + 1));

        // 4. reservoir: offer a zero-copy snapshot of the post-update
        //    parameters (later steps COW-detach, so it stays immutable)
        if is_sample_step(&scfg.schedule, t, scfg.burn_in, scfg.thin) {
            let snap = ctx.own_params().wait()?.tensor()?;
            reservoir_add(ctx, snap, scfg.seed, scfg.max_samples);
        }
        Ok(Value::F32(loss))
    });

    let pcfg = cfg.clone();
    let predict = handler(move |ctx, args| {
        let x = args[0].as_tensor()?.clone();
        let classify = ctx.model().task == "classify";
        let samples: Vec<Tensor> = match ctx.state_get(K_SAMPLES) {
            Some(Value::List(v)) => {
                v.into_iter().filter_map(|s| s.tensor().ok()).collect()
            }
            _ => Vec::new(),
        };
        let mut acc: Option<Tensor> = None;
        let mut n = 0usize;
        match &pcfg.model {
            ModelSource::Native { forward, .. } => {
                if samples.is_empty() {
                    // empty reservoir: fall back to the current params
                    // (pre-burn-in chain == plain point prediction)
                    let params = ctx.own_params().wait()?.tensor()?;
                    eval::accumulate_prediction(&mut acc, forward(&params, &x)?, classify);
                    n = 1;
                } else {
                    for s in &samples {
                        eval::accumulate_prediction(&mut acc, forward(s, &x)?, classify);
                        n += 1;
                    }
                }
            }
            ModelSource::Artifact => {
                if samples.is_empty() {
                    let pred = ctx.forward(x).wait()?.tensor()?;
                    eval::accumulate_prediction(&mut acc, pred, classify);
                    n = 1;
                } else {
                    // Zero-copy backup of the live params; each sample
                    // is swapped in (refcount bump), forwarded, and the
                    // backup moved back — ALWAYS, even when a forward
                    // fails mid-loop, so a transient predict error can
                    // never leave the chain running on a stale sample.
                    let backup = ctx.own_params().wait()?.tensor()?;
                    let mut failure = None;
                    for s in &samples {
                        let pred = ctx
                            .set_params(s.clone())
                            .wait()
                            .and_then(|_| ctx.forward(x.clone()).wait())
                            .and_then(|v| v.tensor());
                        match pred {
                            Ok(p) => {
                                eval::accumulate_prediction(&mut acc, p, classify);
                                n += 1;
                            }
                            Err(e) => {
                                failure = Some(e);
                                break;
                            }
                        }
                    }
                    ctx.set_params(backup).wait()?;
                    if let Some(e) = failure {
                        return Err(e);
                    }
                }
            }
        }
        eval::finalize_mean(acc, n, classify)
            .map(Value::Tensor)
            .ok_or_else(|| PushError::new("MCMC_PREDICT over zero predictions"))
    });

    [
        ("MCMC_STEP".to_string(), step),
        ("MCMC_PREDICT".to_string(), predict),
    ]
    .into_iter()
    .collect()
}

impl SgMcmc {
    /// Create `cfg.particles` independent chains. Each particle answers
    /// `MCMC_STEP(x, y)` with one SGLD/SGHMC update (plus reservoir
    /// bookkeeping) and `MCMC_PREDICT(x)` with its posterior-predictive
    /// mean over reservoir samples.
    ///
    /// On a single-node PD, particles are created in-process with handler
    /// closures — byte-for-byte the pre-fabric behavior. On a multi-node
    /// PD the same chains are created through the transport from a
    /// serializable spec: the "sgmcmc" handler program plus the wire
    /// config, with per-particle init parameters shipped explicitly. The
    /// chains themselves cannot tell the difference — every deterministic
    /// stream is keyed by (seed, GLOBAL pid, step), never by node.
    pub fn new(pd: PushDist, cfg: SgmcmcConfig) -> Result<SgMcmc> {
        assert!(cfg.particles > 0);
        let init = cfg.init.clone();
        let pids = if pd.nodes() > 1 {
            let wire = cfg.to_wire().map_err(|e| anyhow!("{e}"))?;
            pd.p_create_spec_n(cfg.particles, |i| crate::pd::SpecOpts {
                program: Some(("sgmcmc".to_string(), wire.clone())),
                init_params: init.as_ref().map(|f| f(i)),
                ..crate::pd::SpecOpts::default()
            })?
        } else {
            let table = chain_handler_table(&cfg);
            pd.p_create_n(cfg.particles, |i| CreateOpts {
                receive: table.clone(),
                init_params: init.as_ref().map(|f| f(i)),
                ..CreateOpts::default()
            })?
        };
        Ok(SgMcmc { pd, pids, cfg, recover_rounds: 0 })
    }

    /// Arm the bounded checkpoint-and-retry wrapper: up to `rounds` rounds
    /// may be recovered (migrate the dead node's chains from the last
    /// checkpoint, rewind survivors, replay the round) before training
    /// fails loudly naming the dead node(s). See DESIGN.md §Elastic
    /// fabric.
    pub fn with_recovery(mut self, rounds: usize) -> Self {
        self.recover_rounds = rounds;
        self
    }

    pub fn pd(&self) -> &PushDist {
        &self.pd
    }

    /// One synchronized chain step of every particle on (x, y); returns
    /// the mean minibatch loss. One broadcast fan-out, one join_all
    /// barrier (the ensemble-shaped round).
    pub fn step_all(&self, x: &Tensor, y: &Tensor) -> Result<f64> {
        let futs = self.pd.broadcast(
            &self.pids,
            "MCMC_STEP",
            vec![Value::Tensor(x.clone()), Value::Tensor(y.clone())],
        );
        let losses = PFuture::join_all(&futs)
            .wait()
            .map_err(|e| anyhow!("{e}"))?
            .list()
            .map_err(|e| anyhow!("{e}"))?;
        let mut total = 0.0f64;
        for l in &losses {
            total += l.f32().map_err(|e| anyhow!("{e}"))? as f64;
        }
        Ok(total / losses.len() as f64)
    }

    /// [`SgMcmc::step_all`] wrapped in bounded node-death recovery: on
    /// success the checkpoint advances to the post-round state; on a
    /// failure caused by a DEAD link (any other failure propagates as-is)
    /// the dead node's chains are migrated onto survivors from `ckpt`,
    /// the survivors are rewound to `ckpt`, and the SAME round replays —
    /// deterministic streams are keyed by (seed, global pid, step), so the
    /// replayed round is bit-identical to the one the dead node
    /// interrupted. `used` counts recoveries across the whole run; once it
    /// would exceed the budget, the error names the dead node(s) — a loud
    /// failure, never a hang.
    pub fn step_all_recovering(
        &self,
        x: &Tensor,
        y: &Tensor,
        ckpt: &mut Checkpoint,
        used: &mut usize,
    ) -> Result<f64> {
        loop {
            // The capture is part of the round: a node dying between the
            // barrier and the capture is recovered exactly like one dying
            // mid-round (`ckpt` still holds the pre-round state either way).
            let round = self
                .step_all(x, y)
                .and_then(|loss| Checkpoint::capture(&self.pd).map(|c| (loss, c)));
            match round {
                Ok((loss, c)) => {
                    *ckpt = c;
                    return Ok(loss);
                }
                Err(e) => {
                    let dead = self.pd.dead_nodes();
                    if dead.is_empty() {
                        return Err(e);
                    }
                    let names: Vec<String> = dead
                        .iter()
                        .map(|n| match self.pd.peer_addr(*n) {
                            Some(a) => format!("node {n} ({a})"),
                            None => format!("node {n}"),
                        })
                        .collect();
                    if *used >= self.recover_rounds {
                        return Err(anyhow!(
                            "recover budget ({}) exhausted; dead node(s): {}; last error: {e:#}",
                            self.recover_rounds,
                            names.join(", ")
                        ));
                    }
                    *used += 1;
                    crate::log_warn!(
                        "dead node(s) {}; migrating chains and replaying round (recovery {}/{})",
                        names.join(", "),
                        used,
                        self.recover_rounds
                    );
                    self.pd.recover(ckpt)?;
                    // Restore MERGES state keys, so it cannot delete a key
                    // the failed round added but `ckpt` predates (e.g. the
                    // reservoir of a chain's first sample step). Reset
                    // such keys to their pre-round defaults explicitly —
                    // each default is read identically to the key being
                    // absent — so the replay is bit-identical for ANY
                    // kill step, not just post-first-sample ones.
                    for pid in &self.pids {
                        let have = ckpt.state.get(pid);
                        let has = |k: &str| {
                            have.map(|e| e.iter().any(|(key, _)| key == k)).unwrap_or(false)
                        };
                        let mut reset: Vec<(String, Value)> = Vec::new();
                        if !has(K_STEP) {
                            reset.push((K_STEP.to_string(), Value::Usize(0)));
                        }
                        if !has(K_SEEN) {
                            reset.push((K_SEEN.to_string(), Value::Usize(0)));
                            reset.push((K_SAMPLES.to_string(), Value::List(Vec::new())));
                        }
                        if self.cfg.algo == SgmcmcAlgo::Sghmc && !has(K_MOM) {
                            let d = self.pd.model().param_count;
                            reset.push((K_MOM.to_string(), Value::Tensor(Tensor::zeros(vec![d]))));
                        }
                        if !reset.is_empty() {
                            self.pd
                                .restore_particle_state(*pid, reset)
                                .map_err(|e| anyhow!("{e}"))?;
                        }
                    }
                }
            }
        }
    }

    /// A [`crate::infer::PosteriorServer`] over this run's chains: answers
    /// posterior-predictive queries from versioned reservoir snapshots on
    /// the CALLER's thread while training keeps stepping (no broadcast
    /// round, no scheduler occupancy — DESIGN.md §10). Requires a native
    /// model source (serving forwards run outside the device layer).
    pub fn serve_handle(&self) -> Result<crate::infer::PosteriorServer> {
        crate::infer::PosteriorServer::new(self.pd.serve_handle(), self.pids.clone(), &self.cfg)
    }

    /// [`SgMcmc::serve_handle`] with explicit serving policy (refresh
    /// deadline/retries, admission limit — DESIGN.md §12).
    pub fn serve_handle_with(
        &self,
        serve_cfg: crate::infer::ServeConfig,
    ) -> Result<crate::infer::PosteriorServer> {
        crate::infer::PosteriorServer::with_config(
            self.pd.serve_handle(),
            self.pids.clone(),
            &self.cfg,
            serve_cfg,
        )
    }

    /// Read one chain's clock / momentum / reservoir (zero-copy clones).
    pub fn chain(&self, pid: Pid) -> ChainSnapshot {
        let mut snap = ChainSnapshot::default();
        if let Some(entries) = self.pd.particle_state(pid) {
            for (k, v) in entries {
                match (k.as_str(), v) {
                    (K_STEP, Value::Usize(t)) => snap.step = t,
                    (K_SEEN, Value::Usize(n)) => snap.seen = n,
                    (K_MOM, Value::Tensor(t)) => snap.momentum = Some(t),
                    (K_SAMPLES, Value::List(vs)) => {
                        snap.samples =
                            vs.into_iter().filter_map(|s| s.tensor().ok()).collect();
                    }
                    _ => {}
                }
            }
        }
        snap
    }
}

impl Infer for SgMcmc {
    fn name(&self) -> &str {
        self.cfg.algo.name()
    }

    fn pids(&self) -> Vec<Pid> {
        self.pids.clone()
    }

    fn train(&mut self, source: &mut dyn BatchSource, epochs: usize) -> Result<TrainReport> {
        let mut report = TrainReport::new(self.name());
        if self.recover_rounds > 0 && self.pd.nodes() > 1 {
            // Elastic path: per-round checkpoint (COW — no parameter-sized
            // copies) so a node death mid-round migrates + replays instead
            // of killing the run. The budget spans the whole run.
            let mut ckpt = Checkpoint::capture(&self.pd)?;
            let mut used = 0usize;
            for _ in 0..epochs {
                let stream = source.epoch_stream();
                let t0 = Instant::now();
                let mut loss = 0.0;
                let mut nb = 0usize;
                for b in stream {
                    loss += self.step_all_recovering(&b.x, &b.y, &mut ckpt, &mut used)?;
                    nb += 1;
                }
                report.push(loss / nb.max(1) as f64, t0.elapsed().as_secs_f64());
            }
            return Ok(report);
        }
        for _ in 0..epochs {
            let stream = source.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0;
            let mut nb = 0usize;
            for b in stream {
                loss += self.step_all(&b.x, &b.y)?;
                nb += 1;
            }
            report.push(loss / nb.max(1) as f64, t0.elapsed().as_secs_f64());
        }
        Ok(report)
    }

    /// Posterior-predictive mean: each particle averages predictions over
    /// its reservoir samples (majority votes for classify), then particle
    /// outputs are averaged — the multi-chain analogue of §3.4.
    fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        let futs = self
            .pd
            .broadcast(&self.pids, "MCMC_PREDICT", vec![Value::Tensor(x.clone())]);
        let joined = PFuture::join_all(&futs);
        let preds = joined
            .wait()
            .map_err(|e| anyhow!("{e}"))?
            .list()
            .map_err(|e| anyhow!("{e}"))?;
        // Release the futures before accumulating so the first prediction
        // is uniquely owned and the axpy chain runs in place.
        drop(joined);
        drop(futs);
        let classify = self.pd.model().task == "classify";
        let mut acc: Option<Tensor> = None;
        let mut n = 0usize;
        for p in preds {
            // Particle outputs are already per-chain vote sums / means —
            // accumulate raw (re-voting would erase the vote weights).
            let t = p.tensor().map_err(|e| anyhow!("{e}"))?;
            match &mut acc {
                None => acc = Some(t),
                Some(a) => ops::axpy(a, 1.0, &t),
            }
            n += 1;
        }
        let mut out = acc.ok_or_else(|| anyhow!("predict over zero particles"))?;
        if !classify {
            kernels::div_scale(out.as_f32_mut(), n as f32);
        }
        Ok(out)
    }

    fn nel_stats(&self) -> crate::nel::NelStats {
        self.pd.stats()
    }

    /// Split R-hat / ESS across the particle-chains' reservoirs (worst
    /// parameter dimension). NaN-safe: undiagnosable chains (pre-burn-in,
    /// too few samples) come back NaN and render "n/a".
    fn diagnostics(&self) -> Option<eval::ChainDiag> {
        let chains: Vec<Vec<Tensor>> =
            self.pids.iter().map(|p| self.chain(*p).samples).collect();
        Some(eval::chain_diagnostics(&chains))
    }

    fn transport_counters(&self) -> Vec<crate::pd::transport::TransportCounters> {
        self.pd.transport_counters()
    }
}

/// A manifest holding ONLY the hermetic `linear_native` model spec
/// (`d` flat weights, `[batch, d] → [batch, 1]` regression, no artifact
/// entries). The one shared constructor behind `push train/serve
/// --model linear_native`, the transport/serve/sgmcmc test suites, and
/// the serving micro-benches — the spec lives in one place instead of a
/// hand-rolled copy per crate.
pub fn linear_native_manifest(d: usize, batch: usize) -> crate::runtime::Manifest {
    let spec = crate::runtime::ModelSpec {
        name: "linear_native".to_string(),
        param_count: d,
        task: "regress".to_string(),
        x_shape: vec![batch, d],
        y_shape: vec![batch, 1],
        y_dtype: crate::runtime::DType::F32,
        arch: "mlp".to_string(),
        meta: std::collections::BTreeMap::new(),
        entries: std::collections::BTreeMap::new(),
    };
    crate::runtime::Manifest {
        dir: std::path::PathBuf::from("."),
        models: [("linear_native".to_string(), spec)].into_iter().collect(),
        svgd: Vec::new(),
    }
}

/// Closed-form linear least-squares model for the synthetic regression
/// task (`data::synth::linear`): loss = mean((x·θ − y)²) over the batch,
/// grad = 2/B · Xᵀ(Xθ − y), forward = Xθ. Used by the hermetic tests, the
/// `sgmcmc_regression` example, and the micro-benches — no artifacts, no
/// PJRT.
pub fn linear_native_model() -> ModelSource {
    let grad: NativeGradFn = Arc::new(|params, x, y| {
        let d = params.element_count();
        let b = x.shape.first().copied().unwrap_or(0);
        if b == 0 || x.element_count() != b * d || y.element_count() != b {
            return Err(PushError::new(format!(
                "linear grad: x {:?} / y {:?} incompatible with {d} params",
                x.shape, y.shape
            )));
        }
        let w = params.as_f32();
        let xs = x.as_f32();
        let ys = y.as_f32();
        let mut g = vec![0.0f32; d];
        let mut loss = 0.0f32;
        for i in 0..b {
            let row = &xs[i * d..(i + 1) * d];
            let pred = kernels::dot(row, w);
            let err = pred - ys[i];
            loss += err * err;
            kernels::axpy(&mut g, 2.0 * err, row);
        }
        let inv_b = 1.0 / b as f32;
        kernels::scale(&mut g, inv_b);
        Ok((loss * inv_b, Tensor::f32(vec![d], g)))
    });
    let forward: NativeForwardFn = Arc::new(|params, x| {
        let d = params.element_count();
        let b = x.shape.first().copied().unwrap_or(0);
        if x.element_count() != b * d {
            return Err(PushError::new(format!(
                "linear forward: x {:?} incompatible with {d} params",
                x.shape
            )));
        }
        let w = params.as_f32();
        let xs = x.as_f32();
        let preds: Vec<f32> =
            (0..b).map(|i| kernels::dot(&xs[i * d..(i + 1) * d], w)).collect();
        Ok(Tensor::f32(vec![b, 1], preds))
    });
    ModelSource::Native { name: "linear", grad, forward }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_and_poly_schedules() {
        let c = Schedule::Constant { eps: 0.5 };
        assert_eq!(c.step_size(0), 0.5);
        assert_eq!(c.step_size(1000), 0.5);
        assert!(c.samples_at(0));

        let p = Schedule::PolyDecay { a: 1.0, b: 1.0, gamma: 1.0 };
        assert!((p.step_size(0) - 1.0).abs() < 1e-6);
        assert!((p.step_size(3) - 0.25).abs() < 1e-6);
        assert!(p.step_size(10) < p.step_size(9), "monotone decay");
        assert!(p.samples_at(7));
    }

    #[test]
    fn cyclical_schedule_restarts_and_gates_sampling() {
        let s = Schedule::Cyclical { eps0: 1.0, cycle_len: 10, sample_frac: 0.3 };
        // cosine: max at cycle start, ~0 at cycle end, restarts at t = M
        assert!((s.step_size(0) - 1.0).abs() < 1e-6);
        assert!(s.step_size(9) < 0.1);
        assert!((s.step_size(10) - 1.0).abs() < 1e-6, "warm restart");
        // sampling only in the final 30% of each cycle: t mod 10 >= 7
        for t in 0..7 {
            assert!(!s.samples_at(t), "t={t} is exploration");
        }
        for t in 7..10 {
            assert!(s.samples_at(t), "t={t} is sampling");
        }
        assert!(!s.samples_at(10), "restart re-enters exploration");
    }

    #[test]
    fn candidate_counting() {
        assert_eq!(expected_candidates(0, 4, 2), 0);
        assert_eq!(expected_candidates(4, 4, 2), 0);
        assert_eq!(expected_candidates(5, 4, 2), 1); // t = 4
        assert_eq!(expected_candidates(6, 4, 2), 1);
        assert_eq!(expected_candidates(7, 4, 2), 2); // t = 4, 6
        assert_eq!(expected_candidates(10, 0, 1), 10);
        // is_sample_step agrees with the closed form
        let s = Schedule::Constant { eps: 1.0 };
        let n = (0..10).filter(|&t| is_sample_step(&s, t, 4, 2)).count();
        assert_eq!(n, expected_candidates(10, 4, 2));
        // thin = 0 is treated as 1, not a panic
        assert_eq!(expected_candidates(3, 0, 0), 3);
    }

    #[test]
    fn linear_grad_matches_finite_difference() {
        let model = linear_native_model();
        let ModelSource::Native { grad, forward, .. } = model else {
            panic!("linear model is native")
        };
        let d = 4;
        let params = Tensor::f32(vec![d], vec![0.3, -0.7, 1.1, 0.05]);
        let x = Tensor::f32(vec![3, d], (0..3 * d).map(|i| (i as f32) * 0.1 - 0.5).collect());
        let y = Tensor::f32(vec![3, 1], vec![0.2, -0.4, 1.0]);
        let (l0, g) = grad(&params, &x, &y).unwrap();
        assert!(l0.is_finite());
        let h = 1e-3f32;
        for j in 0..d {
            let mut p2 = params.clone();
            p2.as_f32_mut()[j] += h;
            let (l2, _) = grad(&p2, &x, &y).unwrap();
            let fd = (l2 - l0) / h;
            assert!(
                (fd - g.as_f32()[j]).abs() < 2e-2,
                "grad[{j}] {} vs fd {fd}",
                g.as_f32()[j]
            );
        }
        // forward shape contract
        let pred = forward(&params, &x).unwrap();
        assert_eq!(pred.shape, vec![3, 1]);
    }

    #[test]
    fn wire_config_roundtrips() {
        let cfg = SgmcmcConfig {
            particles: 8,
            algo: SgmcmcAlgo::Sghmc,
            schedule: Schedule::Cyclical { eps0: 0.5, cycle_len: 20, sample_frac: 0.25 },
            temperature: 0.125,
            friction: 0.25,
            burn_in: 7,
            thin: 3,
            max_samples: 9,
            prior_std: Some(2.0),
            seed: 77,
            model: linear_native_model(),
            init: None,
        };
        let back = SgmcmcConfig::from_wire(&cfg.to_wire().unwrap()).unwrap();
        assert_eq!(back.algo, SgmcmcAlgo::Sghmc);
        assert_eq!(back.schedule, cfg.schedule);
        assert_eq!(back.temperature, cfg.temperature);
        assert_eq!(back.friction, cfg.friction);
        assert_eq!((back.burn_in, back.thin, back.max_samples), (7, 3, 9));
        assert_eq!(back.prior_std, Some(2.0));
        assert_eq!(back.seed, 77);
        assert!(matches!(back.model, ModelSource::Native { name: "linear", .. }));

        let cfg2 = SgmcmcConfig {
            model: ModelSource::Artifact,
            prior_std: None,
            schedule: Schedule::PolyDecay { a: 1.0, b: 2.0, gamma: 0.5 },
            ..cfg
        };
        let back2 = SgmcmcConfig::from_wire(&cfg2.to_wire().unwrap()).unwrap();
        assert!(matches!(back2.model, ModelSource::Artifact));
        assert_eq!(back2.prior_std, None);
        assert_eq!(back2.schedule, cfg2.schedule);

        // anonymous native sources cannot cross the wire
        let ModelSource::Native { grad, forward, .. } = linear_native_model() else {
            unreachable!()
        };
        let anon = SgmcmcConfig {
            model: ModelSource::Native { name: "", grad, forward },
            ..SgmcmcConfig::default()
        };
        assert!(anon.to_wire().is_err());
        // the registered zoo names resolve to themselves on the far side
        for name in ["mlp_native", "conv1d_native", "linear_spiral_native"] {
            let zoo = SgmcmcConfig {
                model: model_source_by_name(name).unwrap(),
                ..SgmcmcConfig::default()
            };
            let back = SgmcmcConfig::from_wire(&zoo.to_wire().unwrap()).unwrap();
            match back.model {
                ModelSource::Native { name: got, .. } => assert_eq!(got, name),
                other => panic!("{name} decoded as {other:?}"),
            }
        }
        // garbage rejects cleanly
        assert!(SgmcmcConfig::from_wire(&Value::Unit).is_err());
        assert!(SgmcmcConfig::from_wire(&Value::List(vec![Value::Unit; 10])).is_err());
    }

    #[test]
    fn zero_temperature_draws_no_noise() {
        let mut rng = Rng::new(7);
        let mut u = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        add_noise(&mut u, 0.0, &mut rng);
        assert_eq!(u.as_f32(), &[1.0, 2.0, 3.0]);
        let mut check = Rng::new(7);
        assert_eq!(rng.next_u64(), check.next_u64(), "rng untouched at T=0");
    }
}
