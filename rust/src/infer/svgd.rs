//! Stein variational gradient descent (Liu & Wang 2016) on particles —
//! the all-to-all-communication extreme of the paper's spectrum (§3.1),
//! implemented with the leader/follower message protocol of Appendix B
//! (Figures 5/6).
//!
//! Per batch, the leader: (1) triggers a gradient computation on every
//! follower (concurrent across devices), (2) gathers every particle's
//! parameters via read-only views, (3) stacks them and runs the L1 Pallas
//! `svgd_update` kernel artifact on its own device (the paper's
//! kernel-matrix bottleneck, O(n^2 d)), and (4) scatters per-particle
//! updates applied concurrently via SVGD_FOLLOW. The optional Gaussian
//! prior adds the score term of Eq. 26 (Appendix B.1).
//!
//! The round is zero-copy end to end on the coordinator (DESIGN.md
//! §Zero-copy parameter plane): views share the owners' buffers, the only
//! full copies are the two [n, d] stacks handed to the kernel, update rows
//! are views into the kernel's output, and the final axpy mutates each
//! particle's parameters in place.
//!
//! Sign convention: canonical descent-form SVGD — the paper's Appendix-B
//! listing flips the repulsion term; see DESIGN.md §SVGD-sign.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::BatchSource;
use crate::infer::models::fold_predictions;
use crate::infer::sgmcmc::{ModelSource, NativeForwardFn, NativeGradFn};
use crate::infer::{eval, Infer, TrainReport};
use crate::nel::CreateOpts;
use crate::particle::{handler, PFuture, PushError, Value};
use crate::pd::PushDist;
use crate::runtime::kernels;
use crate::runtime::Tensor;
use crate::Pid;

/// Per-particle init-parameter factory for native runs (index 0 is the
/// leader, 1.. the followers — matching `pids()` order).
type NativeInit = Arc<dyn Fn(usize) -> Tensor + Send + Sync>;

#[derive(Debug, Clone)]
pub struct SvgdConfig {
    pub particles: usize,
    pub lr: f32,
    /// RBF kernel lengthscale h (ignored when `median_heuristic` is on).
    pub lengthscale: f32,
    /// Recompute h each step from the particles' pairwise distances
    /// (Liu & Wang 2016: h^2 = median^2 / log n) — keeps the kernel
    /// informative as particles spread.
    pub median_heuristic: bool,
    /// Gaussian prior std; None = likelihood-only (improper flat prior).
    pub prior_std: Option<f32>,
    /// Force the native (non-artifact) kernel update even when an AOT
    /// artifact exists — used by the ablation bench.
    pub force_native: bool,
}

impl Default for SvgdConfig {
    fn default() -> Self {
        SvgdConfig {
            particles: 4,
            lr: 1e-2,
            lengthscale: 1.0,
            median_heuristic: false,
            prior_std: None,
            force_native: false,
        }
    }
}

pub struct Svgd {
    pd: PushDist,
    leader: Pid,
    followers: Vec<Pid>,
    pub cfg: SvgdConfig,
    /// Particles run a native model source: gradients come from its
    /// closed-form closure and prediction from PREDICT handlers instead
    /// of the AOT grad/forward artifacts.
    native: bool,
}

impl Svgd {
    pub fn new(pd: PushDist, cfg: SvgdConfig) -> Result<Svgd> {
        Svgd::build(pd, cfg, None)
    }

    /// SVGD over a [`ModelSource::Native`]: followers answer SVGD_STEP
    /// with the model's closed-form (loss, grad) pair, the leader runs
    /// the same closure for its own gradient, and `predict_mean` fans out
    /// PREDICT to every particle's native forward (there is no AOT fwd
    /// entry to `mean_forward` over). The kernel-matrix update itself is
    /// unchanged: Pallas artifact when one matches (n, d), native loop
    /// otherwise.
    pub fn new_native(
        pd: PushDist,
        cfg: SvgdConfig,
        source: &ModelSource,
        init: NativeInit,
    ) -> Result<Svgd> {
        let (grad, forward) = match source {
            ModelSource::Native { grad, forward, .. } => (grad.clone(), forward.clone()),
            ModelSource::Artifact => {
                return Err(anyhow!("Svgd::new_native needs a native model source"))
            }
        };
        Svgd::build(pd, cfg, Some((grad, forward, init)))
    }

    fn build(
        pd: PushDist,
        cfg: SvgdConfig,
        native: Option<(NativeGradFn, NativeForwardFn, NativeInit)>,
    ) -> Result<Svgd> {
        assert!(cfg.particles > 0);
        let is_native = native.is_some();
        // --- follower handlers -------------------------------------------
        // SVGD_STEP: compute (loss, grad) on own device — AOT grad
        // artifact or the native closure — and return both.
        let svgd_step = match &native {
            Some((grad, _, _)) => {
                let grad = grad.clone();
                handler(move |ctx, args| {
                    let x = args[0].as_tensor()?.clone();
                    let y = args[1].as_tensor()?.clone();
                    let params = ctx.own_params().wait()?.tensor()?;
                    let (loss, g) = grad(&params, &x, &y)?;
                    drop(params);
                    Ok(Value::List(vec![
                        Value::Tensor(Tensor::scalar_f32(loss)),
                        Value::Tensor(g),
                    ]))
                })
            }
            None => handler(|ctx, args| {
                let x = args[0].as_tensor()?.clone();
                let y = args[1].as_tensor()?.clone();
                ctx.grad(x, y).wait()
            }),
        };
        // SVGD_FOLLOW: apply params -= lr * update on own device.
        let svgd_follow = handler(|ctx, args| {
            let lr = args[0].f32()?;
            let update = args[1].as_tensor()?.clone();
            ctx.axpy_params(-lr, update).wait()
        });

        // PREDICT (native only): forward on own params, vote-ready (the
        // one-hot/mean convention of `eval::accumulate_prediction`).
        let predict = native.as_ref().map(|(_, forward, _)| {
            let forward = forward.clone();
            handler(move |ctx, args| {
                let x = args[0].as_tensor()?.clone();
                let classify = ctx.model().task == "classify";
                let params = ctx.own_params().wait()?.tensor()?;
                let mut acc = None;
                eval::accumulate_prediction(&mut acc, forward(&params, &x)?, classify);
                eval::finalize_mean(acc, 1, classify)
                    .map(Value::Tensor)
                    .ok_or_else(|| PushError::new("PREDICT produced nothing"))
            })
        });

        let follower_table = || {
            let mut t = vec![
                ("SVGD_STEP".to_string(), svgd_step.clone()),
                ("SVGD_FOLLOW".to_string(), svgd_follow.clone()),
            ];
            if let Some(p) = &predict {
                t.push(("PREDICT".to_string(), p.clone()));
            }
            t.into_iter().collect()
        };
        let init_fn = native.as_ref().map(|(_, _, i)| i.clone());
        let follower_init = init_fn.clone();
        let followers = pd.p_create_n(cfg.particles - 1, |i| CreateOpts {
            receive: follower_table(),
            init_params: follower_init.as_ref().map(|f| f(i + 1)),
            ..CreateOpts::default()
        })?;

        // --- leader --------------------------------------------------------
        // Captures follower pids + kernel artifact path + config; receives
        // SVGD_BATCH(x, y) and performs steps 1-4 of the module docstring.
        let fls = followers.clone();
        let artifact = if cfg.force_native { None } else { pd.svgd_artifact(cfg.particles) };
        let lcfg = cfg.clone();
        let leader_grad = native.as_ref().map(|(g, _, _)| g.clone());
        let svgd_batch = handler(move |ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let y = args[1].as_tensor()?.clone();
            let n = fls.len() + 1;

            // 1. every particle computes its gradient concurrently: ONE
            //    broadcast fan-out (label interned once, counters bumped
            //    once, one scheduling batch) + one join_all barrier
            //    instead of per-follower sends and a serial wait loop.
            //    Futures and the join aggregate are dropped before the
            //    prior term so the extracted gradients are uniquely owned
            //    and the axpy below mutates in place.
            let step_futs = ctx.broadcast(
                &fls,
                "SVGD_STEP",
                vec![Value::Tensor(x.clone()), Value::Tensor(y.clone())],
            );
            let step_joined = PFuture::join_all(&step_futs);
            let mut losses = Vec::with_capacity(n);
            let mut grads: Vec<Tensor> = Vec::with_capacity(n);
            match &leader_grad {
                // Native: the leader's own (loss, grad) comes straight
                // from the closure while the broadcast is in flight; the
                // params snapshot drops with the arm.
                Some(g) => {
                    let params = ctx.own_params().wait()?.tensor()?;
                    let (loss, grad) = g(&params, &x, &y)?;
                    losses.push(loss);
                    grads.push(grad);
                }
                None => {
                    let own = ctx.grad(x.clone(), y.clone());
                    let mut lg = own.wait()?.list()?;
                    losses.push(lg[0].as_tensor()?.scalar());
                    grads.push(lg.remove(1).tensor()?);
                }
            }
            let gathered_steps = step_joined.wait()?;
            drop(step_joined);
            drop(step_futs);
            for lg in gathered_steps.list()? {
                let mut lg = lg.list()?;
                losses.push(lg[0].as_tensor()?.scalar());
                grads.push(lg.remove(1).tensor()?);
            }

            // single-particle degenerate case: plain gradient descent
            if n == 1 {
                ctx.axpy_params(-lcfg.lr, grads.remove(0)).wait()?;
                return Ok(Value::F32(losses[0]));
            }

            // 2. gather every particle's parameters as zero-copy views
            //    (each shares its owner's resident buffer; COW keeps the
            //    snapshot stable if the owner steps meanwhile). join_all
            //    resolves the whole gather at once; dropping the futures
            //    right away matters — they hold view clones that would
            //    otherwise force the scatter's axpy to COW-copy.
            let own_params = ctx.own_params();
            let pfuts: Vec<PFuture> = fls.iter().map(|p| ctx.get(*p)).collect();
            let pjoined = PFuture::join_all(&pfuts);
            let mut params = Vec::with_capacity(n);
            params.push(own_params.wait()?.tensor()?);
            drop(own_params);
            let gathered = pjoined.wait()?;
            drop(pjoined);
            drop(pfuts);
            for v in gathered.list()? {
                params.push(v.tensor()?);
            }

            // Appendix B.1: score-based posterior gradient adds the prior
            // term  -grad log p(theta) = theta / sigma^2. In place: the
            // gradient buffers are uniquely owned here.
            if let Some(std) = lcfg.prior_std {
                let inv_var = 1.0 / (std * std);
                for (g, p) in grads.iter_mut().zip(&params) {
                    crate::runtime::tensor::ops::axpy(g, inv_var, p);
                }
            }

            let h = if lcfg.median_heuristic {
                median_lengthscale(&params)
            } else {
                lcfg.lengthscale
            };

            // 3. kernel-matrix update: Pallas artifact on the leader's
            //    device when available, native O(n^2 d) otherwise. The
            //    [n, d] stacked inputs are built straight from the views —
            //    one allocation each, no per-particle intermediates — and
            //    the artifact's [n, d] output is split into zero-copy row
            //    views for the scatter.
            let updates: Vec<Tensor> = match &artifact {
                Some(path) => {
                    let prows: Vec<&Tensor> = params.iter().collect();
                    let grows: Vec<&Tensor> = grads.iter().collect();
                    let stacked_p = Tensor::stack_rows(&prows);
                    let stacked_g = Tensor::stack_rows(&grows);
                    let h = Tensor::scalar_f32(h);
                    let u = ctx
                        .run_artifact(path.clone(), vec![stacked_p, stacked_g, h])
                        .wait()?
                        .tensor()?;
                    u.unstack_rows()
                }
                None => svgd_update_native(&params, &grads, h)
                    .map_err(|e| PushError::new(format!("{e:#}")))?,
            };

            // Release the gathered views BEFORE the scatter: each particle's
            // cache slot becomes uniquely owned again, so the followers'
            // axpy applies in place instead of forcing a COW copy.
            drop(params);
            drop(grads);

            // 4. scatter: followers apply their rows concurrently; the
            //    leader applies its own. Row views share the single update
            //    buffer (payload accounting still counts d floats per row).
            //    Per-row args keep this on the send path (broadcast ships
            //    ONE shared arg list); the barrier is a single join_all.
            let mut apply_futs = Vec::with_capacity(n);
            let mut it = updates.into_iter();
            let own_update = it.next().expect("leader row");
            for (p, u) in fls.iter().zip(it) {
                apply_futs.push(ctx.send(
                    *p,
                    "SVGD_FOLLOW",
                    vec![Value::F32(lcfg.lr), Value::Tensor(u)],
                ));
            }
            apply_futs.push(ctx.axpy_params(-lcfg.lr, own_update));
            PFuture::join_all(&apply_futs).wait()?;

            let mean_loss = losses.iter().sum::<f32>() / losses.len() as f32;
            Ok(Value::F32(mean_loss))
        });

        let mut leader_table = follower_table();
        leader_table.insert("SVGD_BATCH".to_string(), svgd_batch);
        let leader = pd.p_create(CreateOpts {
            device: Some(0),
            receive: leader_table,
            init_params: init_fn.map(|f| f(0)),
            ..CreateOpts::default()
        })?;

        Ok(Svgd { pd, leader, followers, cfg, native: is_native })
    }

    pub fn pd(&self) -> &PushDist {
        &self.pd
    }

    pub fn leader(&self) -> Pid {
        self.leader
    }

    /// One SVGD step over (x, y); returns the mean loss across particles.
    pub fn step_batch(&self, x: &Tensor, y: &Tensor) -> Result<f64> {
        let v = self
            .pd
            .p_launch(
                self.leader,
                "SVGD_BATCH",
                vec![Value::Tensor(x.clone()), Value::Tensor(y.clone())],
            )
            .wait()
            .map_err(|e| anyhow!("{e}"))?;
        Ok(v.f32().map_err(|e| anyhow!("{e}"))? as f64)
    }
}

impl Infer for Svgd {
    fn name(&self) -> &str {
        "svgd"
    }

    fn pids(&self) -> Vec<Pid> {
        let mut all = vec![self.leader];
        all.extend(&self.followers);
        all
    }

    fn train(&mut self, source: &mut dyn BatchSource, epochs: usize) -> Result<TrainReport> {
        let mut report = TrainReport::new(self.name());
        for _ in 0..epochs {
            let stream = source.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0;
            let mut nb = 0usize;
            for b in stream {
                loss += self.step_batch(&b.x, &b.y)?;
                nb += 1;
            }
            report.push(loss / nb.max(1) as f64, t0.elapsed().as_secs_f64());
        }
        Ok(report)
    }

    /// Posterior-mean prediction: AOT `mean_forward` for artifact models;
    /// for native models, summed class votes (classify) or averaged
    /// particle predictions (regress) via the PREDICT handlers.
    fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        let pids = self.pids();
        if !self.native {
            return self.pd.mean_forward(&pids, x);
        }
        let futs = self.pd.broadcast(&pids, "PREDICT", vec![Value::Tensor(x.clone())]);
        let joined = PFuture::join_all(&futs);
        let preds = joined.wait().map_err(|e| anyhow!("{e}"))?.list().map_err(|e| anyhow!("{e}"))?;
        // Drop the futures before accumulating so the first prediction is
        // uniquely owned and the axpy chain runs in place.
        drop(joined);
        drop(futs);
        fold_predictions(preds, self.pd.model().task == "classify")
    }

    fn nel_stats(&self) -> crate::nel::NelStats {
        self.pd.stats()
    }
}

/// Liu & Wang's median heuristic: h = median(pairwise dist) / sqrt(log n).
pub fn median_lengthscale(params: &[Tensor]) -> f32 {
    let n = params.len();
    if n < 2 {
        return 1.0;
    }
    let mut d2s = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        let pi = params[i].as_f32();
        for j in (i + 1)..n {
            d2s.push(kernels::sq_dist(pi, params[j].as_f32()));
        }
    }
    d2s.sort_by(f32::total_cmp);
    let med2 = d2s[d2s.len() / 2];
    let h2 = med2 / ((n as f32 + 1.0).ln()).max(1e-3);
    h2.sqrt().max(1e-3)
}

/// Native CPU SVGD update, canonical descent form (mirrors
/// `compile/kernels/ref.py::svgd_update_ref`):
///
///   k_ij = exp(-0.5 ||p_i - p_j||^2 / h^2)
///   U_i  = (1/n) sum_j [ k_ij g_j + k_ij (p_j - p_i) / h^2 ]
///
/// Used when no AOT artifact matches (n, d), by the handwritten baseline,
/// and as the oracle in kernel-consistency tests.
pub fn svgd_update_native(params: &[Tensor], grads: &[Tensor], h: f32) -> Result<Vec<Tensor>> {
    let n = params.len();
    if n == 0 || grads.len() != n {
        return Err(anyhow!("svgd_update_native: {} params vs {} grads", n, grads.len()));
    }
    let d = params[0].element_count();
    let h2 = h * h;

    // pairwise squared distances through the kernel plane's fixed-shape
    // row reduction
    let mut k = vec![0.0f32; n * n];
    for i in 0..n {
        k[i * n + i] = 1.0;
        let pi = params[i].as_f32();
        for j in (i + 1)..n {
            let d2 = kernels::sq_dist(pi, params[j].as_f32());
            let kij = (-0.5 * d2 / h2).exp();
            k[i * n + j] = kij;
            k[j * n + i] = kij;
        }
    }

    let inv_h2 = 1.0 / h2;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let pi = params[i].as_f32();
        let mut u = vec![0.0f32; d];
        for j in 0..n {
            let kij = k[i * n + j];
            // u += k_ij g_j + (k_ij / h²)(p_j − p_i), one fused row pass
            kernels::rbf_accum(&mut u, kij, grads[j].as_f32(), kij * inv_h2, params[j].as_f32(), pi);
        }
        kernels::div_scale(&mut u, n as f32);
        out.push(Tensor::f32(vec![d], u));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_far_apart_is_grad_over_n() {
        let p = vec![
            Tensor::f32(vec![2], vec![0.0, 0.0]),
            Tensor::f32(vec![2], vec![1000.0, 1000.0]),
        ];
        let g = vec![
            Tensor::f32(vec![2], vec![2.0, -2.0]),
            Tensor::f32(vec![2], vec![4.0, 4.0]),
        ];
        let u = svgd_update_native(&p, &g, 1.0).unwrap();
        assert!((u[0].as_f32()[0] - 1.0).abs() < 1e-5);
        assert!((u[1].as_f32()[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn native_repulsion_separates_coincident_particles() {
        // zero gradients, two nearly-coincident particles: applying
        // p -= lr * U must push them apart.
        let p = vec![
            Tensor::f32(vec![1], vec![0.0]),
            Tensor::f32(vec![1], vec![0.1]),
        ];
        let g = vec![Tensor::zeros(vec![1]), Tensor::zeros(vec![1])];
        let u = svgd_update_native(&p, &g, 1.0).unwrap();
        // U_0 points toward p_1 (positive); descent moves p_0 away.
        assert!(u[0].as_f32()[0] > 0.0);
        assert!(u[1].as_f32()[0] < 0.0);
    }

    #[test]
    fn native_rejects_mismatch() {
        let p = vec![Tensor::zeros(vec![2])];
        assert!(svgd_update_native(&p, &[], 1.0).is_err());
    }
}
