//! Deep ensembles (Lakshminarayanan et al. 2017) on particles.
//!
//! The no-communication extreme of the paper's spectrum (§3.1): n particles
//! train independently; the only synchronization is the per-batch barrier
//! the driver imposes by waiting on every particle's STEP future (which is
//! what the paper's epoch timing measures).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::BatchSource;
use crate::infer::models::{self, native_sgd_step};
use crate::infer::sgmcmc::ModelSource;
use crate::infer::{eval, Infer, TrainReport};
use crate::nel::CreateOpts;
use crate::particle::{handler, PFuture, PushError, Value};
use crate::pd::PushDist;
use crate::runtime::Tensor;
use crate::Pid;

pub struct DeepEnsemble {
    pd: PushDist,
    pids: Vec<Pid>,
    pub lr: f32,
    /// Use Adam (paper Tables 3/4 protocol) instead of plain SGD.
    pub adam: bool,
    /// Members run a native model source: STEP takes closed-form SGD steps
    /// and prediction goes through the members' PREDICT handlers instead
    /// of the AOT forward artifact.
    native: bool,
}

impl DeepEnsemble {
    /// Create `n` particles, each answering `STEP(x, y, lr)` with one SGD
    /// step on its own device.
    pub fn new(pd: PushDist, n: usize, lr: f32) -> Result<DeepEnsemble> {
        assert!(n > 0);
        let step = handler(|ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let y = args[1].as_tensor()?.clone();
            let lr = args[2].f32()?;
            let adam = matches!(args.get(3), Some(crate::Value::Bool(true)));
            if adam {
                ctx.adam_step(x, y, lr).wait()
            } else {
                ctx.step(x, y, lr).wait()
            }
        });
        let pids = pd.p_create_n(n, |_| CreateOpts {
            receive: [("STEP".to_string(), step.clone())].into_iter().collect(),
            ..CreateOpts::default()
        })?;
        Ok(DeepEnsemble { pd, pids, lr, adam: false, native: false })
    }

    /// An ensemble over a [`ModelSource::Native`]: STEP answers with one
    /// closed-form SGD step (the `adam` flag is ignored — there is no
    /// native Adam), PREDICT with the member's own forward, and creation
    /// takes explicit per-member init params so the whole family is
    /// hermetic (no AOT init/step/fwd artifacts anywhere).
    pub fn new_native(
        pd: PushDist,
        n: usize,
        lr: f32,
        source: &ModelSource,
        init: Arc<dyn Fn(usize) -> Tensor + Send + Sync>,
    ) -> Result<DeepEnsemble> {
        assert!(n > 0);
        let (grad, forward) = match source {
            ModelSource::Native { grad, forward, .. } => (grad.clone(), forward.clone()),
            ModelSource::Artifact => {
                return Err(anyhow!("DeepEnsemble::new_native needs a native model source"))
            }
        };
        let step = handler(move |ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let y = args[1].as_tensor()?.clone();
            let lr = args[2].f32()?;
            let loss = native_sgd_step(ctx, &grad, &x, &y, lr)?;
            Ok(Value::Tensor(Tensor::scalar_f32(loss)))
        });
        let predict = handler(move |ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let classify = ctx.model().task == "classify";
            let params = ctx.own_params().wait()?.tensor()?;
            let mut acc = None;
            eval::accumulate_prediction(&mut acc, forward(&params, &x)?, classify);
            eval::finalize_mean(acc, 1, classify)
                .map(Value::Tensor)
                .ok_or_else(|| PushError::new("PREDICT produced nothing"))
        });
        let pids = pd.p_create_n(n, |i| CreateOpts {
            receive: [
                ("STEP".to_string(), step.clone()),
                ("PREDICT".to_string(), predict.clone()),
            ]
            .into_iter()
            .collect(),
            init_params: Some(init(i)),
            ..CreateOpts::default()
        })?;
        Ok(DeepEnsemble { pd, pids, lr, adam: false, native: true })
    }

    /// Switch the STEP message to Adam updates (native members ignore it
    /// and keep taking plain SGD steps).
    pub fn with_adam(mut self) -> DeepEnsemble {
        self.adam = true;
        self
    }

    pub fn pd(&self) -> &PushDist {
        &self.pd
    }

    /// One synchronized step of every particle on (x, y); returns the mean
    /// loss. Exposed for the benches' per-batch timing. The fan-out is one
    /// `broadcast` (label interned once, one scheduling batch) and the
    /// barrier one `join_all` wait instead of a serial per-future
    /// lock-step.
    pub fn step_all(&self, x: &Tensor, y: &Tensor) -> Result<f64> {
        let futs = self.pd.broadcast(
            &self.pids,
            "STEP",
            vec![
                Value::Tensor(x.clone()),
                Value::Tensor(y.clone()),
                Value::F32(self.lr),
                Value::Bool(self.adam),
            ],
        );
        let losses = PFuture::join_all(&futs)
            .wait()
            .map_err(|e| anyhow!("{e}"))?
            .list()
            .map_err(|e| anyhow!("{e}"))?;
        let mut total = 0.0f64;
        for l in &losses {
            total += l.as_tensor().map_err(|e| anyhow!("{e}"))?.scalar() as f64;
        }
        Ok(total / losses.len() as f64)
    }
}

impl Infer for DeepEnsemble {
    fn name(&self) -> &str {
        "deep_ensemble"
    }

    fn pids(&self) -> Vec<Pid> {
        self.pids.clone()
    }

    fn train(&mut self, source: &mut dyn BatchSource, epochs: usize) -> Result<TrainReport> {
        let mut report = TrainReport::new(self.name());
        for _ in 0..epochs {
            let stream = source.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0;
            let mut nb = 0usize;
            for b in stream {
                loss += self.step_all(&b.x, &b.y)?;
                nb += 1;
            }
            report.push(loss / nb.max(1) as f64, t0.elapsed().as_secs_f64());
        }
        Ok(report)
    }

    /// Ensemble prediction: the AOT `mean_forward` for artifact members;
    /// for native members, summed class votes (classify) or averaged
    /// member predictions (regress) via their PREDICT handlers — the same
    /// vote protocol SWAG and the MCMC reservoir use.
    fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        if !self.native {
            return self.pd.mean_forward(&self.pids, x);
        }
        let futs = self.pd.broadcast(&self.pids, "PREDICT", vec![Value::Tensor(x.clone())]);
        let joined = PFuture::join_all(&futs);
        let preds = joined.wait().map_err(|e| anyhow!("{e}"))?.list().map_err(|e| anyhow!("{e}"))?;
        // Release the futures before accumulating so the first prediction
        // is uniquely owned and the axpy chain runs in place.
        drop(joined);
        drop(futs);
        models::fold_predictions(preds, self.pd.model().task == "classify")
    }

    fn nel_stats(&self) -> crate::nel::NelStats {
        self.pd.stats()
    }
}
