//! SWAG / multi-SWAG (Maddox et al. 2019; Wilson & Izmailov 2020) on
//! particles.
//!
//! Each particle tracks the first and second moments of its own SGD
//! trajectory in its local state (the paper's "augments a deep ensemble
//! with more particle-independent computation", §5.1 — moment tracking is
//! O(P) axpy work on the particle's device, no communication). Prediction
//! draws `n_samples` parameter settings per particle from the diagonal
//! Gaussian N(mean, scale * var) and majority-votes across all samples of
//! all particles (classify) or averages predictions (regress) — the §C.4
//! protocol.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::BatchSource;
use crate::infer::models::{fold_predictions, native_sgd_step};
use crate::infer::sgmcmc::ModelSource;
use crate::infer::{Infer, TrainReport};
use crate::nel::{CreateOpts, ParticleCtx};
use crate::particle::{handler, PFuture, Value};
use crate::pd::PushDist;
use crate::runtime::Tensor;
use crate::util::rng::Rng;
use crate::Pid;

#[derive(Debug, Clone)]
pub struct SwagConfig {
    pub particles: usize,
    pub lr: f32,
    /// Epochs of plain SGD before moment collection starts (paper §C.4:
    /// 7 pretrain + 3 SWAG).
    pub pretrain_epochs: usize,
    /// Posterior draws per particle at prediction time (paper: 5).
    pub n_samples: usize,
    /// Variance scale for the draws (paper: 1e-30, i.e. near-SWA).
    pub scale: f32,
    /// Use Adam updates (the paper's Tables 3/4 protocol; its footnote
    /// recommends vanilla SGD for the SWAG phase — set `pretrain_epochs`
    /// high to mimic that split if desired).
    pub adam: bool,
    pub seed: u64,
}

impl Default for SwagConfig {
    fn default() -> Self {
        SwagConfig {
            particles: 2,
            lr: 1e-2,
            pretrain_epochs: 7,
            n_samples: 5,
            scale: 1e-30,
            adam: false,
            seed: 0,
        }
    }
}

pub struct MultiSwag {
    pd: PushDist,
    pids: Vec<Pid>,
    pub cfg: SwagConfig,
}

const K_N: &str = "swag_n";
const K_MEAN: &str = "swag_mean";
const K_SQ: &str = "swag_sqmean";

/// Running first/second moment update from the particle's current params —
/// the O(P) per-step SWAG bookkeeping, shared by the artifact and native
/// SWAG_STEP handlers.
fn update_moments(ctx: &ParticleCtx) -> Result<(), crate::PushError> {
    let params = ctx.own_params().wait()?.tensor()?;
    let n = match ctx.state_get(K_N) {
        Some(Value::Usize(n)) => n,
        _ => 0,
    };
    let w_old = n as f32 / (n as f32 + 1.0);
    let w_new = 1.0 / (n as f32 + 1.0);
    let mut mean = match ctx.state_take(K_MEAN) {
        Some(Value::Tensor(t)) => t,
        _ => Tensor::zeros(params.shape.clone()),
    };
    let mut sq = match ctx.state_take(K_SQ) {
        Some(Value::Tensor(t)) => t,
        _ => Tensor::zeros(params.shape.clone()),
    };
    crate::runtime::tensor::ops::scale_add(&mut mean, w_old, w_new, &params);
    crate::runtime::tensor::ops::scale_add_sq(&mut sq, w_old, w_new, &params);
    ctx.state_set(K_MEAN, Value::Tensor(mean));
    ctx.state_set(K_SQ, Value::Tensor(sq));
    ctx.state_set(K_N, Value::Usize(n + 1));
    Ok(())
}

/// One diagonal-Gaussian posterior draw:
/// theta = mean + scale * sqrt(max(sq - mean^2, 0)) * eps.
fn draw_theta(mean: &Tensor, sq: &Tensor, scale: f32, rng: &mut Rng) -> Tensor {
    let mut theta = mean.clone();
    let m = mean.as_f32();
    let s = sq.as_f32();
    for (i, t) in theta.as_f32_mut().iter_mut().enumerate() {
        let var = (s[i] - m[i] * m[i]).max(0.0);
        *t = m[i] + scale * var.sqrt() * rng.normal();
    }
    theta
}

impl MultiSwag {
    pub fn new(pd: PushDist, cfg: SwagConfig) -> Result<MultiSwag> {
        assert!(cfg.particles > 0);
        // Optimizer step (pretraining phase): SGD or Adam by message arg.
        let step = handler(|ctx, args| {
            let (x, y) = (args[0].as_tensor()?.clone(), args[1].as_tensor()?.clone());
            let lr = args[2].f32()?;
            if matches!(args.get(3), Some(Value::Bool(true))) {
                ctx.adam_step(x, y, lr).wait()
            } else {
                ctx.step(x, y, lr).wait()
            }
        });
        // SGD step + first/second moment update in particle-local state.
        let swag_step = handler(|ctx, args| {
            let (x, y) = (args[0].as_tensor()?.clone(), args[1].as_tensor()?.clone());
            let lr = args[2].f32()?;
            let loss = if matches!(args.get(3), Some(Value::Bool(true))) {
                ctx.adam_step(x, y, lr).wait()?
            } else {
                ctx.step(x, y, lr).wait()?
            };
            update_moments(ctx)?;
            Ok(loss)
        });
        // Posterior-sample prediction: draw, forward, vote/average, restore.
        let swag_predict = handler(|ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let n_samples = args[1].usize()?;
            let scale = args[2].f32()?;
            let seed = args[3].usize()? as u64;
            let classify = ctx.model().task == "classify";

            // Zero-copy snapshot of the pre-draw parameters; restored at
            // the end by moving the same buffer back (no copies either way).
            let backup = ctx.own_params().wait()?.tensor()?;
            let (mean, sq) = match (ctx.state_get(K_MEAN), ctx.state_get(K_SQ)) {
                (Some(Value::Tensor(m)), Some(Value::Tensor(s))) => (m, s),
                // No moments collected: fall back to the current params
                // (pretrain-only particle == plain ensemble member).
                _ => (backup.clone(), {
                    let mut s = backup.clone();
                    let b = backup.as_f32();
                    for (si, bi) in s.as_f32_mut().iter_mut().zip(b) {
                        *si = bi * bi;
                    }
                    s
                }),
            };
            let mut rng = Rng::new(seed).fold_in(ctx.pid.0 as u64);
            let mut acc: Option<Tensor> = None;
            // The pre-draw params are restored even when a forward fails
            // mid-loop — a transient predict error must never leave the
            // particle running on a posterior draw.
            let mut failure = None;
            for _ in 0..n_samples {
                let theta = draw_theta(&mean, &sq, scale, &mut rng);
                let pred = ctx
                    .set_params(theta)
                    .wait()
                    .and_then(|_| ctx.forward(x.clone()).wait())
                    .and_then(|v| v.tensor());
                match pred {
                    Ok(p) => crate::infer::eval::accumulate_prediction(&mut acc, p, classify),
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                }
            }
            ctx.set_params(backup).wait()?;
            if let Some(e) = failure {
                return Err(e);
            }
            crate::infer::eval::finalize_mean(acc, n_samples, classify)
                .map(Value::Tensor)
                .ok_or_else(|| crate::PushError::new("n_samples == 0"))
        });

        let pids = pd.p_create_n(cfg.particles, |_| CreateOpts {
            receive: [
                ("STEP".to_string(), step.clone()),
                ("SWAG_STEP".to_string(), swag_step.clone()),
                ("SWAG_PREDICT".to_string(), swag_predict.clone()),
            ]
            .into_iter()
            .collect(),
            ..CreateOpts::default()
        })?;
        Ok(MultiSwag { pd, pids, cfg })
    }

    /// Multi-SWAG over a [`ModelSource::Native`]: the optimizer is
    /// closed-form SGD (the `adam` flag is ignored — there is no native
    /// Adam), the moment bookkeeping is byte-identical to the artifact
    /// path, and SWAG_PREDICT evaluates each diagonal-Gaussian draw
    /// directly through the native forward — no set_params/restore
    /// round-trip, so the resident params never move.
    pub fn new_native(
        pd: PushDist,
        cfg: SwagConfig,
        source: &ModelSource,
        init: Arc<dyn Fn(usize) -> Tensor + Send + Sync>,
    ) -> Result<MultiSwag> {
        assert!(cfg.particles > 0);
        let (grad, forward) = match source {
            ModelSource::Native { grad, forward, .. } => (grad.clone(), forward.clone()),
            ModelSource::Artifact => {
                return Err(anyhow!("MultiSwag::new_native needs a native model source"))
            }
        };
        let sgrad = grad.clone();
        let step = handler(move |ctx, args| {
            let (x, y) = (args[0].as_tensor()?.clone(), args[1].as_tensor()?.clone());
            let lr = args[2].f32()?;
            let loss = native_sgd_step(ctx, &sgrad, &x, &y, lr)?;
            Ok(Value::Tensor(Tensor::scalar_f32(loss)))
        });
        let swag_step = handler(move |ctx, args| {
            let (x, y) = (args[0].as_tensor()?.clone(), args[1].as_tensor()?.clone());
            let lr = args[2].f32()?;
            let loss = native_sgd_step(ctx, &grad, &x, &y, lr)?;
            update_moments(ctx)?;
            Ok(Value::Tensor(Tensor::scalar_f32(loss)))
        });
        let swag_predict = handler(move |ctx, args| {
            let x = args[0].as_tensor()?.clone();
            let n_samples = args[1].usize()?;
            let scale = args[2].f32()?;
            let seed = args[3].usize()? as u64;
            let classify = ctx.model().task == "classify";

            let current = ctx.own_params().wait()?.tensor()?;
            let (mean, sq) = match (ctx.state_get(K_MEAN), ctx.state_get(K_SQ)) {
                (Some(Value::Tensor(m)), Some(Value::Tensor(s))) => (m, s),
                // No moments collected: fall back to the current params
                // (pretrain-only particle == plain ensemble member).
                _ => (current.clone(), {
                    let mut s = current.clone();
                    let b = current.as_f32();
                    for (si, bi) in s.as_f32_mut().iter_mut().zip(b) {
                        *si = bi * bi;
                    }
                    s
                }),
            };
            drop(current);
            let mut rng = Rng::new(seed).fold_in(ctx.pid.0 as u64);
            let mut acc: Option<Tensor> = None;
            for _ in 0..n_samples {
                let theta = draw_theta(&mean, &sq, scale, &mut rng);
                let pred = forward(&theta, &x)?;
                crate::infer::eval::accumulate_prediction(&mut acc, pred, classify);
            }
            crate::infer::eval::finalize_mean(acc, n_samples, classify)
                .map(Value::Tensor)
                .ok_or_else(|| crate::PushError::new("n_samples == 0"))
        });

        let pids = pd.p_create_n(cfg.particles, |i| CreateOpts {
            receive: [
                ("STEP".to_string(), step.clone()),
                ("SWAG_STEP".to_string(), swag_step.clone()),
                ("SWAG_PREDICT".to_string(), swag_predict.clone()),
            ]
            .into_iter()
            .collect(),
            init_params: Some(init(i)),
            ..CreateOpts::default()
        })?;
        Ok(MultiSwag { pd, pids, cfg })
    }

    pub fn pd(&self) -> &PushDist {
        &self.pd
    }

    /// Synchronized step of all particles; `collect_moments` selects plain
    /// SGD vs SWAG-moment mode. Returns mean loss. One broadcast fan-out,
    /// one join_all barrier.
    pub fn step_all(&self, x: &Tensor, y: &Tensor, collect_moments: bool) -> Result<f64> {
        let msg = if collect_moments { "SWAG_STEP" } else { "STEP" };
        let futs = self.pd.broadcast(
            &self.pids,
            msg,
            vec![
                Value::Tensor(x.clone()),
                Value::Tensor(y.clone()),
                Value::F32(self.cfg.lr),
                Value::Bool(self.cfg.adam),
            ],
        );
        let losses = PFuture::join_all(&futs)
            .wait()
            .map_err(|e| anyhow!("{e}"))?
            .list()
            .map_err(|e| anyhow!("{e}"))?;
        let mut total = 0.0;
        for l in &losses {
            total += l.as_tensor().map_err(|e| anyhow!("{e}"))?.scalar() as f64;
        }
        Ok(total / losses.len() as f64)
    }

    /// Multi-SWAG prediction: summed class votes (classify) or averaged
    /// predictions (regress) across all samples of all particles.
    pub fn predict_swag(&self, x: &Tensor) -> Result<Tensor> {
        let futs = self.pd.broadcast(
            &self.pids,
            "SWAG_PREDICT",
            vec![
                Value::Tensor(x.clone()),
                Value::Usize(self.cfg.n_samples),
                Value::F32(self.cfg.scale),
                Value::Usize(self.cfg.seed as usize),
            ],
        );
        let joined = PFuture::join_all(&futs);
        let preds = joined.wait().map_err(|e| anyhow!("{e}"))?.list().map_err(|e| anyhow!("{e}"))?;
        // Drop the futures (and the join aggregate) before accumulating:
        // each still holds a clone of its prediction in its Ready state —
        // releasing them leaves the first prediction uniquely owned so the
        // axpy chain runs in place.
        drop(joined);
        drop(futs);
        fold_predictions(preds, self.pd.model().task == "classify")
    }
}

impl Infer for MultiSwag {
    fn name(&self) -> &str {
        "multi_swag"
    }

    fn pids(&self) -> Vec<Pid> {
        self.pids.clone()
    }

    /// `epochs` total: the first `cfg.pretrain_epochs` run plain SGD, the
    /// remainder collect SWAG moments (paper §C.4's 7 + 3 split).
    fn train(&mut self, source: &mut dyn BatchSource, epochs: usize) -> Result<TrainReport> {
        let mut report = TrainReport::new(self.name());
        for e in 0..epochs {
            let collect = e >= self.cfg.pretrain_epochs;
            let stream = source.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0;
            let mut nb = 0usize;
            for b in stream {
                loss += self.step_all(&b.x, &b.y, collect)?;
                nb += 1;
            }
            report.push(loss / nb.max(1) as f64, t0.elapsed().as_secs_f64());
        }
        Ok(report)
    }

    fn predict_mean(&self, x: &Tensor) -> Result<Tensor> {
        self.predict_swag(x)
    }

    fn nel_stats(&self) -> crate::nel::NelStats {
        self.pd.stats()
    }
}
