//! BDL inference algorithms written against the particle abstraction
//! (paper §3.4, Appendix B): deep ensembles, SWAG / multi-SWAG, SVGD, and
//! the stochastic-gradient MCMC family (SGLD / SGHMC with cyclical
//! schedules).
//!
//! Each algorithm is a struct owning a [`PushDist`] whose particles carry
//! the algorithm's message handlers; `train` drives epochs by launching
//! messages and waiting on futures. Every algorithm is agnostic to the
//! number of devices — changing `NelConfig::num_devices` rescales the same
//! code (the property the paper's §B.2 emphasizes).

pub mod ensemble;
pub mod eval;
pub mod models;
pub mod serve;
pub mod sgmcmc;
pub mod svgd;
pub mod swag;

use anyhow::Result;

use crate::data::BatchSource;
use crate::runtime::Tensor;

pub use ensemble::DeepEnsemble;
pub use models::{
    native_manifest, native_model, Activation, Conv1dSpec, MlpSpec, NativeModel,
    NATIVE_MODEL_NAMES,
};
pub use serve::{
    Overloaded, PosteriorServer, PosteriorSnapshot, QueryResult, ReservoirSnapshot, ServeConfig,
    ServeStats, Staleness,
};
pub use sgmcmc::{ModelSource, Schedule, SgMcmc, SgmcmcAlgo, SgmcmcConfig};
pub use svgd::{svgd_update_native, Svgd, SvgdConfig};
pub use swag::{MultiSwag, SwagConfig};

/// Per-epoch training record.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub mean_loss: f64,
    pub secs: f64,
}

/// What `train` returns; consumed by the bench harness and EXPERIMENTS.md.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub algo: String,
    pub epochs: Vec<EpochReport>,
}

impl TrainReport {
    pub fn new(algo: &str) -> TrainReport {
        TrainReport { algo: algo.to_string(), epochs: Vec::new() }
    }

    pub fn push(&mut self, mean_loss: f64, secs: f64) {
        self.epochs.push(EpochReport { mean_loss, secs });
    }

    pub fn final_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.mean_loss).unwrap_or(f64::NAN)
    }

    pub fn mean_epoch_secs(&self) -> f64 {
        if self.epochs.is_empty() {
            return f64::NAN;
        }
        self.epochs.iter().map(|e| e.secs).sum::<f64>() / self.epochs.len() as f64
    }
}

/// The common interface all Push inference algorithms implement (paper's
/// `Infer` base class, Figure 5).
pub trait Infer {
    fn name(&self) -> &str;

    /// Particle ids participating in inference.
    fn pids(&self) -> Vec<crate::Pid>;

    /// Run `epochs` of Bayesian inference over the source's data. Batches
    /// are pulled one at a time through a [`crate::data::BatchStream`], so
    /// a [`crate::data::PrefetchLoader`] overlaps batch materialization
    /// with the round's device compute; a plain `DataLoader` gathers
    /// synchronously. Either way the batch sequence is identical.
    fn train(&mut self, source: &mut dyn BatchSource, epochs: usize) -> Result<TrainReport>;

    /// Posterior-mean prediction at `x` (paper §3.4: the average of
    /// particle predictions).
    fn predict_mean(&self, x: &Tensor) -> Result<Tensor>;

    /// NEL statistics of the backing PD (device busy time, swaps,
    /// messages) — the scaling benches' modeled-makespan source. For a
    /// multi-node PD this is the fabric-wide merge (summed once).
    fn nel_stats(&self) -> crate::nel::NelStats;

    /// Cross-chain convergence diagnostics (split R-hat / ESS over the
    /// particle-chains), when the algorithm keeps posterior samples.
    /// None for non-sampling algorithms; NaN fields (rendered "n/a")
    /// when the chains are not diagnosable yet.
    fn diagnostics(&self) -> Option<eval::ChainDiag> {
        None
    }

    /// Per-node transport frame/byte counters of the backing PD (empty
    /// for algorithms that don't surface them; all-zero in-process).
    fn transport_counters(&self) -> Vec<crate::pd::transport::TransportCounters> {
        Vec::new()
    }
}
