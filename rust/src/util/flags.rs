//! CLI flag parsing for the `push` launcher and the bench binaries.
//!
//! Supports `--key value`, `--key=value`, bare `--switch` booleans, and
//! positional arguments, with typed getters and an auto-generated usage
//! string. No clap in the vendored crate set.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Flags {
    pub positional: Vec<String>,
    named: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Flags {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Flags, String> {
        let mut f = Flags::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates flag parsing
                    f.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    f.named.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    f.named.insert(rest.to_string(), v);
                } else {
                    f.switches.push(rest.to_string());
                }
            } else {
                f.positional.push(a);
            }
        }
        Ok(f)
    }

    pub fn from_env() -> Result<Flags, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.named.contains_key(switch)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.named
            .get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key} expects an integer, got {v:?}")))
            .transpose()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        Ok(self.usize(key)?.unwrap_or(default))
    }

    pub fn f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.named
            .get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key} expects a number, got {v:?}")))
            .transpose()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        Ok(self.f64(key)?.unwrap_or(default))
    }

    /// Comma-separated usize list, e.g. `--particles 1,2,4,8`.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        match self.named.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| format!("--{key} expects ints, got {p:?}"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Unrecognized-key guard for strict CLIs.
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.named.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k} (known: {})", known.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Flags {
        Flags::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn named_and_positional() {
        let f = parse("bench fig4 --devices 4 --particles=1,2,4 --verbose");
        assert_eq!(f.positional, vec!["bench", "fig4"]);
        assert_eq!(f.usize_or("devices", 1).unwrap(), 4);
        assert_eq!(f.usize_list("particles").unwrap().unwrap(), vec![1, 2, 4]);
        assert!(f.has("verbose"));
        assert!(!f.has("quiet"));
    }

    #[test]
    fn eq_form_and_defaults() {
        let f = parse("--lr=0.01");
        assert!((f.f64_or("lr", 0.0).unwrap() - 0.01).abs() < 1e-12);
        assert_eq!(f.usize_or("epochs", 10).unwrap(), 10);
    }

    #[test]
    fn bad_int_reports_key() {
        let f = parse("--devices four");
        let err = f.usize("devices").unwrap_err();
        assert!(err.contains("devices"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let f = parse("a -- --not-a-flag");
        assert_eq!(f.positional, vec!["a", "--not-a-flag"]);
    }

    #[test]
    fn check_known_rejects() {
        let f = parse("--oops 1");
        assert!(f.check_known(&["devices"]).is_err());
        assert!(f.check_known(&["oops"]).is_ok());
    }
}
