//! Descriptive statistics for the bench harness (criterion is not in the
//! vendored crate set, so timing summaries are computed here).

/// Summary of a sample of timings (seconds) or any scalar metric.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, q in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Arithmetic mean (convenience for accuracy tables).
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_singleton() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p90, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.25) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
