//! Deterministic splittable RNG for synthetic data and particle seeds.
//!
//! SplitMix64 core (Steele et al. 2014) with Box–Muller normals. Every
//! dataset/particle derives its stream by folding a label into the seed, so
//! runs are reproducible regardless of device count or scheduling order —
//! the property the scaling benches rely on when comparing configurations.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller output.
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    /// Derive an independent stream (a cheap stand-in for jax.random.fold_in).
    pub fn fold_in(&self, label: u64) -> Rng {
        let mut r = Rng::new(self.state ^ label.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        r.state = r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_in_decorrelates() {
        let base = Rng::new(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
