//! Minimal JSON: recursive-descent parser + pretty printer.
//!
//! Scope: exactly what `artifacts/manifest.json` and the bench-report
//! writers need — objects, arrays, strings (with escapes), f64 numbers,
//! bools, null. No serde in the vendored crate set, so this lives in-repo.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch / missing key) ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Pretty-print with 1-space indent (matches aot.py's json.dump style).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push(' ');
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (manifest is ASCII).
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through intact)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        self.err("invalid utf-8")
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"models": {"m": {"param_count": 1377, "entries": {"fwd": {"file": "m.fwd.hlo.txt", "args": [{"shape": [16, 8], "dtype": "f32"}]}}}}, "svgd": []}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_rejects_fractional() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }
}
