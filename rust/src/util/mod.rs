//! In-repo substrates the offline build cannot pull from crates.io:
//! a JSON parser/printer (manifest + bench reports), a splittable RNG
//! (deterministic synthetic data), descriptive statistics, CLI flag
//! parsing, and a tiny leveled logger.

pub mod flags;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
