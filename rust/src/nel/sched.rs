//! Sharded M:N control-plane scheduler.
//!
//! The paper gives every particle "its own logical thread of execution";
//! the seed implementation made that literal — one OS thread per particle
//! — which caps the system at a few hundred particles (stack memory,
//! spawn latency, context-switch pressure). This module decouples logical
//! particles from OS threads:
//!
//! * **Mailboxes.** Each particle owns a FIFO [`Mailbox`] plus a 4-state
//!   scheduling word (`IDLE / QUEUED / RUNNING / RUNNING_DIRTY`). A push
//!   that finds the mailbox idle enqueues the particle on a run-queue
//!   shard; all other pushes are just a queue append — the current owner
//!   is guaranteed to observe them. Exactly one run-queue reference per
//!   particle can exist (only the `IDLE -> QUEUED` edge enqueues), which
//!   is what makes handler execution **non-reentrant** by construction.
//! * **Worker pool.** A fixed pool of control workers (default
//!   `available_parallelism`, `NelConfig::control_workers` to override)
//!   pops particles from per-worker shards (`pid % shards` is a
//!   particle's home shard) and steals from siblings when its own shard
//!   is dry. Each scheduling turn drains at most [`MAILBOX_BATCH`]
//!   envelopes so one chatty particle cannot starve a shard. Idle
//!   workers park on a condvar (no polling); every enqueue wakes a
//!   sleeper if one exists.
//! * **Dependency-first lane.** A send issued from *inside a handler* is
//!   one whose reply the sender will likely block on. Those targets go to
//!   a global priority lane that every worker drains BEFORE its shard, so
//!   a blocked handler's dependencies always run ahead of fresh root
//!   work and wait DAGs unwind depth-first.
//! * **Blocked-worker compensation + helping.** Handlers may block on
//!   futures (the paper's actor + async-await blend). A worker entering
//!   `PFuture::wait` on a pending future announces itself through the
//!   [`BlockObserver`] hook. While the pool is under its cap
//!   ([`Shared::max_workers`], the tokio `block_in_place` discipline) a
//!   spare is spawned so runnable workers stay at the configured target,
//!   and surplus workers retire after an idle grace period once blockers
//!   resume. At the cap, the blocking worker switches to **helping**: it
//!   runs pending tasks itself between short waits — lane first, then
//!   shards, a full worker turn (bounded nesting, [`MAX_HELP_DEPTH`]) —
//!   so no queued work, lane or shard, can be stranded by blocked
//!   workers no matter how wide or deep the wait DAG is.
//!   Progress invariant: after every block event there is either a
//!   runnable worker or an actively-helping blocked one. Cyclic waits
//!   (A's handler waits on B's while B's waits on A's) still deadlock,
//!   exactly as they did with a thread per particle; the helping
//!   backstop only runs out in the astronomically contrived case of more
//!   than `max_workers * MAX_HELP_DEPTH` simultaneously nested blocking
//!   handlers.
//!
//! Shutdown: dropping the last `Nel` handle fails every undelivered
//! envelope with "NEL shut down" and flags the pool; workers wake and
//! exit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Duration;

use crate::particle::{set_block_observer, BlockObserver, PushError};

use super::trace::{Event, EventKind, Trace};
use super::{Envelope, Nel, NelInner, ParticleEntry};

/// Max envelopes one scheduling turn drains before handing the worker
/// back (fairness under fan-in).
const MAILBOX_BATCH: usize = 16;

/// How long an idle worker parks before re-checking whether it is
/// surplus and should retire. Work arrival wakes parked workers
/// immediately; this is purely the retire-check cadence, so surplus
/// compensation workers linger warm for one grace period and are reused
/// by back-to-back blocking rounds instead of respawning.
const IDLE_PARK: Duration = Duration::from_millis(100);

/// Max nested `help` frames per worker stack (each frame is a full
/// handler run for some other particle).
const MAX_HELP_DEPTH: usize = 32;

thread_local! {
    static HELP_DEPTH: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
    /// Nested blocking-wait frames on this worker (outer wait + waits
    /// inside helped handlers). Only the outermost frame counts toward
    /// `Shared::blocked`, so that gauge means blocked THREADS and the
    /// spawn/retire arithmetic sees true runnable coverage.
    static BLOCK_FRAMES: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

// ---- mailbox ------------------------------------------------------------

const IDLE: u8 = 0;
/// On a run queue (or about to be — the pusher that won the
/// `IDLE -> QUEUED` edge is responsible for enqueueing).
const QUEUED: u8 = 1;
/// A worker owns the mailbox and is draining it.
const RUNNING: u8 = 2;
/// A push landed while RUNNING; the owner must re-check before releasing.
const RUNNING_DIRTY: u8 = 3;

/// Per-particle FIFO message queue plus its scheduling state word.
pub(crate) struct Mailbox {
    queue: Mutex<VecDeque<Envelope>>,
    sched_state: AtomicU8,
    /// Set (under the queue lock) at NEL shutdown; later pushes bounce.
    closed: AtomicBool,
}

pub(crate) enum PushOutcome {
    /// Mailbox went non-empty while idle: the caller must enqueue the
    /// particle on the run queue.
    MustSchedule,
    /// Already queued or running — the current owner will see the message.
    Delivered,
    /// Mailbox closed (NEL shut down); the envelope comes back.
    Closed(Envelope),
}

impl Mailbox {
    pub fn new() -> Mailbox {
        Mailbox {
            queue: Mutex::new(VecDeque::new()),
            sched_state: AtomicU8::new(IDLE),
            closed: AtomicBool::new(false),
        }
    }

    /// Append an envelope. The queue push happens strictly BEFORE the
    /// scheduling-state transition, so an owner that observes its queue
    /// empty and then fails the `RUNNING -> IDLE` release is guaranteed
    /// to find this message on its re-check (no lost wakeups).
    pub fn push(&self, env: Envelope) -> PushOutcome {
        {
            let mut q = self.queue.lock().unwrap();
            if self.closed.load(Ordering::Relaxed) {
                return PushOutcome::Closed(env);
            }
            q.push_back(env);
        }
        loop {
            let s = self.sched_state.load(Ordering::Acquire);
            match s {
                IDLE => {
                    if self
                        .sched_state
                        .compare_exchange(IDLE, QUEUED, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        return PushOutcome::MustSchedule;
                    }
                }
                RUNNING => {
                    if self
                        .sched_state
                        .compare_exchange(
                            RUNNING,
                            RUNNING_DIRTY,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        return PushOutcome::Delivered;
                    }
                }
                // QUEUED / RUNNING_DIRTY: someone is already on the hook.
                _ => return PushOutcome::Delivered,
            }
        }
    }

    /// Close the mailbox and hand back every undelivered envelope
    /// (shutdown path; the caller fails their reply futures).
    pub fn close(&self) -> Vec<Envelope> {
        let mut q = self.queue.lock().unwrap();
        self.closed.store(true, Ordering::Relaxed);
        q.drain(..).collect()
    }

    fn pop(&self) -> Option<Envelope> {
        self.queue.lock().unwrap().pop_front()
    }
}

// ---- scheduler ----------------------------------------------------------

/// Point-in-time scheduler counters, surfaced via `NelStats::sched`.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Configured pool size (runnable-worker target).
    pub pool_target: usize,
    /// Hard cap on live workers (pool + blocked-compensation spares).
    pub max_workers: usize,
    /// Live worker threads right now.
    pub workers_live: usize,
    /// Workers currently blocked inside `PFuture::wait`.
    pub workers_blocked: usize,
    /// High-water mark of live workers.
    pub workers_peak: usize,
    /// Worker threads ever spawned (initial pool + compensation).
    pub spawns: u64,
    /// Surplus workers retired after blockers resumed.
    pub retires: u64,
    /// Spares spawned because a worker blocked mid-handler.
    pub compensations: u64,
    /// Envelopes processed (handler invocations, including missing-handler
    /// errors).
    pub handler_runs: u64,
    /// Scheduling turns (mailbox drains; `handler_runs / turns` is the
    /// effective batching factor).
    pub turns: u64,
    /// Turns served off a foreign shard.
    pub steals: u64,
    /// Turns served off the dependency-first lane.
    pub priority_turns: u64,
    /// Scheduling turns run by BLOCKED workers in helping mode (pool at
    /// its cap: no spare could be spawned).
    pub helps: u64,
}

#[derive(Default)]
struct Counters {
    spawns: AtomicU64,
    retires: AtomicU64,
    compensations: AtomicU64,
    handler_runs: AtomicU64,
    turns: AtomicU64,
    steals: AtomicU64,
    priority_turns: AtomicU64,
    helps: AtomicU64,
}

pub(crate) struct Shared {
    me: Weak<Shared>,
    nel: Weak<NelInner>,
    trace: Trace,
    shards: Vec<Mutex<VecDeque<Arc<ParticleEntry>>>>,
    /// Dependency-first lane: particles activated by a mid-handler send.
    /// Drained before any shard by every worker, and by blocked workers
    /// in helping mode.
    priority: Mutex<VecDeque<Arc<ParticleEntry>>>,
    /// Count of workers parked on `idle_cv`. Guarded by its own mutex so
    /// the register-then-recheck sleep protocol has no lost wakeups.
    idle: Mutex<usize>,
    idle_cv: Condvar,
    shutdown: AtomicBool,
    pool_target: usize,
    max_workers: usize,
    next_worker_id: AtomicUsize,
    /// Live worker threads (monotonic id space is `next_worker_id`).
    spawned: AtomicUsize,
    /// Workers currently inside a blocking `wait`.
    blocked: AtomicUsize,
    peak: AtomicUsize,
    c: Counters,
}

pub(crate) struct Scheduler {
    shared: Arc<Shared>,
}

impl Scheduler {
    /// Build the pool and spawn `pool_target` workers. `nel` is the
    /// (still-cyclic) back-reference workers use to run handlers.
    pub fn new(pool_target: usize, nel: Weak<NelInner>, trace: Trace) -> Scheduler {
        let pool_target = pool_target.max(1);
        // Compensation headroom: how many spares may back-fill blocked
        // workers (tokio's blocking-thread cap, scaled to the pool).
        // Beyond it, blocked workers switch to helping.
        let max_workers = pool_target * 4 + 4;
        let shards = (0..pool_target).map(|_| Mutex::new(VecDeque::new())).collect();
        let shared = Arc::new_cyclic(|me| Shared {
            me: me.clone(),
            nel,
            trace,
            shards,
            priority: Mutex::new(VecDeque::new()),
            idle: Mutex::new(0),
            idle_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            pool_target,
            max_workers,
            next_worker_id: AtomicUsize::new(0),
            spawned: AtomicUsize::new(0),
            blocked: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            c: Counters::default(),
        });
        for _ in 0..pool_target {
            shared.spawn_worker(false);
        }
        Scheduler { shared }
    }

    /// Enqueue one newly-runnable particle. `dependency_first` (sends
    /// issued mid-handler) routes it to the priority lane — see the module
    /// docs for why that keeps bounded compensation deadlock-free.
    pub fn schedule(&self, entry: Arc<ParticleEntry>, dependency_first: bool) {
        if dependency_first {
            self.shared.schedule_priority(entry);
        } else {
            self.shared.schedule(entry);
        }
    }

    /// Enqueue a batch of newly-runnable particles: one lock acquisition
    /// per *shard* (or one lane extend) and one sleeper sweep, not one
    /// wakeup per particle — the fan-out path.
    pub fn schedule_batch(&self, entries: Vec<Arc<ParticleEntry>>, dependency_first: bool) {
        if entries.is_empty() {
            return;
        }
        let many = entries.len() > 1;
        if dependency_first {
            self.shared.priority.lock().unwrap().extend(entries);
        } else {
            let n = self.shared.shards.len();
            let mut buckets: Vec<Vec<Arc<ParticleEntry>>> = (0..n).map(|_| Vec::new()).collect();
            for e in entries {
                buckets[e.pid.0 as usize % n].push(e);
            }
            for (i, b) in buckets.into_iter().enumerate() {
                if !b.is_empty() {
                    self.shared.shards[i].lock().unwrap().extend(b);
                }
            }
        }
        if many {
            self.shared.wake_all();
        } else {
            self.shared.wake_one();
        }
    }

    /// Flag the pool down and wake every sleeper. Called from
    /// `NelInner::drop` AFTER all mailboxes are closed and drained.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all();
    }

    pub fn stats(&self) -> SchedStats {
        let sh = &self.shared;
        SchedStats {
            pool_target: sh.pool_target,
            max_workers: sh.max_workers,
            workers_live: sh.spawned.load(Ordering::Acquire),
            workers_blocked: sh.blocked.load(Ordering::Acquire),
            workers_peak: sh.peak.load(Ordering::Acquire),
            spawns: sh.c.spawns.load(Ordering::Relaxed),
            retires: sh.c.retires.load(Ordering::Relaxed),
            compensations: sh.c.compensations.load(Ordering::Relaxed),
            handler_runs: sh.c.handler_runs.load(Ordering::Relaxed),
            turns: sh.c.turns.load(Ordering::Relaxed),
            steals: sh.c.steals.load(Ordering::Relaxed),
            priority_turns: sh.c.priority_turns.load(Ordering::Relaxed),
            helps: sh.c.helps.load(Ordering::Relaxed),
        }
    }
}

impl Shared {
    fn spawn_worker(self: &Arc<Self>, compensation: bool) -> bool {
        let id = self.next_worker_id.fetch_add(1, Ordering::Relaxed);
        let live = self.spawned.fetch_add(1, Ordering::AcqRel) + 1;
        self.peak.fetch_max(live, Ordering::AcqRel);
        self.c.spawns.fetch_add(1, Ordering::Relaxed);
        if compensation {
            self.c.compensations.fetch_add(1, Ordering::Relaxed);
        }
        self.trace.record(Event::new(0, None, EventKind::WorkerSpawn, 0));
        let shared = self.clone();
        let ok = std::thread::Builder::new()
            .name(format!("nel-worker-{id}"))
            .spawn(move || worker_loop(shared, id))
            .is_ok();
        if !ok {
            self.spawned.fetch_sub(1, Ordering::AcqRel);
            crate::log_error!("nel scheduler: failed to spawn worker {id}");
        }
        ok
    }

    /// Wake one parked worker, if any. Pushers call this AFTER releasing
    /// the queue lock (idle and queue locks never nest pusher-side).
    fn wake_one(&self) {
        let sleepers = self.idle.lock().unwrap();
        if *sleepers > 0 {
            self.idle_cv.notify_one();
        }
    }

    fn wake_all(&self) {
        let _guard = self.idle.lock().unwrap();
        self.idle_cv.notify_all();
    }

    fn schedule(&self, entry: Arc<ParticleEntry>) {
        let i = entry.pid.0 as usize % self.shards.len();
        self.shards[i].lock().unwrap().push_back(entry);
        self.wake_one();
    }

    fn schedule_priority(&self, entry: Arc<ParticleEntry>) {
        self.priority.lock().unwrap().push_back(entry);
        self.wake_one();
    }

    /// Pop the dependency-first lane, then the home shard, then steal
    /// round-robin from the siblings. Returns the task and whether it
    /// came off the priority lane (its requeue destination).
    fn find_task(&self, home: usize) -> Option<(Arc<ParticleEntry>, bool)> {
        if let Some(e) = self.priority.lock().unwrap().pop_front() {
            self.c.priority_turns.fetch_add(1, Ordering::Relaxed);
            return Some((e, true));
        }
        if let Some(e) = self.shards[home].lock().unwrap().pop_front() {
            return Some((e, false));
        }
        let n = self.shards.len();
        for k in 1..n {
            let i = (home + k) % n;
            if let Some(e) = self.shards[i].lock().unwrap().pop_front() {
                self.c.steals.fetch_add(1, Ordering::Relaxed);
                return Some((e, false));
            }
        }
        None
    }

    /// Cheap emptiness probe used by the sleep protocol (called with the
    /// idle lock held; queue locks are only ever taken after it on this
    /// path, and pushers never hold a queue lock while taking idle).
    fn have_work(&self) -> bool {
        if !self.priority.lock().unwrap().is_empty() {
            return true;
        }
        self.shards.iter().any(|s| !s.lock().unwrap().is_empty())
    }

    /// Retire when removing this worker still leaves `pool_target`
    /// runnable workers (surplus from blocked-worker compensation).
    fn try_retire(&self) -> bool {
        loop {
            let s = self.spawned.load(Ordering::Acquire);
            let b = self.blocked.load(Ordering::Acquire);
            if s.saturating_sub(b) <= self.pool_target {
                return false;
            }
            if self
                .spawned
                .compare_exchange(s, s - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.c.retires.fetch_add(1, Ordering::Relaxed);
                self.trace.record(Event::new(0, None, EventKind::WorkerRetire, 0));
                return true;
            }
        }
    }

    /// Run one particle off a run queue for the scheduler (a worker's
    /// normal turn, or a blocked worker helping). Returns false when no
    /// task was available.
    fn run_one(&self, home: usize) -> bool {
        let Some((entry, from_priority)) = self.find_task(home) else {
            return false;
        };
        self.c.turns.fetch_add(1, Ordering::Relaxed);
        let requeue = match self.nel.upgrade() {
            Some(inner) => {
                let nel = Nel { inner };
                run_mailbox(&nel, &entry, &self.c)
            }
            None => {
                // NEL gone mid-flight: fail whatever is queued.
                for env in entry.mailbox.close() {
                    env.reply.complete(Err(PushError::new("NEL shut down")));
                }
                false
            }
        };
        if requeue {
            // Keep dependency work visible to helpers: anything that came
            // off the lane goes back on the lane.
            if from_priority {
                self.schedule_priority(entry);
            } else {
                self.schedule(entry);
            }
        }
        true
    }
}

impl BlockObserver for Shared {
    /// A worker is about to block inside a handler. Back-fill the pool so
    /// runnable workers stay at `pool_target`; at the `max_workers` cap,
    /// return false — the caller then helps drain the dependency lane
    /// between waits, which is what makes wait DAGs of any width safe.
    fn block_begin(&self) -> bool {
        let outermost = BLOCK_FRAMES.with(|c| {
            let n = c.get();
            c.set(n + 1);
            n == 0
        });
        if outermost {
            self.blocked.fetch_add(1, Ordering::AcqRel);
        }
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return true;
            }
            let s = self.spawned.load(Ordering::Acquire);
            let b = self.blocked.load(Ordering::Acquire);
            if s.saturating_sub(b) >= self.pool_target {
                return true;
            }
            if s >= self.max_workers {
                return false;
            }
            match self.me.upgrade() {
                Some(me) => {
                    if !me.spawn_worker(true) {
                        // cannot grow (OS limit): fall back to helping
                        return false;
                    }
                }
                None => return true,
            }
        }
    }

    fn block_end(&self) {
        let outermost = BLOCK_FRAMES.with(|c| {
            let n = c.get() - 1;
            c.set(n);
            n == 0
        });
        if outermost {
            self.blocked.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// One helping turn for a blocked worker: run one pending task —
    /// lane first, then shards, exactly like a runnable worker's turn
    /// (`run_one`). Draining shards too matters: a dependency that is
    /// already QUEUED on a shard (scheduled earlier by a driver send, or
    /// put back by the fairness requeue) would otherwise be invisible to
    /// helpers and strand behind a saturated pool. Nested helping is
    /// bounded — each frame is a full handler run on this worker's stack.
    fn help(&self) -> bool {
        let depth = HELP_DEPTH.with(|d| d.get());
        if depth >= MAX_HELP_DEPTH {
            return false;
        }
        HELP_DEPTH.with(|d| d.set(depth + 1));
        let ran = self.run_one(0);
        HELP_DEPTH.with(|d| d.set(depth));
        if ran {
            self.c.helps.fetch_add(1, Ordering::Relaxed);
        }
        ran
    }
}

/// Drain one particle's mailbox (up to `MAILBOX_BATCH` envelopes).
/// Returns true when the particle must be re-enqueued.
fn run_mailbox(nel: &Nel, entry: &Arc<ParticleEntry>, c: &Counters) -> bool {
    let mb = &entry.mailbox;
    // We hold the only run-queue reference, so we own the QUEUED state.
    mb.sched_state.store(RUNNING, Ordering::Release);
    let mut drained = 0;
    while let Some(env) = mb.pop() {
        nel.process_envelope(entry, env);
        c.handler_runs.fetch_add(1, Ordering::Relaxed);
        drained += 1;
        if drained >= MAILBOX_BATCH {
            // Fairness yield: keep ownership as QUEUED and go back to the
            // run queue. Racing pushers see QUEUED and stay out.
            mb.sched_state.store(QUEUED, Ordering::Release);
            return true;
        }
    }
    // Queue observed empty: release unless a push raced in after our last
    // pop (it would have flipped RUNNING -> RUNNING_DIRTY).
    match mb
        .sched_state
        .compare_exchange(RUNNING, IDLE, Ordering::AcqRel, Ordering::Acquire)
    {
        Ok(_) => false,
        Err(_) => {
            mb.sched_state.store(QUEUED, Ordering::Release);
            true
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    let home = id % shared.shards.len();
    set_block_observer(Some(shared.clone() as Arc<dyn BlockObserver>));
    let mut retired = false;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        if shared.run_one(home) {
            continue;
        }
        // Nothing runnable: park. Register as a sleeper, re-check for
        // work that raced in (pushers bump queues BEFORE peeking the
        // sleeper count, and never hold a queue lock while doing so — so
        // this recheck-under-idle-lock cannot miss a wakeup), then wait.
        let mut sleepers = shared.idle.lock().unwrap();
        if shared.shutdown.load(Ordering::Acquire) || shared.have_work() {
            continue;
        }
        *sleepers += 1;
        let (guard, res) = shared.idle_cv.wait_timeout(sleepers, IDLE_PARK).unwrap();
        sleepers = guard;
        *sleepers -= 1;
        let timed_out = res.timed_out();
        drop(sleepers);
        // A full quiet park with surplus capacity = this compensation
        // worker is no longer needed (grace period: back-to-back blocking
        // rounds reuse warm spares instead of respawning threads).
        if timed_out && shared.try_retire() {
            retired = true;
            break;
        }
    }
    if !retired {
        shared.spawned.fetch_sub(1, Ordering::AcqRel);
    }
    set_block_observer(None);
}
