//! NEL event trace — the instrumentation behind the paper's Figure 3b
//! timeline (message send, context switch / swap, dispatch, future
//! resolution). Disabled by default; `push trace` and the quickstart enable
//! it to print a two-particle interaction timeline.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::particle::Pid;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A message was enqueued to a particle's mailbox.
    MsgSend,
    /// A scheduler worker began one of the particle's handlers.
    HandlerStart,
    HandlerEnd,
    /// A compute job began executing on a device stream.
    JobStart,
    JobEnd,
    /// Active-set context switches (paper §4.2).
    SwapIn,
    SwapOut,
    /// Cross-device parameter view / message payload movement.
    Transfer,
    /// Particle lifecycle.
    Create,
    /// Handler panic / failure surfaced to a future.
    Error,
    /// Control-plane worker lifecycle (nel::sched): pool growth from
    /// blocked-worker compensation and surplus retirement.
    WorkerSpawn,
    WorkerRetire,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::MsgSend => "msg_send",
            EventKind::HandlerStart => "handler_start",
            EventKind::HandlerEnd => "handler_end",
            EventKind::JobStart => "job_start",
            EventKind::JobEnd => "job_end",
            EventKind::SwapIn => "swap_in",
            EventKind::SwapOut => "swap_out",
            EventKind::Transfer => "transfer",
            EventKind::Create => "create",
            EventKind::Error => "error",
            EventKind::WorkerSpawn => "worker_spawn",
            EventKind::WorkerRetire => "worker_retire",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Event {
    /// Microseconds since trace start (filled by `Trace::record`).
    pub t_us: u64,
    pub device: usize,
    pub pid: Option<Pid>,
    pub kind: EventKind,
    pub bytes: usize,
    /// Shared label (message name, usually). `Arc<str>` so the NEL can
    /// attach the same interned label to many events without per-event
    /// String allocations on the send hot path.
    pub note: Option<Arc<str>>,
}

impl Event {
    pub fn new(device: usize, pid: Option<Pid>, kind: EventKind, bytes: usize) -> Event {
        Event { t_us: 0, device, pid, kind, bytes, note: None }
    }

    pub fn with_note(mut self, note: impl Into<Arc<str>>) -> Event {
        self.note = Some(note.into());
        self
    }

    /// The note text, or "" when unset.
    pub fn note_str(&self) -> &str {
        self.note.as_deref().unwrap_or("")
    }
}

struct TraceInner {
    start: Instant,
    events: Mutex<Vec<Event>>,
    cap: usize,
}

/// Cheap-to-clone handle; a disabled trace records nothing.
#[derive(Clone)]
pub struct Trace {
    inner: Option<Arc<TraceInner>>,
}

impl Trace {
    pub fn enabled(cap: usize) -> Trace {
        Trace {
            inner: Some(Arc::new(TraceInner {
                start: Instant::now(),
                events: Mutex::new(Vec::new()),
                cap,
            })),
        }
    }

    pub fn disabled() -> Trace {
        Trace { inner: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    pub fn record(&self, mut e: Event) {
        if let Some(inner) = &self.inner {
            e.t_us = inner.start.elapsed().as_micros() as u64;
            let mut evs = inner.events.lock().unwrap();
            if evs.len() < inner.cap {
                evs.push(e);
            }
        }
    }

    pub fn snapshot(&self) -> Vec<Event> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => inner.events.lock().unwrap().clone(),
        }
    }

    pub fn len(&self) -> usize {
        self.inner
            .as_ref()
            .map(|i| i.events.lock().unwrap().len())
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render a Figure-3b-style textual timeline.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("    t(us)  dev  particle  event          bytes  note\n");
        for e in self.snapshot() {
            let pid = e
                .pid
                .map(|p| format!("{p}"))
                .unwrap_or_else(|| "-".to_string());
            out.push_str(&format!(
                "{:>9}  {:>3}  {:>8}  {:<13} {:>6}  {}\n",
                e.t_us,
                e.device,
                pid,
                e.kind.name(),
                e.bytes,
                e.note_str()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let t = Trace::disabled();
        t.record(Event::new(0, None, EventKind::MsgSend, 0));
        assert_eq!(t.len(), 0);
        assert!(!t.is_enabled());
    }

    #[test]
    fn enabled_records_in_order() {
        let t = Trace::enabled(16);
        t.record(Event::new(0, Some(Pid(1)), EventKind::MsgSend, 10));
        t.record(Event::new(1, Some(Pid(2)), EventKind::SwapIn, 20));
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert!(evs[0].t_us <= evs[1].t_us);
        assert_eq!(evs[1].kind, EventKind::SwapIn);
        assert!(t.to_text().contains("swap_in"));
    }

    #[test]
    fn cap_bounds_memory() {
        let t = Trace::enabled(3);
        for i in 0..10 {
            t.record(Event::new(i, None, EventKind::JobStart, 0));
        }
        assert_eq!(t.len(), 3);
    }
}
