//! The node event loop (paper §4.2): particle-to-device mapping, mailboxes,
//! context-switch dispatch, and the messaging semantics of §3.2.
//!
//! Execution model (maps the paper's Figure 3b onto threads):
//!
//! * Each **particle** gets a *control thread* processing its mailbox
//!   sequentially — the particle's "own logical thread of execution".
//!   Handlers run here and MAY block on futures (actor + async-await
//!   blend).
//! * Each **device** runs a *stream thread* (device::DevicePool) executing
//!   compute jobs FIFO — the paper's "launch a thread to dispatch NN
//!   computations" (T4c). Compute jobs never block on futures, so device
//!   streams cannot deadlock; the context switch (active-set swap) happens
//!   here, exactly when a job touches a non-resident particle.
//! * Parameters are owned by the device layer (resident cache or host
//!   store); every access is a job on the owning particle's device, so
//!   FIFO ordering per device serializes parameter access without locks.
//!
//! Deadlock discipline for handlers: waits must form a DAG (the shipped
//! algorithms use a leader/follower pattern — the leader waits on
//! followers, never the reverse while holding a resource).

pub mod trace;

use std::collections::BTreeMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock, Weak};

use anyhow::{anyhow, Result};

use crate::device::{CostModel, DeviceConfig, DevicePool, DeviceStats};
use crate::particle::{Handler, HandlerTable, PFuture, PResult, Pid, PushError, Value};
use crate::runtime::{ModelSpec, Tensor};
use trace::{Event, EventKind, Trace};

/// NEL configuration (paper API: `num_devices`, `cache_size`, `view_size`).
#[derive(Debug, Clone)]
pub struct NelConfig {
    pub num_devices: usize,
    /// Active-set slots per device.
    pub cache_size: usize,
    /// View-buffer slots per device (paper §B.2). Tracked for accounting;
    /// views are materialized host-side copies in this implementation.
    pub view_size: usize,
    /// Device memory budget in bytes.
    pub mem_budget: usize,
    pub cost: CostModel,
    /// Record a Figure-3b event trace (bounded).
    pub trace: bool,
    /// Serialize all device streams through one lock (measurement mode for
    /// 1-core hosts; see device::DeviceConfig::serialize).
    pub serialize_streams: bool,
    /// Base seed for particle parameter initialization.
    pub seed: u64,
}

impl Default for NelConfig {
    fn default() -> Self {
        NelConfig {
            num_devices: 1,
            cache_size: 4,
            view_size: 4,
            mem_budget: 2 << 30,
            cost: CostModel::default(),
            trace: false,
            serialize_streams: false,
            seed: 0,
        }
    }
}

/// Aggregate messaging counters (device compute counters live in
/// device::DeviceStats).
#[derive(Debug, Default)]
pub struct NelCounters {
    pub msgs_sent: AtomicU64,
    pub msgs_cross_device: AtomicU64,
    pub msg_payload_bytes: AtomicU64,
    pub handler_errors: AtomicU64,
}

#[derive(Debug, Clone, Default)]
pub struct NelStats {
    pub msgs_sent: u64,
    pub msgs_cross_device: u64,
    pub msg_payload_bytes: u64,
    pub handler_errors: u64,
    pub devices: Vec<DeviceStats>,
}

struct Envelope {
    /// Message label, interned once per `send` and shared (refcount bumps)
    /// with every trace event it decorates — the old `String` form cloned
    /// the label three times per send.
    msg: Arc<str>,
    args: Vec<Value>,
    reply: PFuture,
}

pub(crate) struct ParticleEntry {
    pub pid: Pid,
    pub device: usize,
    pub model: Arc<ModelSpec>,
    pub handlers: Arc<HandlerTable>,
    pub state: Arc<Mutex<BTreeMap<String, Value>>>,
    tx: Sender<Envelope>,
}

pub(crate) struct NelInner {
    pool: DevicePool,
    pub trace: Trace,
    particles: RwLock<BTreeMap<Pid, Arc<ParticleEntry>>>,
    next_pid: AtomicU32,
    counters: NelCounters,
    cfg: NelConfig,
}

/// Handle to the node event loop. Clone freely; the NEL shuts down when the
/// last handle drops (control threads exit when their mailboxes close).
#[derive(Clone)]
pub struct Nel {
    inner: Arc<NelInner>,
}

/// Options for particle creation (paper: `p_create(..., device=, receive=,
/// state=)`).
#[derive(Default)]
pub struct CreateOpts {
    /// Pin to a device; default round-robin by pid.
    pub device: Option<usize>,
    pub receive: HandlerTable,
    pub state: Vec<(String, Value)>,
    /// Skip parameter initialization (moment/scratch particles that only
    /// carry state — the multi-SWAG-as-particles encoding, §C.2).
    pub no_params: bool,
}

impl Nel {
    pub fn new(cfg: NelConfig) -> Result<Nel> {
        let trace = if cfg.trace { Trace::enabled(1 << 20) } else { Trace::disabled() };
        let dev_cfg = DeviceConfig {
            cache_size: cfg.cache_size,
            mem_budget: cfg.mem_budget,
            cost: cfg.cost.clone(),
            serialize: cfg
                .serialize_streams
                .then(|| std::sync::Arc::new(std::sync::Mutex::new(()))),
        };
        let pool = DevicePool::new(cfg.num_devices, dev_cfg, trace.clone())?;
        Ok(Nel {
            inner: Arc::new(NelInner {
                pool,
                trace,
                particles: RwLock::new(BTreeMap::new()),
                next_pid: AtomicU32::new(0),
                counters: NelCounters::default(),
                cfg,
            }),
        })
    }

    pub fn config(&self) -> &NelConfig {
        &self.inner.cfg
    }

    pub fn num_devices(&self) -> usize {
        self.inner.pool.len()
    }

    pub fn trace(&self) -> &Trace {
        &self.inner.trace
    }

    pub fn particle_ids(&self) -> Vec<Pid> {
        self.inner.particles.read().unwrap().keys().copied().collect()
    }

    pub fn device_of(&self, pid: Pid) -> Option<usize> {
        self.inner.particles.read().unwrap().get(&pid).map(|e| e.device)
    }

    fn entry(&self, pid: Pid) -> Result<Arc<ParticleEntry>, PushError> {
        self.inner
            .particles
            .read()
            .unwrap()
            .get(&pid)
            .cloned()
            .ok_or_else(|| PushError::new(format!("unknown particle {pid}")))
    }

    /// Create a particle of `model`, initialize its parameters on its
    /// device (via the model's AOT `init` entry), register handlers, and
    /// start its control thread. Returns the new pid immediately — device
    /// FIFO ordering makes later jobs see the initialized parameters.
    pub fn p_create(&self, model: Arc<ModelSpec>, opts: CreateOpts) -> Result<Pid> {
        let pid = Pid(self.inner.next_pid.fetch_add(1, Ordering::Relaxed));
        let device = match opts.device {
            Some(d) => {
                if d >= self.num_devices() {
                    return Err(anyhow!("device {d} out of range (have {})", self.num_devices()));
                }
                d
            }
            None => pid.0 as usize % self.num_devices(),
        };
        self.inner
            .trace
            .record(Event::new(device, Some(pid), EventKind::Create, 0));

        let (tx, rx) = channel::<Envelope>();
        let entry = Arc::new(ParticleEntry {
            pid,
            device,
            model: model.clone(),
            handlers: Arc::new(opts.receive),
            state: Arc::new(Mutex::new(opts.state.into_iter().collect())),
            tx,
        });
        self.inner.particles.write().unwrap().insert(pid, entry.clone());

        if !opts.no_params {
            // Initialize parameters on the particle's device; the job
            // inserts into the host store, first use swaps in.
            let init = model.entry("init")?.clone();
            let seed = self.inner.cfg.seed;
            self.submit_job(device, move |ctx| {
                let key = Tensor::u32(vec![2], vec![(seed & 0xffff_ffff) as u32, pid.0]);
                let outs = ctx.runtime.execute(&init.file, &[key])?;
                let params = outs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("init returned nothing"))?;
                ctx.host.insert(pid, params);
                Ok(Value::Unit)
            });
        }

        self.spawn_control_thread(entry, rx);
        Ok(pid)
    }

    fn spawn_control_thread(&self, entry: Arc<ParticleEntry>, rx: Receiver<Envelope>) {
        let weak: Weak<NelInner> = Arc::downgrade(&self.inner);
        let pid = entry.pid;
        let device = entry.device;
        let model = entry.model.clone();
        let handlers = entry.handlers.clone();
        let state = entry.state.clone();
        // The control thread must NOT keep `entry` alive (it holds the
        // mailbox sender; holding it would prevent shutdown).
        drop(entry);
        std::thread::Builder::new()
            .name(format!("particle-{}", pid.0))
            .spawn(move || {
                while let Ok(env) = rx.recv() {
                    let Some(inner) = weak.upgrade() else {
                        env.reply.complete(Err(PushError::new("NEL shut down")));
                        break;
                    };
                    let nel = Nel { inner };
                    nel.inner.trace.record(
                        Event::new(device, Some(pid), EventKind::HandlerStart, 0)
                            .with_note(env.msg.clone()),
                    );
                    let ctx = ParticleCtx {
                        pid,
                        device,
                        nel: nel.clone(),
                        model: model.clone(),
                        state: state.clone(),
                    };
                    let result = match handlers.get(&*env.msg) {
                        None => Err(PushError::new(format!(
                            "particle {pid} has no handler for {:?}",
                            env.msg
                        ))),
                        Some(h) => run_handler(h, &ctx, &env.args),
                    };
                    if result.is_err() {
                        nel.inner.counters.handler_errors.fetch_add(1, Ordering::Relaxed);
                        nel.inner.trace.record(
                            Event::new(device, Some(pid), EventKind::Error, 0)
                                .with_note(env.msg.clone()),
                        );
                    }
                    nel.inner.trace.record(
                        Event::new(device, Some(pid), EventKind::HandlerEnd, 0)
                            .with_note(env.msg.clone()),
                    );
                    env.reply.complete(result);
                    // `nel` (strong ref) drops here — no permanent cycle.
                }
            })
            .expect("spawning particle control thread");
    }

    /// Asynchronously send `msg` to `pid` (paper: `particle.send` /
    /// `p_launch`). Returns the future of the handler's result.
    ///
    /// The label is interned into one `Arc<str>` shared by the envelope and
    /// every trace event; tensor payloads ride along as zero-copy clones,
    /// with `payload` counting their logical bytes for the transfer model.
    pub fn send(&self, from_device: Option<usize>, to: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        let entry = match self.entry(to) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let msg: Arc<str> = Arc::from(msg);
        let payload: usize = args
            .iter()
            .map(|v| match v {
                Value::Tensor(t) => t.size_bytes(),
                _ => 0,
            })
            .sum();
        self.inner.counters.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.inner
            .counters
            .msg_payload_bytes
            .fetch_add(payload as u64, Ordering::Relaxed);
        if let Some(fd) = from_device {
            if fd != entry.device {
                self.inner.counters.msgs_cross_device.fetch_add(1, Ordering::Relaxed);
                if payload > 0 {
                    // Cross-device payload movement charged on the receiver.
                    let cost = self.inner.cfg.cost.clone();
                    self.submit_job(entry.device, move |ctx| {
                        cost.charge_transfer(payload, ctx.stats);
                        Ok(Value::Unit)
                    });
                }
            }
        }
        self.inner.trace.record(
            Event::new(entry.device, Some(to), EventKind::MsgSend, payload)
                .with_note(msg.clone()),
        );
        let reply = PFuture::new();
        let env = Envelope {
            msg,
            args,
            reply: reply.clone(),
        };
        if entry.tx.send(env).is_err() {
            return PFuture::ready(Err(PushError::new(format!(
                "particle {to} mailbox closed"
            ))));
        }
        reply
    }

    /// Submit a compute job to a device stream, completing `reply` with its
    /// result. Low-level; prefer the typed wrappers below.
    fn submit_job<F>(&self, device: usize, f: F) -> PFuture
    where
        F: FnOnce(&mut crate::device::DeviceCtx<'_>) -> Result<Value> + Send + 'static,
    {
        let reply = PFuture::new();
        let r2 = reply.clone();
        let trace = self.inner.trace.clone();
        let res = self.inner.pool.device(device).submit(Box::new(move |ctx| {
            trace.record(Event::new(ctx.device_id, None, EventKind::JobStart, 0));
            let out = std::panic::catch_unwind(AssertUnwindSafe(|| f(ctx)))
                .unwrap_or_else(|p| Err(anyhow!("compute job panicked: {}", panic_msg(p.as_ref()))));
            trace.record(Event::new(ctx.device_id, None, EventKind::JobEnd, 0));
            r2.complete(out.map_err(PushError::from));
        }));
        if let Err(e) = res {
            reply.complete(Err(PushError::from(e)));
        }
        reply
    }

    /// Run a model entry (fwd/grad/step/...) for `pid` on its device. The
    /// particle's flat parameter vector is prepended as the first argument;
    /// if `write_back` is given, that output index replaces the parameters.
    pub fn run_entry(
        &self,
        pid: Pid,
        entry_name: &'static str,
        extra_args: Vec<Tensor>,
        write_back: Option<usize>,
    ) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let spec = match entry.model.entry(entry_name) {
            Ok(s) => s.clone(),
            Err(e) => return PFuture::ready(Err(PushError::from(e))),
        };
        self.submit_job(entry.device, move |ctx| {
            // Perf (EXPERIMENTS.md §Perf L3): move the resident parameter
            // tensor out of its cache slot for the call instead of cloning
            // it — saves one param-sized memcpy per step. The slot is
            // restored (or replaced by the written-back output) before the
            // job ends, so the single-authority invariant holds: no other
            // job can interleave on this device stream.
            let slot = ctx.params_mut(pid)?;
            let params = std::mem::replace(slot, Tensor::f32(vec![0], vec![]));
            let mut args = Vec::with_capacity(1 + extra_args.len());
            args.push(params);
            args.extend(extra_args);
            let result = ctx.runtime.execute(&spec.file, &args);
            let mut outs = match result {
                Ok(o) => o,
                Err(e) => {
                    // restore the moved-out parameters on failure
                    *ctx.params_mut(pid)? = args.into_iter().next().unwrap();
                    return Err(e);
                }
            };
            let restore = match write_back {
                Some(ix) if ix < outs.len() => outs.remove(ix),
                Some(ix) => {
                    *ctx.params_mut(pid)? = args.into_iter().next().unwrap();
                    return Err(anyhow!(
                        "entry {entry_name} has {} outputs, cannot write back #{ix}",
                        outs.len()
                    ));
                }
                None => args.into_iter().next().unwrap(),
            };
            *ctx.params_mut(pid)? = restore;
            let vals: Vec<Value> = outs.into_iter().map(Value::Tensor).collect();
            Ok(match vals.len() {
                1 => vals.into_iter().next().unwrap(),
                _ => Value::List(vals),
            })
        })
    }

    /// One Adam step (paper Tables 3/4 protocol: Adam, lr 1e-3). The
    /// optimizer moments m/v and step count live in the particle's local
    /// state and ride along to its device each step; the AOT `adam` entry
    /// computes the update with bias correction.
    pub fn run_adam(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let spec = match entry.model.entry("adam") {
            Ok(s) => s.clone(),
            Err(e) => return PFuture::ready(Err(PushError::from(e))),
        };
        let state = entry.state.clone();
        self.submit_job(entry.device, move |ctx| {
            let slot = ctx.params_mut(pid)?;
            let params = std::mem::replace(slot, Tensor::f32(vec![0], vec![]));
            let d = params.element_count();
            let (m, v, t) = {
                let mut st = state.lock().unwrap();
                let m = match st.remove("adam_m") {
                    Some(Value::Tensor(t)) => t,
                    _ => Tensor::zeros(vec![d]),
                };
                let v = match st.remove("adam_v") {
                    Some(Value::Tensor(t)) => t,
                    _ => Tensor::zeros(vec![d]),
                };
                let t = match st.get("adam_t") {
                    Some(Value::Usize(n)) => *n,
                    _ => 0,
                };
                (m, v, t)
            };
            let args = [
                params,
                m,
                v,
                Tensor::scalar_f32((t + 1) as f32),
                x,
                y,
                Tensor::scalar_f32(lr),
            ];
            let outs = match ctx.runtime.execute(&spec.file, &args) {
                Ok(o) => o,
                Err(e) => {
                    *ctx.params_mut(pid)? = args.into_iter().next().unwrap();
                    return Err(e);
                }
            };
            let mut it = outs.into_iter();
            let loss = it.next().ok_or_else(|| anyhow!("adam: no loss"))?;
            let new_flat = it.next().ok_or_else(|| anyhow!("adam: no params"))?;
            let new_m = it.next().ok_or_else(|| anyhow!("adam: no m"))?;
            let new_v = it.next().ok_or_else(|| anyhow!("adam: no v"))?;
            *ctx.params_mut(pid)? = new_flat;
            {
                let mut st = state.lock().unwrap();
                st.insert("adam_m".into(), Value::Tensor(new_m));
                st.insert("adam_v".into(), Value::Tensor(new_v));
                st.insert("adam_t".into(), Value::Usize(t + 1));
            }
            Ok(Value::Tensor(loss))
        })
    }

    /// Execute an arbitrary artifact on `device` (SVGD kernel updates).
    pub fn run_artifact(
        &self,
        device: usize,
        path: std::path::PathBuf,
        args: Vec<Tensor>,
    ) -> PFuture {
        self.submit_job(device, move |ctx| {
            let outs = ctx.runtime.execute(&path, &args)?;
            let vals: Vec<Value> = outs.into_iter().map(Value::Tensor).collect();
            Ok(match vals.len() {
                1 => vals.into_iter().next().unwrap(),
                _ => Value::List(vals),
            })
        })
    }

    /// Read-only view of a particle's parameters (paper: `get` + `view`).
    /// Runs on the owner's device; cross-device requests charge a transfer.
    /// The returned tensor is a zero-copy COW snapshot: it shares the
    /// resident buffer until either side writes.
    pub fn get_params(&self, requester_device: Option<usize>, pid: Pid) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let cost = self.inner.cfg.cost.clone();
        let cross = requester_device.map(|rd| rd != entry.device).unwrap_or(false);
        self.submit_job(entry.device, move |ctx| {
            let t = ctx.params_view(pid)?;
            if cross {
                cost.charge_transfer(t.size_bytes(), ctx.stats);
                ctx.trace.record(
                    Event::new(ctx.device_id, Some(pid), EventKind::Transfer, t.size_bytes()),
                );
            }
            Ok(Value::Tensor(t))
        })
    }

    /// Overwrite a particle's parameters.
    pub fn set_params(&self, pid: Pid, t: Tensor) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        self.submit_job(entry.device, move |ctx| {
            let params = ctx.params_mut(pid)?;
            if params.shape != t.shape {
                return Err(anyhow!(
                    "set_params shape mismatch: particle has {:?}, got {:?}",
                    params.shape,
                    t.shape
                ));
            }
            *params = t;
            Ok(Value::Unit)
        })
    }

    /// In-place `params += alpha * update` on the particle's device (the
    /// apply step of SVGD_FOLLOW and SWAG averaging).
    pub fn axpy_params(&self, pid: Pid, alpha: f32, update: Tensor) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        self.submit_job(entry.device, move |ctx| {
            let params = ctx.params_mut(pid)?;
            if params.element_count() != update.element_count() {
                return Err(anyhow!(
                    "axpy length mismatch: {} vs {}",
                    params.element_count(),
                    update.element_count()
                ));
            }
            crate::runtime::tensor::ops::axpy(params, alpha, &update);
            Ok(Value::Unit)
        })
    }

    /// Barrier: wait until every device has drained its queue, then flush
    /// all resident particles to the host store and return a snapshot of
    /// every particle's parameters. The snapshot tensors share storage
    /// with the store (zero-copy); a later `axpy_params`/`set_params` on a
    /// particle COW-detaches, so snapshots stay immutable.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        let n = self.num_devices();
        let futs: Vec<PFuture> = (0..n)
            .map(|d| {
                self.submit_job(d, move |ctx| {
                    ctx.cache.flush_all(ctx.host);
                    Ok(Value::Unit)
                })
            })
            .collect();
        PFuture::wait_all(&futs)?;
        let mut out = BTreeMap::new();
        for pid in self.particle_ids() {
            if let Some(t) = self.inner.pool.host.get_clone(pid) {
                out.insert(pid, t);
            }
        }
        Ok(out)
    }

    /// Aggregate statistics. Each device answers its stats request on its
    /// own stream (device::Msg::Stats), which drains FIFO behind every
    /// previously submitted job — an implicit per-device barrier, so
    /// counters from jobs whose futures already resolved are guaranteed
    /// visible without extra barrier jobs or per-job publication.
    pub fn stats(&self) -> NelStats {
        let c = &self.inner.counters;
        NelStats {
            msgs_sent: c.msgs_sent.load(Ordering::Relaxed),
            msgs_cross_device: c.msgs_cross_device.load(Ordering::Relaxed),
            msg_payload_bytes: c.msg_payload_bytes.load(Ordering::Relaxed),
            handler_errors: c.handler_errors.load(Ordering::Relaxed),
            devices: self.inner.pool.stats(),
        }
    }
}

fn run_handler(h: &Handler, ctx: &ParticleCtx, args: &[Value]) -> PResult {
    std::panic::catch_unwind(AssertUnwindSafe(|| h(ctx, args)))
        .unwrap_or_else(|p| Err(PushError::new(format!("handler panicked: {}", panic_msg(p.as_ref())))))
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// The context a handler executes with — the paper's `particle` argument
/// (Figure 1): local state access plus messaging.
pub struct ParticleCtx {
    pub pid: Pid,
    pub device: usize,
    nel: Nel,
    model: Arc<ModelSpec>,
    state: Arc<Mutex<BTreeMap<String, Value>>>,
}

impl ParticleCtx {
    pub fn nel(&self) -> &Nel {
        &self.nel
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// All particle ids in the NEL (paper: `particle.particle_ids()`).
    pub fn particle_ids(&self) -> Vec<Pid> {
        self.nel.particle_ids()
    }

    /// Other particles' ids (the common filter in the paper's listings).
    pub fn other_particles(&self) -> Vec<Pid> {
        self.particle_ids().into_iter().filter(|p| *p != self.pid).collect()
    }

    /// Async send (paper: `particle.send(pid, msg, *args)`).
    pub fn send(&self, to: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.nel.send(Some(self.device), to, msg, args)
    }

    /// Async read-only view of another particle's parameters (paper:
    /// `particle.get(pid)` + `.view()`).
    pub fn get(&self, pid: Pid) -> PFuture {
        self.nel.get_params(Some(self.device), pid)
    }

    /// This particle's own parameters (no transfer charge).
    pub fn own_params(&self) -> PFuture {
        self.nel.get_params(None, self.pid)
    }

    /// One SGD step on (x, y): runs the model's AOT `step` entry on this
    /// particle's device, writes back parameters, resolves to the loss.
    pub fn step(&self, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel
            .run_entry(self.pid, "step", vec![x, y, Tensor::scalar_f32(lr)], Some(1))
    }

    /// One Adam step (moments in particle state); resolves to the loss.
    pub fn adam_step(&self, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel.run_adam(self.pid, x, y, lr)
    }

    /// Forward pass; resolves to the prediction tensor.
    pub fn forward(&self, x: Tensor) -> PFuture {
        self.nel.run_entry(self.pid, "fwd", vec![x], None)
    }

    /// Loss + flat gradient; resolves to List[loss, grad].
    pub fn grad(&self, x: Tensor, y: Tensor) -> PFuture {
        self.nel.run_entry(self.pid, "grad", vec![x, y], None)
    }

    pub fn set_params(&self, t: Tensor) -> PFuture {
        self.nel.set_params(self.pid, t)
    }

    pub fn axpy_params(&self, alpha: f32, update: Tensor) -> PFuture {
        self.nel.axpy_params(self.pid, alpha, update)
    }

    /// Execute an arbitrary AOT artifact on this particle's device (the
    /// SVGD leader runs the L1 kernel artifact this way).
    pub fn run_artifact(&self, path: std::path::PathBuf, args: Vec<Tensor>) -> PFuture {
        self.nel.run_artifact(self.device, path, args)
    }

    // ---- local user state (paper: `state=` at p_create) ----
    pub fn state_get(&self, key: &str) -> Option<Value> {
        self.state.lock().unwrap().get(key).cloned()
    }

    pub fn state_set(&self, key: &str, v: Value) {
        self.state.lock().unwrap().insert(key.to_string(), v);
    }

    pub fn state_take(&self, key: &str) -> Option<Value> {
        self.state.lock().unwrap().remove(key)
    }
}
