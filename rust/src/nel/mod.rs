//! The node event loop (paper §4.2): particle-to-device mapping, mailboxes,
//! context-switch dispatch, and the messaging semantics of §3.2.
//!
//! Execution model (maps the paper's Figure 3b onto threads):
//!
//! * Each **particle** keeps "its own logical thread of execution" — a
//!   FIFO mailbox whose handlers run sequentially and never concurrently
//!   with themselves — but particles are multiplexed M:N onto a fixed
//!   pool of control workers by the sharded scheduler in [`sched`]
//!   (thread-per-particle capped the system at a few hundred particles).
//!   Handlers MAY block on futures (actor + async-await blend); a blocked
//!   worker is compensated for by a bounded spare so the pool never
//!   starves.
//! * Each **device** runs a *stream thread* (device::DevicePool) executing
//!   compute jobs FIFO — the paper's "launch a thread to dispatch NN
//!   computations" (T4c). Compute jobs never block on futures, so device
//!   streams cannot deadlock; the context switch (active-set swap) happens
//!   here, exactly when a job touches a non-resident particle.
//! * Parameters are owned by the device layer (resident cache or host
//!   store); every access is a job on the owning particle's device, so
//!   FIFO ordering per device serializes parameter access without locks.
//!
//! Deadlock discipline for handlers: waits must form a DAG (the shipped
//! algorithms use a leader/follower pattern — the leader waits on
//! followers, never the reverse while holding a resource). Non-cyclic
//! wait DAGs of any width and depth make progress on a bounded pool: the
//! dependency-first lane plus blocked-worker helping (see sched's module
//! docs) guarantee a blocked handler's dependencies always get run.

mod sched;
pub mod trace;

pub use sched::SchedStats;

use std::collections::BTreeMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use crate::device::{CostModel, DeviceConfig, DevicePool, DeviceStats};
use crate::particle::{Handler, HandlerTable, PFuture, PResult, Pid, PushError, Value};
use crate::runtime::{ModelSpec, Tensor};
use trace::{Event, EventKind, Trace};

/// NEL configuration (paper API: `num_devices`, `cache_size`, `view_size`).
#[derive(Debug, Clone)]
pub struct NelConfig {
    pub num_devices: usize,
    /// Active-set slots per device.
    pub cache_size: usize,
    /// View-buffer slots per device (paper §B.2). Tracked for accounting;
    /// views are materialized host-side copies in this implementation.
    pub view_size: usize,
    /// Device memory budget in bytes.
    pub mem_budget: usize,
    pub cost: CostModel,
    /// Record a Figure-3b event trace (bounded).
    pub trace: bool,
    /// Serialize all device streams through one lock (measurement mode for
    /// 1-core hosts; see device::DeviceConfig::serialize).
    pub serialize_streams: bool,
    /// Control workers in the M:N particle scheduler (0 = one per
    /// available CPU). OS thread count stays O(workers + devices)
    /// regardless of particle count.
    pub control_workers: usize,
    /// Base seed for particle parameter initialization.
    pub seed: u64,
    /// Node id when this NEL is one node of a multi-node fabric
    /// (DESIGN.md §Distributed NEL). Only used to label unknown-particle
    /// errors so a handler-side send to a remote pid says WHY it failed:
    /// particles are registered node-locally, and cross-node traffic must
    /// route through the PD fabric, not through a node's own NEL.
    pub node: Option<usize>,
}

impl Default for NelConfig {
    fn default() -> Self {
        NelConfig {
            num_devices: 1,
            cache_size: 4,
            view_size: 4,
            mem_budget: 2 << 30,
            cost: CostModel::default(),
            trace: false,
            serialize_streams: false,
            control_workers: 0,
            seed: 0,
            node: None,
        }
    }
}

/// Aggregate messaging counters (device compute counters live in
/// device::DeviceStats).
#[derive(Debug, Default)]
pub struct NelCounters {
    pub msgs_sent: AtomicU64,
    pub msgs_cross_device: AtomicU64,
    pub msg_payload_bytes: AtomicU64,
    pub handler_errors: AtomicU64,
}

#[derive(Debug, Clone, Default)]
pub struct NelStats {
    pub msgs_sent: u64,
    pub msgs_cross_device: u64,
    pub msg_payload_bytes: u64,
    pub handler_errors: u64,
    pub sched: SchedStats,
    pub devices: Vec<DeviceStats>,
}

impl NelStats {
    /// Sum per-node stats into ONE fabric-wide view. This is the single
    /// aggregation point multi-node reports go through — summing here and
    /// never again is what keeps bench rows from double-counting when a
    /// run spans nodes. Counters add; scheduler gauges (pool target, cap,
    /// live/blocked/peak workers) add across nodes (each node owns a
    /// disjoint worker pool, so totals are exact and per-node peaks sum
    /// to an upper bound of the simultaneous fabric peak); device stats
    /// concatenate in node order, so per-device breakdowns survive.
    pub fn merged<'a>(parts: impl IntoIterator<Item = &'a NelStats>) -> NelStats {
        let mut out = NelStats::default();
        for s in parts {
            out.msgs_sent += s.msgs_sent;
            out.msgs_cross_device += s.msgs_cross_device;
            out.msg_payload_bytes += s.msg_payload_bytes;
            out.handler_errors += s.handler_errors;
            out.sched.pool_target += s.sched.pool_target;
            out.sched.max_workers += s.sched.max_workers;
            out.sched.workers_live += s.sched.workers_live;
            out.sched.workers_blocked += s.sched.workers_blocked;
            out.sched.workers_peak += s.sched.workers_peak;
            out.sched.spawns += s.sched.spawns;
            out.sched.retires += s.sched.retires;
            out.sched.compensations += s.sched.compensations;
            out.sched.handler_runs += s.sched.handler_runs;
            out.sched.turns += s.sched.turns;
            out.sched.steals += s.sched.steals;
            out.sched.priority_turns += s.sched.priority_turns;
            out.sched.helps += s.sched.helps;
            out.devices.extend(s.devices.iter().cloned());
        }
        out
    }
}

pub(crate) struct Envelope {
    /// Message label, interned once per `send` (once per *fan-out* for
    /// `broadcast`) and shared (refcount bumps) with every trace event it
    /// decorates — the old `String` form cloned the label three times per
    /// send.
    pub(crate) msg: Arc<str>,
    pub(crate) args: Vec<Value>,
    pub(crate) reply: PFuture,
}

pub(crate) struct ParticleEntry {
    pub pid: Pid,
    pub device: usize,
    pub model: Arc<ModelSpec>,
    pub handlers: Arc<HandlerTable>,
    pub state: Arc<Mutex<BTreeMap<String, Value>>>,
    mailbox: sched::Mailbox,
}

pub(crate) struct NelInner {
    sched: sched::Scheduler,
    pool: DevicePool,
    pub trace: Trace,
    particles: RwLock<BTreeMap<Pid, Arc<ParticleEntry>>>,
    next_pid: AtomicU32,
    counters: NelCounters,
    cfg: NelConfig,
}

impl Drop for NelInner {
    /// Runs when the last `Nel` handle drops. A worker mid-handler holds a
    /// temporary strong ref (the ctx's `Nel`), so no handler can be
    /// running here: every worker is idle. Fail the undelivered envelopes,
    /// then flag the pool down — workers exit at their next poll tick.
    fn drop(&mut self) {
        for entry in self.particles.get_mut().unwrap().values() {
            for env in entry.mailbox.close() {
                env.reply.complete(Err(PushError::new("NEL shut down")));
            }
        }
        self.sched.shutdown();
    }
}

/// Handle to the node event loop. Clone freely; the NEL shuts down when the
/// last handle drops (undelivered messages fail, the worker pool winds
/// down).
#[derive(Clone)]
pub struct Nel {
    inner: Arc<NelInner>,
}

/// Options for particle creation (paper: `p_create(..., device=, receive=,
/// state=)`).
#[derive(Default)]
pub struct CreateOpts {
    /// Register under this pid instead of the NEL's own allocator — the
    /// node-local half of fabric-assigned GLOBAL pids: in a multi-node
    /// run the PD fabric is the sole pid authority, so a particle's pid
    /// (and every (seed, pid, step) deterministic stream keyed by it) is
    /// the same no matter which node it lands on. The NEL's allocator is
    /// kept ahead of externally assigned pids, so mixing both modes on
    /// one NEL cannot collide.
    pub pid: Option<Pid>,
    /// Pin to a device; default round-robin by pid.
    pub device: Option<usize>,
    pub receive: HandlerTable,
    pub state: Vec<(String, Value)>,
    /// Skip parameter initialization (moment/scratch particles that only
    /// carry state — the multi-SWAG-as-particles encoding, §C.2).
    pub no_params: bool,
    /// Caller-provided initial parameters: inserted into the host store
    /// directly instead of running the model's AOT `init` entry. Makes
    /// particle creation hermetic (no artifacts, no PJRT) — the SGMCMC
    /// native-model path and checkpoint-restore flows rely on this.
    /// Takes precedence over both the init artifact and `no_params`.
    pub init_params: Option<Tensor>,
}

impl Nel {
    pub fn new(cfg: NelConfig) -> Result<Nel> {
        let trace = if cfg.trace { Trace::enabled(1 << 20) } else { Trace::disabled() };
        let dev_cfg = DeviceConfig {
            cache_size: cfg.cache_size,
            mem_budget: cfg.mem_budget,
            cost: cfg.cost.clone(),
            serialize: cfg
                .serialize_streams
                .then(|| std::sync::Arc::new(std::sync::Mutex::new(()))),
        };
        let pool = DevicePool::new(cfg.num_devices, dev_cfg, trace.clone())?;
        let workers = match cfg.control_workers {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            n => n,
        };
        // The scheduler's workers run handlers through a Weak back-ref so
        // the pool cannot keep the NEL alive (new_cyclic hands us the Weak
        // before the strong handle exists; upgrades fail until `new`
        // returns, which is fine — nothing is scheduled yet).
        let inner = Arc::new_cyclic(|weak| NelInner {
            sched: sched::Scheduler::new(workers, weak.clone(), trace.clone()),
            pool,
            trace,
            particles: RwLock::new(BTreeMap::new()),
            next_pid: AtomicU32::new(0),
            counters: NelCounters::default(),
            cfg,
        });
        Ok(Nel { inner })
    }

    pub fn config(&self) -> &NelConfig {
        &self.inner.cfg
    }

    pub fn num_devices(&self) -> usize {
        self.inner.pool.len()
    }

    pub fn trace(&self) -> &Trace {
        &self.inner.trace
    }

    pub fn particle_ids(&self) -> Vec<Pid> {
        self.inner.particles.read().unwrap().keys().copied().collect()
    }

    pub fn device_of(&self, pid: Pid) -> Option<usize> {
        self.inner.particles.read().unwrap().get(&pid).map(|e| e.device)
    }

    /// The unknown-pid error, labeled with this NEL's node when it is one
    /// node of a fabric: a remote pid is not a bug in the pid, it is a
    /// routing fact — node NELs only know node-local particles.
    fn unknown_particle(&self, pid: Pid) -> PushError {
        match self.inner.cfg.node {
            Some(n) => PushError::new(format!(
                "unknown particle {pid} on node {n} (particles register node-locally; \
                 cross-node sends route through the PD fabric)"
            )),
            None => PushError::new(format!("unknown particle {pid}")),
        }
    }

    fn entry(&self, pid: Pid) -> Result<Arc<ParticleEntry>, PushError> {
        self.inner
            .particles
            .read()
            .unwrap()
            .get(&pid)
            .cloned()
            .ok_or_else(|| self.unknown_particle(pid))
    }

    /// Create a particle of `model`, initialize its parameters on its
    /// device (via the model's AOT `init` entry), and register handlers.
    /// Creation is O(1) bookkeeping — a mailbox, a map insert, and (unless
    /// `no_params`) one init job — no OS thread is spawned; the M:N
    /// scheduler runs the particle's handlers on its shared worker pool.
    /// Returns the new pid immediately — device FIFO ordering makes later
    /// jobs see the initialized parameters.
    pub fn p_create(&self, model: Arc<ModelSpec>, opts: CreateOpts) -> Result<Pid> {
        let pid = match opts.pid {
            Some(p) => {
                // External (fabric) pid: keep the local allocator strictly
                // ahead so NEL-allocated pids can never collide with it.
                self.inner.next_pid.fetch_max(p.0 + 1, Ordering::Relaxed);
                if self.inner.particles.read().unwrap().contains_key(&p) {
                    return Err(anyhow!("particle {p} already registered on this node"));
                }
                p
            }
            None => Pid(self.inner.next_pid.fetch_add(1, Ordering::Relaxed)),
        };
        let device = match opts.device {
            Some(d) => {
                if d >= self.num_devices() {
                    return Err(anyhow!("device {d} out of range (have {})", self.num_devices()));
                }
                d
            }
            None => pid.0 as usize % self.num_devices(),
        };
        self.inner
            .trace
            .record(Event::new(device, Some(pid), EventKind::Create, 0));

        let entry = Arc::new(ParticleEntry {
            pid,
            device,
            model: model.clone(),
            handlers: Arc::new(opts.receive),
            state: Arc::new(Mutex::new(opts.state.into_iter().collect())),
            mailbox: sched::Mailbox::new(),
        });
        self.inner.particles.write().unwrap().insert(pid, entry);

        if let Some(t) = opts.init_params {
            // Direct insert: the pid is brand new, so nothing can be
            // resident anywhere — single authority holds trivially.
            self.inner.pool.host.insert(pid, t);
        } else if !opts.no_params {
            // Initialize parameters on the particle's device; the job
            // inserts into the host store, first use swaps in.
            let init = model.entry("init")?.clone();
            let seed = self.inner.cfg.seed;
            self.submit_job(device, move |ctx| {
                let key = Tensor::u32(vec![2], vec![(seed & 0xffff_ffff) as u32, pid.0]);
                let outs = ctx.runtime.execute(&init.file, &[key])?;
                let params = outs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("init returned nothing"))?;
                ctx.host.insert(pid, params);
                Ok(Value::Unit)
            });
        }
        Ok(pid)
    }

    /// Run one envelope's handler for `entry`. Called by scheduler workers
    /// only, with the particle's mailbox in the RUNNING state — the
    /// scheduler guarantees no two invocations for one particle overlap.
    pub(crate) fn process_envelope(&self, entry: &ParticleEntry, env: Envelope) {
        let (pid, device) = (entry.pid, entry.device);
        self.inner.trace.record(
            Event::new(device, Some(pid), EventKind::HandlerStart, 0)
                .with_note(env.msg.clone()),
        );
        let ctx = ParticleCtx {
            pid,
            device,
            nel: self.clone(),
            model: entry.model.clone(),
            state: entry.state.clone(),
        };
        let result = match entry.handlers.get(&*env.msg) {
            None => Err(PushError::new(format!(
                "particle {pid} has no handler for {:?}",
                env.msg
            ))),
            Some(h) => run_handler(h, &ctx, &env.args),
        };
        if result.is_err() {
            self.inner.counters.handler_errors.fetch_add(1, Ordering::Relaxed);
            self.inner.trace.record(
                Event::new(device, Some(pid), EventKind::Error, 0).with_note(env.msg.clone()),
            );
        }
        self.inner.trace.record(
            Event::new(device, Some(pid), EventKind::HandlerEnd, 0).with_note(env.msg.clone()),
        );
        env.reply.complete(result);
    }

    /// Asynchronously send `msg` to `pid` (paper: `particle.send` /
    /// `p_launch`). Returns the future of the handler's result.
    ///
    /// The label is interned into one `Arc<str>` shared by the envelope and
    /// every trace event; tensor payloads ride along as zero-copy clones,
    /// with `payload` counting their logical bytes for the transfer model.
    ///
    /// Delivery happens BEFORE any accounting: a send to a dead particle
    /// (closed mailbox) must not bump the messaging counters or charge a
    /// phantom cross-device transfer — it used to do both.
    pub fn send(
        &self,
        from_device: Option<usize>,
        to: Pid,
        msg: &str,
        args: Vec<Value>,
    ) -> PFuture {
        let entry = match self.entry(to) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let msg: Arc<str> = Arc::from(msg);
        let payload: usize = args
            .iter()
            .map(|v| match v {
                Value::Tensor(t) => t.size_bytes(),
                _ => 0,
            })
            .sum();
        let reply = PFuture::new();
        let env = Envelope { msg: msg.clone(), args, reply: reply.clone() };
        let outcome = entry.mailbox.push(env);
        if matches!(outcome, sched::PushOutcome::Closed(_)) {
            return PFuture::ready(Err(PushError::new(format!(
                "particle {to} mailbox closed"
            ))));
        }
        // Delivery succeeded: account + trace BEFORE making the particle
        // runnable, so a timeline's msg_send precedes its handler_start
        // whenever the mailbox was idle.
        self.inner.counters.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.inner
            .counters
            .msg_payload_bytes
            .fetch_add(payload as u64, Ordering::Relaxed);
        if let Some(fd) = from_device {
            if fd != entry.device {
                self.inner.counters.msgs_cross_device.fetch_add(1, Ordering::Relaxed);
                if payload > 0 {
                    // Cross-device payload movement charged on the receiver.
                    let cost = self.inner.cfg.cost.clone();
                    self.submit_job(entry.device, move |ctx| {
                        cost.charge_transfer(payload, ctx.stats);
                        Ok(Value::Unit)
                    });
                }
            }
        }
        self.inner.trace.record(
            Event::new(entry.device, Some(to), EventKind::MsgSend, payload)
                .with_note(msg.clone()),
        );
        if matches!(outcome, sched::PushOutcome::MustSchedule) {
            // Sends from inside a handler go dependency-first: the sender
            // will likely block on this reply (sched docs).
            let from_handler = crate::particle::on_scheduler_worker();
            self.inner.sched.schedule(entry.clone(), from_handler);
        }
        reply
    }

    /// Batched fan-out: send `msg` with (shared clones of) `args` to every
    /// pid in `pids`, returning their reply futures in input order. One
    /// label intern, one counter bump, one particle-map pass, one schedule
    /// batch, and one transfer-charge job per destination device — where a
    /// `send` loop pays each of those per message. Unknown pids yield
    /// ready-error futures in their slot; they don't disturb accounting.
    pub fn broadcast(
        &self,
        from_device: Option<usize>,
        pids: &[Pid],
        msg: &str,
        args: Vec<Value>,
    ) -> Vec<PFuture> {
        if pids.is_empty() {
            return Vec::new();
        }
        let msg: Arc<str> = Arc::from(msg);
        let payload: usize = args
            .iter()
            .map(|v| match v {
                Value::Tensor(t) => t.size_bytes(),
                _ => 0,
            })
            .sum();

        // Resolve every target under ONE read lock. For large fan-outs,
        // merge-join the (sorted) request list against the BTreeMap's
        // ordered iterator — O(n + m) total instead of n map probes.
        let entries: Vec<Option<Arc<ParticleEntry>>> = {
            let map = self.inner.particles.read().unwrap();
            if pids.len() >= 8 && pids.len() * 4 >= map.len() {
                let mut order: Vec<(Pid, usize)> =
                    pids.iter().copied().enumerate().map(|(i, p)| (p, i)).collect();
                order.sort_unstable();
                let mut out: Vec<Option<Arc<ParticleEntry>>> = vec![None; pids.len()];
                let mut iter = map.iter().peekable();
                for (pid, ix) in order {
                    while let Some((k, _)) = iter.peek() {
                        if **k < pid {
                            iter.next();
                        } else {
                            break;
                        }
                    }
                    if let Some((k, v)) = iter.peek() {
                        if **k == pid {
                            out[ix] = Some((*v).clone());
                        }
                    }
                }
                out
            } else {
                pids.iter().map(|p| map.get(p).cloned()).collect()
            }
        };

        let mut futs = Vec::with_capacity(pids.len());
        let mut to_schedule = Vec::new();
        let mut delivered: u64 = 0;
        // destination device -> cross-device message count (for the
        // per-device aggregated transfer charge)
        let mut cross: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, found) in entries.into_iter().enumerate() {
            let Some(entry) = found else {
                futs.push(PFuture::ready(Err(self.unknown_particle(pids[i]))));
                continue;
            };
            let reply = PFuture::new();
            let env = Envelope { msg: msg.clone(), args: args.clone(), reply: reply.clone() };
            match entry.mailbox.push(env) {
                sched::PushOutcome::Closed(_) => {
                    futs.push(PFuture::ready(Err(PushError::new(format!(
                        "particle {} mailbox closed",
                        pids[i]
                    )))));
                    continue;
                }
                sched::PushOutcome::MustSchedule => to_schedule.push(entry.clone()),
                sched::PushOutcome::Delivered => {}
            }
            delivered += 1;
            if let Some(fd) = from_device {
                if fd != entry.device {
                    *cross.entry(entry.device).or_insert(0) += 1;
                }
            }
            self.inner.trace.record(
                Event::new(entry.device, Some(entry.pid), EventKind::MsgSend, payload)
                    .with_note(msg.clone()),
            );
            futs.push(reply);
        }

        self.inner.counters.msgs_sent.fetch_add(delivered, Ordering::Relaxed);
        self.inner
            .counters
            .msg_payload_bytes
            .fetch_add(delivered * payload as u64, Ordering::Relaxed);
        let total_cross: usize = cross.values().sum();
        if total_cross > 0 {
            self.inner
                .counters
                .msgs_cross_device
                .fetch_add(total_cross as u64, Ordering::Relaxed);
            if payload > 0 {
                for (dev, n) in cross {
                    let cost = self.inner.cfg.cost.clone();
                    let _ = self.submit_job(dev, move |ctx| {
                        cost.charge_transfer_batch(n, payload, ctx.stats);
                        Ok(Value::Unit)
                    });
                }
            }
        }
        self.inner
            .sched
            .schedule_batch(to_schedule, crate::particle::on_scheduler_worker());
        futs
    }

    /// Submit a compute job to a device stream, completing `reply` with its
    /// result. Low-level; prefer the typed wrappers below.
    fn submit_job<F>(&self, device: usize, f: F) -> PFuture
    where
        F: FnOnce(&mut crate::device::DeviceCtx<'_>) -> Result<Value> + Send + 'static,
    {
        let reply = PFuture::new();
        let r2 = reply.clone();
        let trace = self.inner.trace.clone();
        let res = self.inner.pool.device(device).submit(Box::new(move |ctx| {
            trace.record(Event::new(ctx.device_id, None, EventKind::JobStart, 0));
            let out = std::panic::catch_unwind(AssertUnwindSafe(|| f(ctx))).unwrap_or_else(|p| {
                Err(anyhow!("compute job panicked: {}", panic_msg(p.as_ref())))
            });
            trace.record(Event::new(ctx.device_id, None, EventKind::JobEnd, 0));
            r2.complete(out.map_err(PushError::from));
        }));
        if let Err(e) = res {
            reply.complete(Err(PushError::from(e)));
        }
        reply
    }

    /// Run a model entry (fwd/grad/step/...) for `pid` on its device. The
    /// particle's flat parameter vector is prepended as the first argument;
    /// if `write_back` is given, that output index replaces the parameters.
    pub fn run_entry(
        &self,
        pid: Pid,
        entry_name: &'static str,
        extra_args: Vec<Tensor>,
        write_back: Option<usize>,
    ) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let spec = match entry.model.entry(entry_name) {
            Ok(s) => s.clone(),
            Err(e) => return PFuture::ready(Err(PushError::from(e))),
        };
        self.submit_job(entry.device, move |ctx| {
            // Perf (EXPERIMENTS.md §Perf L3): move the resident parameter
            // tensor out of its cache slot for the call instead of cloning
            // it — saves one param-sized memcpy per step. The slot is
            // restored (or replaced by the written-back output) before the
            // job ends, so the single-authority invariant holds: no other
            // job can interleave on this device stream.
            let slot = ctx.params_mut(pid)?;
            let params = std::mem::replace(slot, Tensor::f32(vec![0], vec![]));
            let mut args = Vec::with_capacity(1 + extra_args.len());
            args.push(params);
            args.extend(extra_args);
            let result = ctx.runtime.execute(&spec.file, &args);
            let mut outs = match result {
                Ok(o) => o,
                Err(e) => {
                    // restore the moved-out parameters on failure
                    *ctx.params_mut(pid)? = args.into_iter().next().unwrap();
                    return Err(e);
                }
            };
            let restore = match write_back {
                Some(ix) if ix < outs.len() => outs.remove(ix),
                Some(ix) => {
                    *ctx.params_mut(pid)? = args.into_iter().next().unwrap();
                    return Err(anyhow!(
                        "entry {entry_name} has {} outputs, cannot write back #{ix}",
                        outs.len()
                    ));
                }
                None => args.into_iter().next().unwrap(),
            };
            *ctx.params_mut(pid)? = restore;
            let vals: Vec<Value> = outs.into_iter().map(Value::Tensor).collect();
            Ok(match vals.len() {
                1 => vals.into_iter().next().unwrap(),
                _ => Value::List(vals),
            })
        })
    }

    /// One Adam step (paper Tables 3/4 protocol: Adam, lr 1e-3). The
    /// optimizer moments m/v and step count live in the particle's local
    /// state and ride along to its device each step; the AOT `adam` entry
    /// computes the update with bias correction.
    pub fn run_adam(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let spec = match entry.model.entry("adam") {
            Ok(s) => s.clone(),
            Err(e) => return PFuture::ready(Err(PushError::from(e))),
        };
        let state = entry.state.clone();
        self.submit_job(entry.device, move |ctx| {
            let slot = ctx.params_mut(pid)?;
            let params = std::mem::replace(slot, Tensor::f32(vec![0], vec![]));
            let d = params.element_count();
            let (m, v, t) = {
                let mut st = state.lock().unwrap();
                let m = match st.remove("adam_m") {
                    Some(Value::Tensor(t)) => t,
                    _ => Tensor::zeros(vec![d]),
                };
                let v = match st.remove("adam_v") {
                    Some(Value::Tensor(t)) => t,
                    _ => Tensor::zeros(vec![d]),
                };
                let t = match st.get("adam_t") {
                    Some(Value::Usize(n)) => *n,
                    _ => 0,
                };
                (m, v, t)
            };
            let args = [
                params,
                m,
                v,
                Tensor::scalar_f32((t + 1) as f32),
                x,
                y,
                Tensor::scalar_f32(lr),
            ];
            let outs = match ctx.runtime.execute(&spec.file, &args) {
                Ok(o) => o,
                Err(e) => {
                    // Restore EVERYTHING the attempt moved out: the
                    // parameter slot AND the optimizer moments — m/v were
                    // `remove`d from particle state above, so dropping
                    // them here would silently restart Adam from zeros on
                    // the next step (the step count survives regardless:
                    // it is only read, never removed).
                    let mut it = args.into_iter();
                    *ctx.params_mut(pid)? = it.next().unwrap();
                    let (m, v) = (it.next().unwrap(), it.next().unwrap());
                    let mut st = state.lock().unwrap();
                    st.insert("adam_m".into(), Value::Tensor(m));
                    st.insert("adam_v".into(), Value::Tensor(v));
                    return Err(e);
                }
            };
            let mut it = outs.into_iter();
            let loss = it.next().ok_or_else(|| anyhow!("adam: no loss"))?;
            let new_flat = it.next().ok_or_else(|| anyhow!("adam: no params"))?;
            let new_m = it.next().ok_or_else(|| anyhow!("adam: no m"))?;
            let new_v = it.next().ok_or_else(|| anyhow!("adam: no v"))?;
            *ctx.params_mut(pid)? = new_flat;
            {
                let mut st = state.lock().unwrap();
                st.insert("adam_m".into(), Value::Tensor(new_m));
                st.insert("adam_v".into(), Value::Tensor(new_v));
                st.insert("adam_t".into(), Value::Usize(t + 1));
            }
            Ok(Value::Tensor(loss))
        })
    }

    /// Execute an arbitrary artifact on `device` (SVGD kernel updates).
    pub fn run_artifact(
        &self,
        device: usize,
        path: std::path::PathBuf,
        args: Vec<Tensor>,
    ) -> PFuture {
        self.submit_job(device, move |ctx| {
            let outs = ctx.runtime.execute(&path, &args)?;
            let vals: Vec<Value> = outs.into_iter().map(Value::Tensor).collect();
            Ok(match vals.len() {
                1 => vals.into_iter().next().unwrap(),
                _ => Value::List(vals),
            })
        })
    }

    /// Read-only view of a particle's parameters (paper: `get` + `view`).
    /// Runs on the owner's device; cross-device requests charge a transfer.
    /// The returned tensor is a zero-copy COW snapshot: it shares the
    /// resident buffer until either side writes.
    pub fn get_params(&self, requester_device: Option<usize>, pid: Pid) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        let cost = self.inner.cfg.cost.clone();
        let cross = requester_device.map(|rd| rd != entry.device).unwrap_or(false);
        self.submit_job(entry.device, move |ctx| {
            let t = ctx.params_view(pid)?;
            if cross {
                cost.charge_transfer(t.size_bytes(), ctx.stats);
                ctx.trace.record(
                    Event::new(ctx.device_id, Some(pid), EventKind::Transfer, t.size_bytes()),
                );
            }
            Ok(Value::Tensor(t))
        })
    }

    /// Overwrite a particle's parameters.
    pub fn set_params(&self, pid: Pid, t: Tensor) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        self.submit_job(entry.device, move |ctx| {
            let params = ctx.params_mut(pid)?;
            if params.shape != t.shape {
                return Err(anyhow!(
                    "set_params shape mismatch: particle has {:?}, got {:?}",
                    params.shape,
                    t.shape
                ));
            }
            *params = t;
            Ok(Value::Unit)
        })
    }

    /// In-place `params += alpha * update` on the particle's device (the
    /// apply step of SVGD_FOLLOW and SWAG averaging).
    pub fn axpy_params(&self, pid: Pid, alpha: f32, update: Tensor) -> PFuture {
        let entry = match self.entry(pid) {
            Ok(e) => e,
            Err(e) => return PFuture::ready(Err(e)),
        };
        self.submit_job(entry.device, move |ctx| {
            let params = ctx.params_mut(pid)?;
            if params.element_count() != update.element_count() {
                return Err(anyhow!(
                    "axpy length mismatch: {} vs {}",
                    params.element_count(),
                    update.element_count()
                ));
            }
            crate::runtime::tensor::ops::axpy(params, alpha, &update);
            Ok(Value::Unit)
        })
    }

    /// Barrier: wait until every device has drained its queue, then flush
    /// all resident particles to the host store and return a snapshot of
    /// every particle's parameters. The snapshot tensors share storage
    /// with the store (zero-copy); a later `axpy_params`/`set_params` on a
    /// particle COW-detaches, so snapshots stay immutable.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        let n = self.num_devices();
        let futs: Vec<PFuture> = (0..n)
            .map(|d| {
                self.submit_job(d, move |ctx| {
                    ctx.cache.flush_all(ctx.host);
                    Ok(Value::Unit)
                })
            })
            .collect();
        PFuture::wait_all(&futs)?;
        let mut out = BTreeMap::new();
        for pid in self.particle_ids() {
            if let Some(t) = self.inner.pool.host.get_clone(pid) {
                out.insert(pid, t);
            }
        }
        Ok(out)
    }

    /// Clone a particle's local state map (the `state=` dict of p_create
    /// plus whatever its handlers stored: Adam moments, SWAG moments,
    /// SGMCMC chain state). Tensor values are zero-copy COW clones.
    /// The whole map is cloned under one state-lock acquisition, so the
    /// snapshot is atomic with respect to any single `state_set` /
    /// `state_set_many` — which is what lets the posterior serving path
    /// read live reservoirs mid-training (DESIGN.md §10). Keys written
    /// through SEPARATE state calls may still be observed mid-update;
    /// checkpoint capture therefore still quiesces (drain) first.
    pub fn particle_state(&self, pid: Pid) -> Option<Vec<(String, Value)>> {
        let entry = self.inner.particles.read().unwrap().get(&pid).cloned()?;
        let st = entry.state.lock().unwrap();
        Some(st.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
    }

    /// Merge `entries` into a particle's local state (checkpoint restore).
    /// Existing keys are overwritten; keys absent from `entries` are left
    /// untouched. Same quiescence caveat as [`Nel::particle_state`].
    pub fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        let entry = self.entry(pid)?;
        let mut st = entry.state.lock().unwrap();
        for (k, v) in entries {
            st.insert(k, v);
        }
        Ok(())
    }

    /// Aggregate statistics. Each device answers its stats request on its
    /// own stream (device::Msg::Stats), which drains FIFO behind every
    /// previously submitted job — an implicit per-device barrier, so
    /// counters from jobs whose futures already resolved are guaranteed
    /// visible without extra barrier jobs or per-job publication.
    pub fn stats(&self) -> NelStats {
        let c = &self.inner.counters;
        NelStats {
            msgs_sent: c.msgs_sent.load(Ordering::Relaxed),
            msgs_cross_device: c.msgs_cross_device.load(Ordering::Relaxed),
            msg_payload_bytes: c.msg_payload_bytes.load(Ordering::Relaxed),
            handler_errors: c.handler_errors.load(Ordering::Relaxed),
            sched: self.inner.sched.stats(),
            devices: self.inner.pool.stats(),
        }
    }
}

fn run_handler(h: &Handler, ctx: &ParticleCtx, args: &[Value]) -> PResult {
    std::panic::catch_unwind(AssertUnwindSafe(|| h(ctx, args))).unwrap_or_else(|p| {
        Err(PushError::new(format!("handler panicked: {}", panic_msg(p.as_ref()))))
    })
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// The context a handler executes with — the paper's `particle` argument
/// (Figure 1): local state access plus messaging.
pub struct ParticleCtx {
    pub pid: Pid,
    pub device: usize,
    nel: Nel,
    model: Arc<ModelSpec>,
    state: Arc<Mutex<BTreeMap<String, Value>>>,
}

impl ParticleCtx {
    pub fn nel(&self) -> &Nel {
        &self.nel
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// All particle ids in the NEL (paper: `particle.particle_ids()`).
    pub fn particle_ids(&self) -> Vec<Pid> {
        self.nel.particle_ids()
    }

    /// Other particles' ids (the common filter in the paper's listings).
    pub fn other_particles(&self) -> Vec<Pid> {
        self.particle_ids().into_iter().filter(|p| *p != self.pid).collect()
    }

    /// Async send (paper: `particle.send(pid, msg, *args)`).
    pub fn send(&self, to: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.nel.send(Some(self.device), to, msg, args)
    }

    /// Batched fan-out of one message to many particles (the leader-round
    /// hot path); see `Nel::broadcast`. Pair with `PFuture::join_all`.
    pub fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        self.nel.broadcast(Some(self.device), pids, msg, args)
    }

    /// Async read-only view of another particle's parameters (paper:
    /// `particle.get(pid)` + `.view()`).
    pub fn get(&self, pid: Pid) -> PFuture {
        self.nel.get_params(Some(self.device), pid)
    }

    /// This particle's own parameters (no transfer charge).
    pub fn own_params(&self) -> PFuture {
        self.nel.get_params(None, self.pid)
    }

    /// One SGD step on (x, y): runs the model's AOT `step` entry on this
    /// particle's device, writes back parameters, resolves to the loss.
    pub fn step(&self, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel
            .run_entry(self.pid, "step", vec![x, y, Tensor::scalar_f32(lr)], Some(1))
    }

    /// One Adam step (moments in particle state); resolves to the loss.
    pub fn adam_step(&self, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel.run_adam(self.pid, x, y, lr)
    }

    /// Forward pass; resolves to the prediction tensor.
    pub fn forward(&self, x: Tensor) -> PFuture {
        self.nel.run_entry(self.pid, "fwd", vec![x], None)
    }

    /// Loss + flat gradient; resolves to List[loss, grad].
    pub fn grad(&self, x: Tensor, y: Tensor) -> PFuture {
        self.nel.run_entry(self.pid, "grad", vec![x, y], None)
    }

    pub fn set_params(&self, t: Tensor) -> PFuture {
        self.nel.set_params(self.pid, t)
    }

    pub fn axpy_params(&self, alpha: f32, update: Tensor) -> PFuture {
        self.nel.axpy_params(self.pid, alpha, update)
    }

    /// Execute an arbitrary AOT artifact on this particle's device (the
    /// SVGD leader runs the L1 kernel artifact this way).
    pub fn run_artifact(&self, path: std::path::PathBuf, args: Vec<Tensor>) -> PFuture {
        self.nel.run_artifact(self.device, path, args)
    }

    // ---- local user state (paper: `state=` at p_create) ----
    pub fn state_get(&self, key: &str) -> Option<Value> {
        self.state.lock().unwrap().get(key).cloned()
    }

    pub fn state_set(&self, key: &str, v: Value) {
        self.state.lock().unwrap().insert(key.to_string(), v);
    }

    /// Set several entries under ONE lock acquisition. `Nel::particle_state`
    /// clones the whole map under the same lock, so a concurrent state
    /// reader (the posterior-predictive serving path, DESIGN.md §10) sees
    /// either none or all of these keys — a multi-key update committed
    /// through separate `state_set` calls could be observed torn.
    pub fn state_set_many(&self, entries: Vec<(String, Value)>) {
        let mut st = self.state.lock().unwrap();
        for (k, v) in entries {
            st.insert(k, v);
        }
    }

    pub fn state_take(&self, key: &str) -> Option<Value> {
        self.state.lock().unwrap().remove(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::particle::handler;
    use crate::runtime::{DType, EntrySpec};

    fn free_cfg(devices: usize) -> NelConfig {
        NelConfig {
            num_devices: devices,
            cost: CostModel::free(),
            control_workers: 2,
            ..NelConfig::default()
        }
    }

    /// A parameter-less model; `entries` maps names to (nonexistent)
    /// artifact files — in the default hermetic build every execute fails,
    /// which is exactly what the error-path tests need.
    fn test_model(entries: &[&str]) -> Arc<ModelSpec> {
        Arc::new(ModelSpec {
            name: "nel_test".to_string(),
            param_count: 4,
            task: "regress".to_string(),
            x_shape: vec![1],
            y_shape: vec![1],
            y_dtype: DType::F32,
            arch: "none".to_string(),
            meta: BTreeMap::new(),
            entries: entries
                .iter()
                .map(|e| {
                    (
                        e.to_string(),
                        EntrySpec {
                            file: std::path::PathBuf::from(format!("/nonexistent/{e}.hlo.txt")),
                            args: Vec::new(),
                            outs: Vec::new(),
                        },
                    )
                })
                .collect(),
        })
    }

    #[test]
    fn failed_adam_step_restores_moments_and_params() {
        let nel = Nel::new(free_cfg(1)).unwrap();
        let m0 = Tensor::f32(vec![4], vec![0.5, 0.5, 0.5, 0.5]);
        let v0 = Tensor::f32(vec![4], vec![0.25, 0.25, 0.25, 0.25]);
        let p = nel
            .p_create(
                test_model(&["adam"]),
                CreateOpts {
                    no_params: true,
                    state: vec![
                        ("adam_m".to_string(), Value::Tensor(m0.clone())),
                        ("adam_v".to_string(), Value::Tensor(v0.clone())),
                        ("adam_t".to_string(), Value::Usize(3)),
                    ],
                    ..CreateOpts::default()
                },
            )
            .unwrap();
        let params0 = Tensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        nel.inner.pool.host.insert(p, params0.clone());

        // The hermetic stub fails every execute, driving the error path.
        let x = Tensor::scalar_f32(0.0);
        let y = Tensor::scalar_f32(0.0);
        assert!(nel.run_adam(p, x, y, 1e-3).wait().is_err());

        // Moments and step count survive the failed step...
        let entry = nel.entry(p).unwrap();
        let st = entry.state.lock().unwrap();
        match st.get("adam_m") {
            Some(Value::Tensor(t)) => assert_eq!(t, &m0, "adam_m lost on failed execute"),
            other => panic!("adam_m missing after failed step: {other:?}"),
        }
        match st.get("adam_v") {
            Some(Value::Tensor(t)) => assert_eq!(t, &v0, "adam_v lost on failed execute"),
            other => panic!("adam_v missing after failed step: {other:?}"),
        }
        assert_eq!(st.get("adam_t"), Some(&Value::Usize(3)));
        drop(st);
        // ...and so do the parameters.
        let after = nel.get_params(None, p).wait().unwrap().tensor().unwrap();
        assert_eq!(after, params0);
    }

    #[test]
    fn send_to_closed_mailbox_leaves_stats_untouched() {
        let nel = Nel::new(free_cfg(2)).unwrap();
        let noop = handler(|_ctx, _| Ok(Value::Unit));
        let p = nel
            .p_create(
                test_model(&[]),
                CreateOpts {
                    no_params: true,
                    device: Some(1),
                    receive: [("PING".to_string(), noop)].into_iter().collect(),
                    ..CreateOpts::default()
                },
            )
            .unwrap();
        // one live round first, with a cross-device payload
        let payload = Tensor::f32(vec![4], vec![1.0; 4]);
        nel.send(Some(0), p, "PING", vec![Value::Tensor(payload.clone())])
            .wait()
            .unwrap();
        let before = nel.stats();
        assert_eq!(before.msgs_sent, 1);
        assert_eq!(before.msgs_cross_device, 1);
        assert_eq!(before.devices[1].transfers, 1);

        // Kill the mailbox (what shutdown does), then send again: the
        // failure must not bump counters or charge a phantom transfer.
        let entry = nel.entry(p).unwrap();
        assert!(entry.mailbox.close().is_empty());
        let err = nel
            .send(Some(0), p, "PING", vec![Value::Tensor(payload)])
            .wait()
            .unwrap_err();
        assert!(err.msg.contains("mailbox closed"), "{err}");
        let after = nel.stats();
        assert_eq!(after.msgs_sent, before.msgs_sent);
        assert_eq!(after.msgs_cross_device, before.msgs_cross_device);
        assert_eq!(after.msg_payload_bytes, before.msg_payload_bytes);
        assert_eq!(after.devices[1].transfers, before.devices[1].transfers);
        assert_eq!(after.devices[1].transfer_bytes, before.devices[1].transfer_bytes);
    }

    #[test]
    fn broadcast_delivers_in_order_and_batches_accounting() {
        let nel = Nel::new(free_cfg(2)).unwrap();
        let who = handler(|ctx, _| Ok(Value::Usize(ctx.pid.0 as usize)));
        let model = test_model(&[]);
        let pids: Vec<Pid> = (0..10)
            .map(|_| {
                nel.p_create(
                    model.clone(),
                    CreateOpts {
                        no_params: true,
                        receive: [("WHO".to_string(), who.clone())].into_iter().collect(),
                        ..CreateOpts::default()
                    },
                )
                .unwrap()
            })
            .collect();

        let payload = Tensor::f32(vec![4], vec![2.0; 4]); // 16 bytes
        let futs = nel.broadcast(
            Some(0),
            &pids,
            "WHO",
            vec![Value::Tensor(payload)],
        );
        assert_eq!(futs.len(), pids.len());
        let vals = PFuture::join_all(&futs).wait().unwrap().list().unwrap();
        for (v, p) in vals.iter().zip(&pids) {
            assert_eq!(*v, Value::Usize(p.0 as usize));
        }

        let stats = nel.stats();
        assert_eq!(stats.msgs_sent, 10);
        assert_eq!(stats.msg_payload_bytes, 160);
        // round-robin placement: odd pids live on device 1 — 5 cross sends
        assert_eq!(stats.msgs_cross_device, 5);
        assert_eq!(stats.devices[1].transfers, 5);
        assert_eq!(stats.devices[1].transfer_bytes, 5 * 16);
        assert_eq!(stats.sched.handler_runs, 10);
        assert!(stats.sched.workers_live <= stats.sched.max_workers);
    }

    #[test]
    fn broadcast_unknown_pids_error_in_slot_without_accounting() {
        let nel = Nel::new(free_cfg(1)).unwrap();
        let noop = handler(|_ctx, _| Ok(Value::Unit));
        let p = nel
            .p_create(
                test_model(&[]),
                CreateOpts {
                    no_params: true,
                    receive: [("PING".to_string(), noop)].into_iter().collect(),
                    ..CreateOpts::default()
                },
            )
            .unwrap();
        let futs = nel.broadcast(None, &[Pid(7777), p, Pid(8888)], "PING", vec![]);
        assert_eq!(futs.len(), 3);
        assert!(futs[0].wait().unwrap_err().msg.contains("unknown particle"));
        assert!(futs[1].wait().is_ok());
        assert!(futs[2].wait().unwrap_err().msg.contains("unknown particle"));
        assert_eq!(nel.stats().msgs_sent, 1);
    }

    #[test]
    fn broadcast_large_fanout_uses_merge_join_path() {
        // >= 8 targets and >= map/4 triggers the merge-join resolve; give
        // it duplicates and an unknown pid to chew on.
        let nel = Nel::new(free_cfg(1)).unwrap();
        let who = handler(|ctx, _| Ok(Value::Usize(ctx.pid.0 as usize)));
        let model = test_model(&[]);
        let pids: Vec<Pid> = (0..16)
            .map(|_| {
                nel.p_create(
                    model.clone(),
                    CreateOpts {
                        no_params: true,
                        receive: [("WHO".to_string(), who.clone())].into_iter().collect(),
                        ..CreateOpts::default()
                    },
                )
                .unwrap()
            })
            .collect();
        // duplicates + unknown, deliberately out of order
        let mut targets: Vec<Pid> = pids.iter().rev().copied().collect();
        targets.push(pids[3]);
        targets.push(Pid(4242));
        let futs = nel.broadcast(None, &targets, "WHO", vec![]);
        for (f, want) in futs.iter().zip(&targets) {
            if want.0 == 4242 {
                assert!(f.wait().is_err());
            } else {
                assert_eq!(f.wait().unwrap(), Value::Usize(want.0 as usize));
            }
        }
        assert_eq!(nel.stats().msgs_sent, 17);
    }

    #[test]
    fn explicit_pid_creation_keeps_allocator_ahead() {
        let nel = Nel::new(free_cfg(1)).unwrap();
        let model = test_model(&[]);
        let p5 = nel
            .p_create(
                model.clone(),
                CreateOpts { no_params: true, pid: Some(Pid(5)), ..CreateOpts::default() },
            )
            .unwrap();
        assert_eq!(p5, Pid(5));
        // the local allocator skipped past the externally assigned pid
        let next = nel
            .p_create(model.clone(), CreateOpts { no_params: true, ..CreateOpts::default() })
            .unwrap();
        assert_eq!(next, Pid(6));
        // re-registering an existing pid is rejected
        let err = nel
            .p_create(
                model,
                CreateOpts { no_params: true, pid: Some(Pid(5)), ..CreateOpts::default() },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("already registered"), "{err:#}");
    }

    #[test]
    fn node_labels_unknown_particle_errors() {
        let nel = Nel::new(NelConfig { node: Some(3), ..free_cfg(1) }).unwrap();
        let err = nel.send(None, Pid(42), "PING", vec![]).wait().unwrap_err();
        assert!(err.msg.contains("unknown particle P42"), "{err}");
        assert!(err.msg.contains("node 3"), "{err}");
        assert!(err.msg.contains("fabric"), "{err}");
        // without a node id the message stays exactly as before
        let plain = Nel::new(free_cfg(1)).unwrap();
        let err = plain.send(None, Pid(7), "PING", vec![]).wait().unwrap_err();
        assert_eq!(err.msg, "unknown particle P7");
    }

    #[test]
    fn nel_stats_merge_sums_each_node_once() {
        let mut a = NelStats {
            msgs_sent: 10,
            msgs_cross_device: 2,
            msg_payload_bytes: 100,
            handler_errors: 1,
            ..NelStats::default()
        };
        a.sched.handler_runs = 5;
        a.sched.pool_target = 4;
        a.sched.workers_peak = 6;
        a.devices.push(DeviceStats { jobs: 3, busy_secs: 0.5, ..DeviceStats::default() });
        let mut b = NelStats { msgs_sent: 7, ..NelStats::default() };
        b.sched.handler_runs = 9;
        b.sched.pool_target = 2;
        b.sched.workers_peak = 1;
        b.devices.push(DeviceStats { jobs: 4, busy_secs: 0.25, ..DeviceStats::default() });
        b.devices.push(DeviceStats::default());

        // merging one node is the identity on every summed field
        let solo = NelStats::merged([&a]);
        assert_eq!(solo.msgs_sent, a.msgs_sent);
        assert_eq!(solo.sched.handler_runs, a.sched.handler_runs);
        assert_eq!(solo.devices.len(), 1);

        // two nodes: every counter appears exactly once in the total
        let m = NelStats::merged([&a, &b]);
        assert_eq!(m.msgs_sent, 17);
        assert_eq!(m.msgs_cross_device, 2);
        assert_eq!(m.msg_payload_bytes, 100);
        assert_eq!(m.handler_errors, 1);
        assert_eq!(m.sched.handler_runs, 14);
        assert_eq!(m.sched.pool_target, 6);
        assert_eq!(m.sched.workers_peak, 7);
        // device breakdowns concatenate in node order — never re-summed
        assert_eq!(m.devices.len(), 3);
        assert_eq!(m.devices[0].jobs, 3);
        assert_eq!(m.devices[1].jobs, 4);
        assert!((m.devices[0].busy_secs - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shutdown_fails_undelivered_envelopes() {
        // A particle whose handler parks long enough for more mail to pile
        // up; dropping the NEL must fail the queued envelopes, not strand
        // their futures.
        let nel = Nel::new(free_cfg(1)).unwrap();
        let slow = handler(|_ctx, _| {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Ok(Value::Unit)
        });
        let p = nel
            .p_create(
                test_model(&[]),
                CreateOpts {
                    no_params: true,
                    receive: [("SLOW".to_string(), slow)].into_iter().collect(),
                    ..CreateOpts::default()
                },
            )
            .unwrap();
        let first = nel.send(None, p, "SLOW", vec![]);
        let queued: Vec<PFuture> = (0..4).map(|_| nel.send(None, p, "SLOW", vec![])).collect();
        // Wait for the first handler to start, then drop the NEL while the
        // rest are still queued.
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(nel);
        // The in-flight handler finishes (its worker holds a strong ref);
        // everything behind it resolves — OK or "NEL shut down" — within
        // the timeout. Nothing may hang.
        let d = std::time::Duration::from_secs(20);
        assert!(first.wait_timeout(d).is_some(), "in-flight future hung");
        for (i, f) in queued.iter().enumerate() {
            assert!(f.wait_timeout(d).is_some(), "queued future {i} hung");
        }
    }
}
