//! The particle abstraction (paper §3.2): identifiers, message values, and
//! the async-await future type that `send`/`get` return.
//!
//! A particle wraps a NN (its flat parameter vector, managed by the device
//! layer), a logical thread of execution (nel::particle spawns one control
//! thread per particle processing its mailbox sequentially), and message
//! passing (handlers registered per message name). This module holds the
//! plain data types; the machinery lives in nel.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::runtime::Tensor;

/// Particle identifier, unique within a NEL (paper: `pid`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pid(pub u32);

impl fmt::Display for Pid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

/// Message argument / result value. The closed set keeps futures clonable
/// and the wire format trivially serializable for a future distributed NEL.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Unit,
    Bool(bool),
    F32(f32),
    Usize(usize),
    Str(String),
    Tensor(Tensor),
    List(Vec<Value>),
}

impl Value {
    pub fn tensor(self) -> Result<Tensor, PushError> {
        match self {
            Value::Tensor(t) => Ok(t),
            other => Err(PushError::new(format!("expected Tensor, got {other:?}"))),
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor, PushError> {
        match self {
            Value::Tensor(t) => Ok(t),
            other => Err(PushError::new(format!("expected Tensor, got {other:?}"))),
        }
    }

    pub fn f32(&self) -> Result<f32, PushError> {
        match self {
            Value::F32(v) => Ok(*v),
            other => Err(PushError::new(format!("expected F32, got {other:?}"))),
        }
    }

    pub fn usize(&self) -> Result<usize, PushError> {
        match self {
            Value::Usize(v) => Ok(*v),
            other => Err(PushError::new(format!("expected Usize, got {other:?}"))),
        }
    }

    pub fn list(self) -> Result<Vec<Value>, PushError> {
        match self {
            Value::List(v) => Ok(v),
            other => Err(PushError::new(format!("expected List, got {other:?}"))),
        }
    }
}

/// Error type that crosses particle boundaries (clonable so multiple
/// waiters can observe the same failure; panics in handlers are captured
/// into this form — the NEL is performance- not fault-oriented, §4.2).
#[derive(Debug, Clone, PartialEq)]
pub struct PushError {
    pub msg: String,
}

impl PushError {
    pub fn new(msg: impl Into<String>) -> PushError {
        PushError { msg: msg.into() }
    }
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for PushError {}

impl From<anyhow::Error> for PushError {
    fn from(e: anyhow::Error) -> Self {
        PushError::new(format!("{e:#}"))
    }
}

pub type PResult = Result<Value, PushError>;

enum FutureState {
    Pending,
    Ready(PResult),
}

struct FutureInner {
    state: Mutex<FutureState>,
    cv: Condvar,
}

/// The paper's `PFuture`: returned by `send`/`get`, resolved by the
/// receiving particle (or device job) on its own timeline.
#[derive(Clone)]
pub struct PFuture {
    inner: Arc<FutureInner>,
}

impl Default for PFuture {
    fn default() -> Self {
        Self::new()
    }
}

impl PFuture {
    pub fn new() -> PFuture {
        PFuture {
            inner: Arc::new(FutureInner {
                state: Mutex::new(FutureState::Pending),
                cv: Condvar::new(),
            }),
        }
    }

    /// An already-resolved future (used when the caller IS the target).
    pub fn ready(v: PResult) -> PFuture {
        let f = PFuture::new();
        f.complete(v);
        f
    }

    /// Resolve the future. Second completion is ignored (the first result
    /// wins — matters when a panic unwinds past an already-completed job).
    pub fn complete(&self, v: PResult) {
        let mut st = self.inner.state.lock().unwrap();
        if matches!(*st, FutureState::Pending) {
            *st = FutureState::Ready(v);
            self.inner.cv.notify_all();
        }
    }

    /// Block until resolved (paper: `future.wait()`).
    pub fn wait(&self) -> PResult {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match &*st {
                FutureState::Ready(v) => return v.clone(),
                FutureState::Pending => st = self.inner.cv.wait(st).unwrap(),
            }
        }
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<PResult> {
        match &*self.inner.state.lock().unwrap() {
            FutureState::Ready(v) => Some(v.clone()),
            FutureState::Pending => None,
        }
    }

    /// Wait with a timeout (deadlock containment in tests).
    pub fn wait_timeout(&self, d: Duration) -> Option<PResult> {
        let mut st = self.inner.state.lock().unwrap();
        let deadline = std::time::Instant::now() + d;
        loop {
            match &*st {
                FutureState::Ready(v) => return Some(v.clone()),
                FutureState::Pending => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, res) = self.inner.cv.wait_timeout(st, deadline - now).unwrap();
                    st = g;
                    if res.timed_out() {
                        if let FutureState::Ready(v) = &*st {
                            return Some(v.clone());
                        }
                        return None;
                    }
                }
            }
        }
    }

    /// Wait on a batch (paper: `p_wait`).
    pub fn wait_all(futs: &[PFuture]) -> Result<Vec<Value>, PushError> {
        futs.iter().map(|f| f.wait()).collect()
    }
}

/// A particle's per-message handler table (paper: the `receive` dict).
/// Handlers run on the particle's control thread with a `ParticleCtx`
/// (defined in nel) and may block on futures from other particles.
pub type Handler =
    Arc<dyn Fn(&crate::nel::ParticleCtx, &[Value]) -> PResult + Send + Sync + 'static>;

pub type HandlerTable = BTreeMap<String, Handler>;

/// Helper: build a handler from a closure.
pub fn handler<F>(f: F) -> Handler
where
    F: Fn(&crate::nel::ParticleCtx, &[Value]) -> PResult + Send + Sync + 'static,
{
    Arc::new(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn future_resolves_across_threads() {
        let f = PFuture::new();
        let f2 = f.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            f2.complete(Ok(Value::F32(4.5)));
        });
        assert_eq!(f.wait().unwrap(), Value::F32(4.5));
        h.join().unwrap();
    }

    #[test]
    fn double_complete_keeps_first() {
        let f = PFuture::new();
        f.complete(Ok(Value::Usize(1)));
        f.complete(Ok(Value::Usize(2)));
        assert_eq!(f.wait().unwrap(), Value::Usize(1));
    }

    #[test]
    fn try_get_pending() {
        let f = PFuture::new();
        assert!(f.try_get().is_none());
        f.complete(Err(PushError::new("boom")));
        assert_eq!(f.try_get().unwrap().unwrap_err().msg, "boom");
    }

    #[test]
    fn wait_timeout_times_out() {
        let f = PFuture::new();
        assert!(f.wait_timeout(Duration::from_millis(20)).is_none());
        f.complete(Ok(Value::Unit));
        assert!(f.wait_timeout(Duration::from_millis(20)).is_some());
    }

    #[test]
    fn value_accessors() {
        assert!(Value::F32(1.0).f32().is_ok());
        assert!(Value::Unit.f32().is_err());
        assert!(Value::List(vec![Value::Unit]).list().is_ok());
        let t = Tensor::scalar_f32(3.0);
        assert_eq!(Value::Tensor(t.clone()).tensor().unwrap(), t);
    }

    #[test]
    fn wait_all_propagates_error() {
        let ok = PFuture::ready(Ok(Value::Unit));
        let bad = PFuture::ready(Err(PushError::new("x")));
        assert!(PFuture::wait_all(&[ok.clone()]).is_ok());
        assert!(PFuture::wait_all(&[ok, bad]).is_err());
    }
}
