//! The particle abstraction (paper §3.2): identifiers, message values, and
//! the async-await future type that `send`/`get` return.
//!
//! A particle wraps a NN (its flat parameter vector, managed by the device
//! layer), a logical thread of execution (the M:N scheduler in nel::sched
//! runs its mailbox sequentially on a fixed worker pool, never two
//! handlers of one particle at once), and message passing (handlers
//! registered per message name). This module holds the plain data types —
//! including the continuation-capable `PFuture` — the machinery lives in
//! nel.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::runtime::Tensor;

/// Particle identifier, unique within a NEL (paper: `pid`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pid(pub u32);

impl fmt::Display for Pid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

/// Message argument / result value. The closed set keeps futures clonable
/// and the wire format trivially serializable for a future distributed NEL.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Unit,
    Bool(bool),
    F32(f32),
    Usize(usize),
    Str(String),
    Tensor(Tensor),
    List(Vec<Value>),
}

impl Value {
    pub fn tensor(self) -> Result<Tensor, PushError> {
        match self {
            Value::Tensor(t) => Ok(t),
            other => Err(PushError::new(format!("expected Tensor, got {other:?}"))),
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor, PushError> {
        match self {
            Value::Tensor(t) => Ok(t),
            other => Err(PushError::new(format!("expected Tensor, got {other:?}"))),
        }
    }

    pub fn f32(&self) -> Result<f32, PushError> {
        match self {
            Value::F32(v) => Ok(*v),
            other => Err(PushError::new(format!("expected F32, got {other:?}"))),
        }
    }

    pub fn usize(&self) -> Result<usize, PushError> {
        match self {
            Value::Usize(v) => Ok(*v),
            other => Err(PushError::new(format!("expected Usize, got {other:?}"))),
        }
    }

    pub fn list(self) -> Result<Vec<Value>, PushError> {
        match self {
            Value::List(v) => Ok(v),
            other => Err(PushError::new(format!("expected List, got {other:?}"))),
        }
    }
}

/// Error type that crosses particle boundaries (clonable so multiple
/// waiters can observe the same failure; panics in handlers are captured
/// into this form — the NEL is performance- not fault-oriented, §4.2).
#[derive(Debug, Clone, PartialEq)]
pub struct PushError {
    pub msg: String,
}

impl PushError {
    pub fn new(msg: impl Into<String>) -> PushError {
        PushError { msg: msg.into() }
    }
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for PushError {}

impl From<anyhow::Error> for PushError {
    fn from(e: anyhow::Error) -> Self {
        PushError::new(format!("{e:#}"))
    }
}

pub type PResult = Result<Value, PushError>;

/// Observer for threads that may block inside `PFuture::wait`. The M:N
/// control-plane scheduler (nel::sched) registers one per worker thread so
/// a handler entering a blocking wait can be compensated for (a spare
/// worker keeps the pool from starving — the tokio `block_in_place`
/// pattern). Threads without an observer (drivers, device streams) block
/// plain.
pub trait BlockObserver: Send + Sync {
    /// The current thread is about to block on a pending future. Returns
    /// true when the pool has (or just spawned) runnable coverage — the
    /// caller may park. Returns false when no more spares are allowed
    /// (worker cap): the caller must actively `help` between short waits
    /// so pending dependency work cannot be stranded by blocked workers.
    fn block_begin(&self) -> bool;
    /// The current thread resumed.
    fn block_end(&self);
    /// Run one unit of pending scheduler work, if any. Called by a
    /// blocked worker when `block_begin` returned false. Returns whether
    /// anything was run.
    fn help(&self) -> bool;
}

/// Tick between `help` attempts for a blocked worker in helping mode.
const HELP_TICK: Duration = Duration::from_millis(1);

thread_local! {
    static BLOCK_OBSERVER: RefCell<Option<Arc<dyn BlockObserver>>> = const { RefCell::new(None) };
}

/// Install (or clear) the blocking observer for the current thread.
pub fn set_block_observer(obs: Option<Arc<dyn BlockObserver>>) {
    BLOCK_OBSERVER.with(|o| *o.borrow_mut() = obs);
}

/// True when the current thread is a scheduler worker (it has a block
/// observer installed). The NEL uses this to route sends issued from
/// inside handlers — whose reply the sender is likely to block on — into
/// the scheduler's dependency-first lane.
pub(crate) fn on_scheduler_worker() -> bool {
    BLOCK_OBSERVER.with(|o| o.borrow().is_some())
}

/// RAII half of a blocking scope: `block_end` on drop (the paired
/// `block_begin` already ran).
struct BlockEndGuard<'a>(&'a Arc<dyn BlockObserver>);

impl Drop for BlockEndGuard<'_> {
    fn drop(&mut self) {
        self.0.block_end();
    }
}

/// Continuation attached to a pending future; runs on the completer's
/// thread, so keep it small (the shipped ones flip an atomic or enqueue).
type Continuation = Box<dyn FnOnce(&PResult) + Send + 'static>;

enum FutureState {
    Pending(Vec<Continuation>),
    Ready(PResult),
}

struct FutureInner {
    state: Mutex<FutureState>,
    cv: Condvar,
}

/// The paper's `PFuture`: returned by `send`/`get`, resolved by the
/// receiving particle (or device job) on its own timeline.
#[derive(Clone)]
pub struct PFuture {
    inner: Arc<FutureInner>,
}

impl Default for PFuture {
    fn default() -> Self {
        Self::new()
    }
}

impl PFuture {
    pub fn new() -> PFuture {
        PFuture {
            inner: Arc::new(FutureInner {
                state: Mutex::new(FutureState::Pending(Vec::new())),
                cv: Condvar::new(),
            }),
        }
    }

    /// An already-resolved future (used when the caller IS the target).
    pub fn ready(v: PResult) -> PFuture {
        let f = PFuture::new();
        f.complete(v);
        f
    }

    /// Resolve the future. Second completion is ignored (the first result
    /// wins — matters when a panic unwinds past an already-completed job).
    /// Continuations registered via `on_ready` fire here, on the
    /// completer's thread, strictly AFTER the state lock is released —
    /// a continuation may itself wait on / complete other futures.
    pub fn complete(&self, v: PResult) {
        let mut st = self.inner.state.lock().unwrap();
        match std::mem::replace(&mut *st, FutureState::Ready(v)) {
            FutureState::Pending(cbs) => {
                self.inner.cv.notify_all();
                if cbs.is_empty() {
                    return;
                }
                // clone the just-stored result for the continuations (one
                // lock acquisition total; tensor payloads are Arc bumps)
                let v = match &*st {
                    FutureState::Ready(v) => v.clone(),
                    FutureState::Pending(_) => unreachable!("stored Ready above"),
                };
                drop(st);
                for cb in cbs {
                    cb(&v);
                }
            }
            FutureState::Ready(first) => {
                // already resolved: restore the first result
                *st = FutureState::Ready(first);
            }
        }
    }

    /// Register a continuation. If the future is already resolved the
    /// callback runs immediately on the calling thread; otherwise it runs
    /// on whichever thread calls `complete` (without the state lock held).
    pub fn on_ready<F>(&self, f: F)
    where
        F: FnOnce(&PResult) + Send + 'static,
    {
        let mut f = Some(f);
        let ready = {
            let mut st = self.inner.state.lock().unwrap();
            match &mut *st {
                FutureState::Pending(cbs) => {
                    cbs.push(Box::new(f.take().unwrap()));
                    None
                }
                FutureState::Ready(v) => Some(v.clone()),
            }
        };
        if let Some(v) = ready {
            (f.take().unwrap())(&v);
        }
    }

    /// Block until resolved (paper: `future.wait()`). A scheduler worker
    /// blocking here announces itself (see `BlockObserver`) so the pool
    /// can compensate with a spare worker — or, when the pool is at its
    /// worker cap, the blocked worker itself drains pending dependency
    /// work between short waits so progress never depends on a thread
    /// that cannot be spawned.
    pub fn wait(&self) -> PResult {
        if let Some(v) = self.try_get() {
            return v;
        }
        self.block_until(None).expect("deadline-less wait resolves")
    }

    /// Non-blocking poll.
    pub fn try_get(&self) -> Option<PResult> {
        match &*self.inner.state.lock().unwrap() {
            FutureState::Ready(v) => Some(v.clone()),
            FutureState::Pending(_) => None,
        }
    }

    /// Wait with a timeout (deadlock containment in tests).
    pub fn wait_timeout(&self, d: Duration) -> Option<PResult> {
        if let Some(v) = self.try_get() {
            return Some(v);
        }
        self.block_until(Some(std::time::Instant::now() + d))
    }

    /// Shared blocking path: plain parking for observer-less threads,
    /// park-with-compensation or help-while-waiting for scheduler
    /// workers. `None` deadline = wait forever.
    fn block_until(&self, deadline: Option<std::time::Instant>) -> Option<PResult> {
        let obs = BLOCK_OBSERVER.with(|o| o.borrow().clone());
        let Some(obs) = obs else {
            return self.park_until(deadline);
        };
        let compensated = obs.block_begin();
        let _end = BlockEndGuard(&obs);
        if compensated {
            return self.park_until(deadline);
        }
        // Worker cap reached: help at full speed while we block — drain
        // queued work back-to-back, re-checking our future between tasks,
        // and only park (briefly) once the scheduler has nothing runnable.
        loop {
            if let Some(v) = self.try_get() {
                return Some(v);
            }
            if obs.help() {
                continue;
            }
            let now = std::time::Instant::now();
            if let Some(dl) = deadline {
                if now >= dl {
                    return None;
                }
            }
            let tick = match deadline {
                Some(dl) => HELP_TICK.min(dl - now),
                None => HELP_TICK,
            };
            if let Some(v) = self.park_until(Some(now + tick)) {
                return Some(v);
            }
        }
    }

    /// Condvar park until resolved or `deadline`.
    fn park_until(&self, deadline: Option<std::time::Instant>) -> Option<PResult> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match &*st {
                FutureState::Ready(v) => return Some(v.clone()),
                FutureState::Pending(_) => match deadline {
                    None => st = self.inner.cv.wait(st).unwrap(),
                    Some(dl) => {
                        let now = std::time::Instant::now();
                        if now >= dl {
                            return None;
                        }
                        let (g, res) = self.inner.cv.wait_timeout(st, dl - now).unwrap();
                        st = g;
                        if res.timed_out() {
                            if let FutureState::Ready(v) = &*st {
                                return Some(v.clone());
                            }
                            return None;
                        }
                    }
                },
            }
        }
    }

    /// Wait on a batch (paper: `p_wait`).
    pub fn wait_all(futs: &[PFuture]) -> Result<Vec<Value>, PushError> {
        futs.iter().map(|f| f.wait()).collect()
    }

    /// Aggregate a batch into ONE future that resolves when every input
    /// has (atomic countdown, no per-future lock-step): to
    /// `Value::List(results)` in input order, or to the first error by
    /// input position. The whole batch always runs to completion — unlike
    /// a serial `wait_all` loop, a late error never leaves earlier futures
    /// unobserved.
    pub fn join_all(futs: &[PFuture]) -> PFuture {
        if futs.is_empty() {
            return PFuture::ready(Ok(Value::List(Vec::new())));
        }
        let out = PFuture::new();
        let n = futs.len();
        let slots: Arc<Mutex<Vec<Option<PResult>>>> = Arc::new(Mutex::new(vec![None; n]));
        let remaining = Arc::new(AtomicUsize::new(n));
        for (i, f) in futs.iter().enumerate() {
            let slots = slots.clone();
            let remaining = remaining.clone();
            let out = out.clone();
            f.on_ready(move |r| {
                slots.lock().unwrap()[i] = Some(r.clone());
                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    // last input resolved: aggregate outside the lock so
                    // out's own continuations never run under it
                    let resolved: Vec<Option<PResult>> =
                        std::mem::take(&mut *slots.lock().unwrap());
                    let mut vals = Vec::with_capacity(resolved.len());
                    let mut err = None;
                    for s in resolved {
                        match s.expect("all inputs resolved") {
                            Ok(v) => vals.push(v),
                            Err(e) => {
                                err = Some(e);
                                break;
                            }
                        }
                    }
                    out.complete(match err {
                        Some(e) => Err(e),
                        None => Ok(Value::List(vals)),
                    });
                }
            });
        }
        out
    }
}

/// A particle's per-message handler table (paper: the `receive` dict).
/// Handlers run (non-reentrantly per particle) on the scheduler's worker
/// pool with a `ParticleCtx` (defined in nel) and may block on futures
/// from other particles.
pub type Handler =
    Arc<dyn Fn(&crate::nel::ParticleCtx, &[Value]) -> PResult + Send + Sync + 'static>;

pub type HandlerTable = BTreeMap<String, Handler>;

/// Helper: build a handler from a closure.
pub fn handler<F>(f: F) -> Handler
where
    F: Fn(&crate::nel::ParticleCtx, &[Value]) -> PResult + Send + Sync + 'static,
{
    Arc::new(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn future_resolves_across_threads() {
        let f = PFuture::new();
        let f2 = f.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            f2.complete(Ok(Value::F32(4.5)));
        });
        assert_eq!(f.wait().unwrap(), Value::F32(4.5));
        h.join().unwrap();
    }

    #[test]
    fn double_complete_keeps_first() {
        let f = PFuture::new();
        f.complete(Ok(Value::Usize(1)));
        f.complete(Ok(Value::Usize(2)));
        assert_eq!(f.wait().unwrap(), Value::Usize(1));
    }

    #[test]
    fn try_get_pending() {
        let f = PFuture::new();
        assert!(f.try_get().is_none());
        f.complete(Err(PushError::new("boom")));
        assert_eq!(f.try_get().unwrap().unwrap_err().msg, "boom");
    }

    #[test]
    fn wait_timeout_times_out() {
        let f = PFuture::new();
        assert!(f.wait_timeout(Duration::from_millis(20)).is_none());
        f.complete(Ok(Value::Unit));
        assert!(f.wait_timeout(Duration::from_millis(20)).is_some());
    }

    #[test]
    fn value_accessors() {
        assert!(Value::F32(1.0).f32().is_ok());
        assert!(Value::Unit.f32().is_err());
        assert!(Value::List(vec![Value::Unit]).list().is_ok());
        let t = Tensor::scalar_f32(3.0);
        assert_eq!(Value::Tensor(t.clone()).tensor().unwrap(), t);
    }

    #[test]
    fn wait_all_propagates_error() {
        let ok = PFuture::ready(Ok(Value::Unit));
        let bad = PFuture::ready(Err(PushError::new("x")));
        assert!(PFuture::wait_all(&[ok.clone()]).is_ok());
        assert!(PFuture::wait_all(&[ok, bad]).is_err());
    }

    #[test]
    fn on_ready_fires_for_pending_and_resolved() {
        let hits = Arc::new(AtomicUsize::new(0));
        // registered before completion: fires from complete()
        let f = PFuture::new();
        let h = hits.clone();
        f.on_ready(move |r| {
            assert!(r.is_ok());
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0);
        f.complete(Ok(Value::Unit));
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        // registered after completion: fires inline
        let h = hits.clone();
        f.on_ready(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn second_complete_does_not_refire_continuations() {
        let hits = Arc::new(AtomicUsize::new(0));
        let f = PFuture::new();
        let h = hits.clone();
        f.on_ready(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        f.complete(Ok(Value::Usize(1)));
        f.complete(Ok(Value::Usize(2)));
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        assert_eq!(f.wait().unwrap(), Value::Usize(1));
    }

    #[test]
    fn join_all_preserves_order_across_threads() {
        let futs: Vec<PFuture> = (0..8).map(|_| PFuture::new()).collect();
        let joined = PFuture::join_all(&futs);
        assert!(joined.try_get().is_none());
        // complete in reverse order from another thread
        let futs2 = futs.clone();
        let h = std::thread::spawn(move || {
            for (i, f) in futs2.iter().enumerate().rev() {
                f.complete(Ok(Value::Usize(i)));
            }
        });
        let vals = joined.wait().unwrap().list().unwrap();
        h.join().unwrap();
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, Value::Usize(i));
        }
    }

    #[test]
    fn join_all_first_error_by_position_wins() {
        let a = PFuture::new();
        let b = PFuture::new();
        let c = PFuture::new();
        let joined = PFuture::join_all(&[a.clone(), b.clone(), c.clone()]);
        c.complete(Err(PushError::new("late")));
        b.complete(Err(PushError::new("early")));
        a.complete(Ok(Value::Unit));
        // b is the first error in input order even though c resolved first
        assert_eq!(joined.wait().unwrap_err().msg, "early");
    }

    #[test]
    fn join_all_empty_resolves_immediately() {
        let joined = PFuture::join_all(&[]);
        assert_eq!(joined.wait().unwrap(), Value::List(Vec::new()));
    }

    #[test]
    fn block_observer_scopes_waits() {
        struct Counter {
            begin: AtomicUsize,
            end: AtomicUsize,
        }
        impl BlockObserver for Counter {
            fn block_begin(&self) -> bool {
                self.begin.fetch_add(1, Ordering::SeqCst);
                true // park mode; helping is exercised by the sched tests
            }
            fn block_end(&self) {
                self.end.fetch_add(1, Ordering::SeqCst);
            }
            fn help(&self) -> bool {
                false
            }
        }
        let c = Arc::new(Counter { begin: AtomicUsize::new(0), end: AtomicUsize::new(0) });
        let f = PFuture::new();
        let f2 = f.clone();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            set_block_observer(Some(c2 as Arc<dyn BlockObserver>));
            // resolved future: no blocking, no observer calls
            let r = PFuture::ready(Ok(Value::Unit)).wait();
            assert!(r.is_ok());
            let out = f2.wait();
            set_block_observer(None);
            out
        });
        std::thread::sleep(Duration::from_millis(20));
        f.complete(Ok(Value::F32(1.0)));
        h.join().unwrap().unwrap();
        assert_eq!(c.begin.load(Ordering::SeqCst), 1);
        assert_eq!(c.end.load(Ordering::SeqCst), 1);
    }
}
