//! Dataset container + batching DataLoader, synchronous or pipelined.
//!
//! Samples are stored row-major in one contiguous buffer per split; the
//! loader materializes `Tensor` batches matching the model's AOT example
//! shapes (fixed batch size — artifacts are shape-specialized, so trailing
//! ragged batches are dropped, mirroring `drop_last=True`).
//!
//! Train loops consume batches through the pull-based [`BatchStream`]
//! (one epoch at a time, one batch per `next`), obtained from any
//! [`BatchSource`]: the plain [`DataLoader`] gathers lazily on the
//! caller's thread, and [`PrefetchLoader`] wraps a `DataLoader` in a
//! bounded-depth double-buffered pipeline that materializes batch `t+1`
//! on a background producer while batch `t` is being consumed
//! (DESIGN.md §10). The shuffle/index stream is keyed by `(seed, epoch)`
//! and the producer runs the *same* shuffle/gather code as the
//! synchronous path, so the prefetched batch sequence is bit-identical
//! to `DataLoader::epoch()` — asynchrony changes timing, never data
//! (pinned by `tests/properties.rs::prop_prefetch_stream_equals_sync`).

use std::sync::{mpsc, Arc};

use crate::runtime::{DType, Tensor, TensorData};
use crate::util::rng::Rng;

/// A fixed-size batch ready to feed an AOT entry.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
}

/// In-memory dataset: n samples of x-shape `x_dims` and y-shape `y_dims`
/// (per-sample shapes, no batch dim). `y_dtype` distinguishes class labels
/// (I32) from regression targets (F32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub x_dims: Vec<usize>,
    pub y_dims: Vec<usize>,
    pub xs: Vec<f32>,
    pub ys_f: Vec<f32>,
    pub ys_i: Vec<i32>,
    pub y_dtype: DType,
}

impl Dataset {
    pub fn new_f32(x_dims: Vec<usize>, y_dims: Vec<usize>) -> Dataset {
        Dataset {
            n: 0,
            x_dims,
            y_dims,
            xs: Vec::new(),
            ys_f: Vec::new(),
            ys_i: Vec::new(),
            y_dtype: DType::F32,
        }
    }

    pub fn new_classify(x_dims: Vec<usize>) -> Dataset {
        Dataset {
            n: 0,
            x_dims,
            y_dims: vec![],
            xs: Vec::new(),
            ys_f: Vec::new(),
            ys_i: Vec::new(),
            y_dtype: DType::I32,
        }
    }

    pub fn x_stride(&self) -> usize {
        self.x_dims.iter().product()
    }

    pub fn y_stride(&self) -> usize {
        self.y_dims.iter().product()
    }

    pub fn push_f32(&mut self, x: &[f32], y: &[f32]) {
        assert_eq!(x.len(), self.x_stride());
        assert_eq!(y.len(), self.y_stride());
        assert_eq!(self.y_dtype, DType::F32);
        self.xs.extend_from_slice(x);
        self.ys_f.extend_from_slice(y);
        self.n += 1;
    }

    pub fn push_classify(&mut self, x: &[f32], label: i32) {
        assert_eq!(x.len(), self.x_stride());
        assert_eq!(self.y_dtype, DType::I32);
        self.xs.extend_from_slice(x);
        self.ys_i.push(label);
        self.n += 1;
    }

    /// Assemble a batch from sample indices.
    pub fn gather(&self, idxs: &[usize]) -> Batch {
        let b = idxs.len();
        let xs_stride = self.x_stride();
        let mut xb = Vec::with_capacity(b * xs_stride);
        for &i in idxs {
            xb.extend_from_slice(&self.xs[i * xs_stride..(i + 1) * xs_stride]);
        }
        let mut x_shape = vec![b];
        x_shape.extend(&self.x_dims);
        let x = Tensor::f32(x_shape, xb);

        let y = match self.y_dtype {
            DType::I32 => {
                let yb: Vec<i32> = idxs.iter().map(|&i| self.ys_i[i]).collect();
                Tensor::new(vec![b], TensorData::i32(yb))
            }
            _ => {
                let ys_stride = self.y_stride();
                let mut yb = Vec::with_capacity(b * ys_stride);
                for &i in idxs {
                    yb.extend_from_slice(&self.ys_f[i * ys_stride..(i + 1) * ys_stride]);
                }
                let mut y_shape = vec![b];
                y_shape.extend(&self.y_dims);
                Tensor::f32(y_shape, yb)
            }
        };
        Batch { x, y }
    }

    /// Split off the last `frac` of samples as a test set. `frac` is
    /// clamped to [0, 1] (NaN reads as 0), so `n_train + n_test == n`
    /// holds for every input — an out-of-range fraction used to make
    /// `n - n_test` underflow straight into `split_off` panics.
    pub fn split(mut self, frac: f32) -> (Dataset, Dataset) {
        let frac = frac.clamp(0.0, 1.0);
        let n_test = (((self.n as f32) * frac).round() as usize).min(self.n);
        let n_train = self.n - n_test;
        let xs_stride = self.x_stride();
        let ys_stride = self.y_stride();
        let mut test = self.clone();
        test.xs = self.xs.split_off(n_train * xs_stride);
        if self.y_dtype == DType::I32 {
            test.ys_i = self.ys_i.split_off(n_train);
            test.ys_f.clear();
        } else {
            test.ys_f = self.ys_f.split_off(n_train * ys_stride);
            test.ys_i.clear();
        }
        test.n = n_test;
        self.n = n_train;
        (self, test)
    }
}

/// Anything a train loop can pull epochs of batches from: the plain
/// synchronous [`DataLoader`] or the pipelined [`PrefetchLoader`]. The
/// shuffle stream advances exactly once per `epoch_stream` call, so two
/// sources built from the same `(data, batch_size, shuffle, seed)` yield
/// bit-identical batch sequences regardless of which implementation (or
/// how much of each epoch) is consumed.
pub trait BatchSource {
    /// Batches each epoch yields (fixed: ragged tails are dropped).
    fn batches_per_epoch(&self) -> usize;

    /// Advance to the next epoch and return its pull-based stream.
    fn epoch_stream(&mut self) -> BatchStream;
}

/// One epoch's pull-based batch stream (`next() -> Option<Batch>`).
/// Either gathers lazily on the calling thread (sync) or pulls from a
/// bounded channel fed by a background producer (prefetch).
pub struct BatchStream {
    inner: StreamInner,
    /// Batches this epoch yields in total.
    nb: usize,
    taken: usize,
}

enum StreamInner {
    /// Lazily gathered from a dataset snapshot + this epoch's index order.
    Sync { data: Arc<Dataset>, order: Vec<usize>, batch_size: usize },
    /// Fed by a [`PrefetchLoader`] producer thread.
    Prefetch { rx: mpsc::Receiver<Batch> },
}

impl BatchStream {
    /// Total batches this epoch yields.
    pub fn len(&self) -> usize {
        self.nb
    }

    pub fn is_empty(&self) -> bool {
        self.nb == 0
    }
}

impl Iterator for BatchStream {
    type Item = Batch;

    /// The next batch, or None once the epoch is exhausted.
    fn next(&mut self) -> Option<Batch> {
        if self.taken >= self.nb {
            return None;
        }
        let b = match &mut self.inner {
            StreamInner::Sync { data, order, batch_size } => {
                let (bs, i) = (*batch_size, self.taken);
                Some(data.gather(&order[i * bs..(i + 1) * bs]))
            }
            // A dead producer (panicked gather) ends the epoch early; the
            // consumer sees a short epoch, never a hang.
            StreamInner::Prefetch { rx } => rx.recv().ok(),
        };
        if b.is_some() {
            self.taken += 1;
        }
        b
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.nb - self.taken;
        // a prefetch producer may die early, so only the sync stream's
        // lower bound is exact
        match self.inner {
            StreamInner::Sync { .. } => (left, Some(left)),
            StreamInner::Prefetch { .. } => (0, Some(left)),
        }
    }
}

/// Epoch iterator producing fixed-size batches, optionally shuffled and
/// optionally capped at `max_batches` per epoch (the paper fixes 40
/// batches/epoch across tasks, §5.1). The dataset is Arc-shared so every
/// epoch stream is a refcount bump, not a data copy.
pub struct DataLoader {
    pub data: Arc<Dataset>,
    pub batch_size: usize,
    pub shuffle: bool,
    pub max_batches: Option<usize>,
    rng: Rng,
    order: Vec<usize>,
}

impl DataLoader {
    pub fn new(data: Dataset, batch_size: usize, shuffle: bool, seed: u64) -> DataLoader {
        assert!(batch_size > 0 && data.n >= batch_size,
                "dataset of {} can't fill a batch of {batch_size}", data.n);
        let order = (0..data.n).collect();
        DataLoader {
            data: Arc::new(data),
            batch_size,
            shuffle,
            max_batches: None,
            rng: Rng::new(seed),
            order,
        }
    }

    pub fn with_max_batches(mut self, m: usize) -> DataLoader {
        self.max_batches = Some(m);
        self
    }

    /// Materialize one epoch of batches (tests, baselines, and the
    /// prefetch-equivalence property; train loops stream instead).
    pub fn epoch(&mut self) -> Vec<Batch> {
        self.epoch_stream().collect()
    }
}

impl BatchSource for DataLoader {
    fn batches_per_epoch(&self) -> usize {
        let full = self.data.n / self.batch_size;
        match self.max_batches {
            Some(m) => full.min(m),
            None => full,
        }
    }

    fn epoch_stream(&mut self) -> BatchStream {
        if self.shuffle {
            self.rng.shuffle(&mut self.order);
        }
        let nb = self.batches_per_epoch();
        BatchStream {
            inner: StreamInner::Sync {
                data: self.data.clone(),
                order: self.order[..nb * self.batch_size].to_vec(),
                batch_size: self.batch_size,
            },
            nb,
            taken: 0,
        }
    }
}

/// Default channel depth of a [`PrefetchLoader`]: double buffering (the
/// producer keeps up to 2 batches ahead of the consumer).
pub const DEFAULT_PREFETCH_DEPTH: usize = 2;

/// A double-buffered pipeline over a [`DataLoader`]: each epoch hands the
/// loader to a background producer that shuffles and gathers batches into
/// a bounded channel (`depth`, default 2) while the consumer computes on
/// the previous batch. The producer runs the loader's own
/// `epoch_stream`, so shuffle order, RNG advancement, and batch contents
/// are bit-identical to the synchronous path — prefetching changes WHEN a
/// batch is materialized, never WHICH batch it is.
///
/// Epochs are sequential: starting a new epoch first reclaims the loader
/// from the previous producer (which exits as soon as its epoch is fully
/// sent or its stream is dropped). Dropping a partially-consumed
/// `BatchStream` cancels the rest of that epoch's gathers; the RNG has
/// already advanced for the epoch, exactly as a discarded
/// `DataLoader::epoch()` result would have.
pub struct PrefetchLoader {
    loader: Option<DataLoader>,
    pending: Option<PendingEpoch>,
    depth: usize,
    nb: usize,
}

struct PendingEpoch {
    /// The producer returns the loader here when its epoch ends.
    ret: mpsc::Receiver<DataLoader>,
    thread: std::thread::JoinHandle<()>,
}

impl PrefetchLoader {
    pub fn new(loader: DataLoader) -> PrefetchLoader {
        let nb = loader.batches_per_epoch();
        PrefetchLoader {
            loader: Some(loader),
            pending: None,
            depth: DEFAULT_PREFETCH_DEPTH,
            nb,
        }
    }

    /// Set the pipeline depth (>= 1): how many materialized batches may
    /// sit between producer and consumer.
    pub fn with_depth(mut self, depth: usize) -> PrefetchLoader {
        assert!(depth >= 1, "prefetch depth must be >= 1");
        self.depth = depth;
        self
    }

    /// Wait for the in-flight epoch's producer (if any) and take the
    /// loader back. The producer exits as soon as its epoch is drained OR
    /// its stream is dropped (its next send fails), so the only way this
    /// wait can stall is a STILL-ALIVE, undrained previous stream parking
    /// the producer on the bounded channel — a caller bug (drop the old
    /// stream before starting a new epoch), surfaced as a panic after a
    /// generous timeout rather than a silent deadlock.
    fn reclaim(&mut self) {
        if let Some(p) = self.pending.take() {
            let loader = match p.ret.recv_timeout(std::time::Duration::from_secs(30)) {
                Ok(l) => l,
                Err(mpsc::RecvTimeoutError::Timeout) => panic!(
                    "PrefetchLoader: the previous epoch's BatchStream is still alive and \
                     undrained; drop it before starting a new epoch"
                ),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    panic!("prefetch producer died (gather panicked?)")
                }
            };
            let _ = p.thread.join();
            self.loader = Some(loader);
        }
    }

    /// Recover the wrapped loader (joins the in-flight epoch first).
    pub fn into_inner(mut self) -> DataLoader {
        self.reclaim();
        self.loader.take().expect("loader present after reclaim")
    }
}

impl BatchSource for PrefetchLoader {
    fn batches_per_epoch(&self) -> usize {
        self.nb
    }

    fn epoch_stream(&mut self) -> BatchStream {
        self.reclaim();
        let mut loader = self.loader.take().expect("loader present after reclaim");
        let (tx, rx) = mpsc::sync_channel::<Batch>(self.depth);
        let (ret_tx, ret_rx) = mpsc::channel::<DataLoader>();
        let thread = std::thread::Builder::new()
            .name("push-prefetch".to_string())
            .spawn(move || {
                // The exact synchronous epoch, materialized ahead of the
                // consumer; a send error means the consumer dropped the
                // stream — stop gathering, the epoch is abandoned.
                for b in loader.epoch_stream() {
                    if tx.send(b).is_err() {
                        break;
                    }
                }
                let _ = ret_tx.send(loader);
            })
            .expect("spawning prefetch producer");
        self.pending = Some(PendingEpoch { ret: ret_rx, thread });
        BatchStream { inner: StreamInner::Prefetch { rx }, nb: self.nb, taken: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut d = Dataset::new_f32(vec![2], vec![1]);
        for i in 0..n {
            d.push_f32(&[i as f32, -(i as f32)], &[2.0 * i as f32]);
        }
        d
    }

    #[test]
    fn gather_shapes() {
        let d = toy(10);
        let b = d.gather(&[1, 3, 5]);
        assert_eq!(b.x.shape, vec![3, 2]);
        assert_eq!(b.y.shape, vec![3, 1]);
        assert_eq!(b.x.as_f32()[2], 3.0);
        assert_eq!(b.y.as_f32()[1], 6.0);
    }

    #[test]
    fn classify_batches_are_i32() {
        let mut d = Dataset::new_classify(vec![4]);
        for i in 0..8 {
            d.push_classify(&[0.0; 4], i % 3);
        }
        let b = d.gather(&[0, 1, 2]);
        assert_eq!(b.y.as_i32(), &[0, 1, 2]);
        assert_eq!(b.y.shape, vec![3]);
    }

    #[test]
    fn loader_covers_epoch_without_repeats() {
        let mut dl = DataLoader::new(toy(10), 3, true, 42);
        let batches = dl.epoch();
        assert_eq!(batches.len(), 3); // 10/3, last ragged batch dropped
        let mut seen: Vec<f32> = batches
            .iter()
            .flat_map(|b| b.x.as_f32().chunks(2).map(|c| c[0]).collect::<Vec<_>>())
            .collect();
        seen.sort_by(f32::total_cmp);
        seen.dedup();
        assert_eq!(seen.len(), 9, "no sample repeated within an epoch");
    }

    #[test]
    fn max_batches_caps() {
        let mut dl = DataLoader::new(toy(100), 10, false, 0).with_max_batches(4);
        assert_eq!(dl.batches_per_epoch(), 4);
        assert_eq!(dl.epoch().len(), 4);
    }

    #[test]
    fn unshuffled_is_deterministic() {
        let mut a = DataLoader::new(toy(9), 3, false, 0);
        let mut b = DataLoader::new(toy(9), 3, false, 99);
        assert_eq!(a.epoch()[0].x, b.epoch()[0].x);
    }

    #[test]
    fn split_partitions() {
        let (tr, te) = toy(10).split(0.3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.xs[0], 7.0);
    }

    #[test]
    fn split_extremes_keep_every_sample() {
        // frac = 0: everything stays in train
        let (tr, te) = toy(5).split(0.0);
        assert_eq!((tr.n, te.n), (5, 0));
        assert_eq!(tr.xs.len(), 5 * 2);
        assert!(te.xs.is_empty() && te.ys_f.is_empty());

        // frac = 1: everything moves to test
        let (tr, te) = toy(5).split(1.0);
        assert_eq!((tr.n, te.n), (0, 5));
        assert!(tr.xs.is_empty() && tr.ys_f.is_empty());
        assert_eq!(te.xs[0], 0.0);

        // out-of-range fractions clamp instead of underflowing
        let (tr, te) = toy(4).split(2.5);
        assert_eq!((tr.n, te.n), (0, 4));
        let (tr, te) = toy(4).split(-1.0);
        assert_eq!((tr.n, te.n), (4, 0));
        let (tr, te) = toy(4).split(f32::NAN);
        assert_eq!((tr.n, te.n), (4, 0));
    }

    #[test]
    fn split_single_sample_conserves_n() {
        for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let (tr, te) = toy(1).split(frac);
            assert_eq!(tr.n + te.n, 1, "frac {frac}");
            assert_eq!(tr.xs.len() + te.xs.len(), 2, "frac {frac}: x rows lost");
            assert_eq!(tr.ys_f.len() + te.ys_f.len(), 1, "frac {frac}: y rows lost");
        }
    }

    #[test]
    fn split_clears_the_unused_label_side() {
        // classify: ys_f must stay empty on BOTH halves, ys_i partitions
        let mut c = Dataset::new_classify(vec![2]);
        for i in 0..6 {
            c.push_classify(&[i as f32, 0.0], i % 3);
        }
        let (tr, te) = c.split(0.5);
        assert_eq!((tr.n, te.n), (3, 3));
        assert!(tr.ys_f.is_empty() && te.ys_f.is_empty());
        assert_eq!(tr.ys_i, vec![0, 1, 2]);
        assert_eq!(te.ys_i, vec![0, 1, 2]);

        // regression: ys_i must stay empty on both halves
        let (tr, te) = toy(6).split(0.5);
        assert!(tr.ys_i.is_empty() && te.ys_i.is_empty());
        assert_eq!(tr.ys_f.len(), 3);
        assert_eq!(te.ys_f.len(), 3);
    }

    #[test]
    fn sync_stream_equals_epoch() {
        let mut a = DataLoader::new(toy(10), 3, true, 7);
        let mut b = DataLoader::new(toy(10), 3, true, 7);
        for _ in 0..3 {
            let want = a.epoch();
            let got: Vec<Batch> = b.epoch_stream().collect();
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.x, g.x);
                assert_eq!(w.y, g.y);
            }
        }
    }

    #[test]
    fn prefetch_stream_matches_sync_including_ragged_tail() {
        // 10 % 3 != 0: the ragged tail drops identically on both paths
        let mut sync = DataLoader::new(toy(10), 3, true, 42);
        let mut pre = PrefetchLoader::new(DataLoader::new(toy(10), 3, true, 42));
        assert_eq!(pre.batches_per_epoch(), 3);
        for epoch in 0..3 {
            let want = sync.epoch();
            let stream = pre.epoch_stream();
            assert_eq!(stream.len(), want.len());
            let got: Vec<Batch> = stream.collect();
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w.x, g.x, "epoch {epoch} batch {i}");
                assert_eq!(w.y, g.y, "epoch {epoch} batch {i}");
            }
        }
    }

    #[test]
    fn prefetch_abandoned_epoch_still_advances_the_shuffle() {
        // consuming only part of an epoch (dropping the stream) must leave
        // the NEXT epoch identical to the synchronous loader's next epoch
        let mut sync = DataLoader::new(toy(12), 4, true, 9);
        let mut pre = PrefetchLoader::new(DataLoader::new(toy(12), 4, true, 9));
        let _ = sync.epoch();
        {
            let mut stream = pre.epoch_stream();
            let _ = stream.next(); // take one batch, drop the rest
        }
        let want = sync.epoch();
        let got: Vec<Batch> = pre.epoch_stream().collect();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.x, g.x);
        }
    }

    #[test]
    fn prefetch_into_inner_returns_the_loader() {
        let mut pre = PrefetchLoader::new(DataLoader::new(toy(9), 3, false, 0)).with_depth(1);
        assert_eq!(pre.epoch_stream().count(), 3);
        let mut loader = pre.into_inner();
        assert_eq!(loader.epoch().len(), 3);
    }
}
