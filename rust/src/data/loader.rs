//! Dataset container + batching DataLoader.
//!
//! Samples are stored row-major in one contiguous buffer per split; the
//! loader materializes `Tensor` batches matching the model's AOT example
//! shapes (fixed batch size — artifacts are shape-specialized, so trailing
//! ragged batches are dropped, mirroring `drop_last=True`).

use crate::runtime::{DType, Tensor, TensorData};
use crate::util::rng::Rng;

/// A fixed-size batch ready to feed an AOT entry.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
}

/// In-memory dataset: n samples of x-shape `x_dims` and y-shape `y_dims`
/// (per-sample shapes, no batch dim). `y_dtype` distinguishes class labels
/// (I32) from regression targets (F32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub x_dims: Vec<usize>,
    pub y_dims: Vec<usize>,
    pub xs: Vec<f32>,
    pub ys_f: Vec<f32>,
    pub ys_i: Vec<i32>,
    pub y_dtype: DType,
}

impl Dataset {
    pub fn new_f32(x_dims: Vec<usize>, y_dims: Vec<usize>) -> Dataset {
        Dataset {
            n: 0,
            x_dims,
            y_dims,
            xs: Vec::new(),
            ys_f: Vec::new(),
            ys_i: Vec::new(),
            y_dtype: DType::F32,
        }
    }

    pub fn new_classify(x_dims: Vec<usize>) -> Dataset {
        Dataset {
            n: 0,
            x_dims,
            y_dims: vec![],
            xs: Vec::new(),
            ys_f: Vec::new(),
            ys_i: Vec::new(),
            y_dtype: DType::I32,
        }
    }

    pub fn x_stride(&self) -> usize {
        self.x_dims.iter().product()
    }

    pub fn y_stride(&self) -> usize {
        self.y_dims.iter().product()
    }

    pub fn push_f32(&mut self, x: &[f32], y: &[f32]) {
        assert_eq!(x.len(), self.x_stride());
        assert_eq!(y.len(), self.y_stride());
        assert_eq!(self.y_dtype, DType::F32);
        self.xs.extend_from_slice(x);
        self.ys_f.extend_from_slice(y);
        self.n += 1;
    }

    pub fn push_classify(&mut self, x: &[f32], label: i32) {
        assert_eq!(x.len(), self.x_stride());
        assert_eq!(self.y_dtype, DType::I32);
        self.xs.extend_from_slice(x);
        self.ys_i.push(label);
        self.n += 1;
    }

    /// Assemble a batch from sample indices.
    pub fn gather(&self, idxs: &[usize]) -> Batch {
        let b = idxs.len();
        let xs_stride = self.x_stride();
        let mut xb = Vec::with_capacity(b * xs_stride);
        for &i in idxs {
            xb.extend_from_slice(&self.xs[i * xs_stride..(i + 1) * xs_stride]);
        }
        let mut x_shape = vec![b];
        x_shape.extend(&self.x_dims);
        let x = Tensor::f32(x_shape, xb);

        let y = match self.y_dtype {
            DType::I32 => {
                let yb: Vec<i32> = idxs.iter().map(|&i| self.ys_i[i]).collect();
                Tensor::new(vec![b], TensorData::i32(yb))
            }
            _ => {
                let ys_stride = self.y_stride();
                let mut yb = Vec::with_capacity(b * ys_stride);
                for &i in idxs {
                    yb.extend_from_slice(&self.ys_f[i * ys_stride..(i + 1) * ys_stride]);
                }
                let mut y_shape = vec![b];
                y_shape.extend(&self.y_dims);
                Tensor::f32(y_shape, yb)
            }
        };
        Batch { x, y }
    }

    /// Split off the last `frac` of samples as a test set.
    pub fn split(mut self, frac: f32) -> (Dataset, Dataset) {
        let n_test = ((self.n as f32) * frac).round() as usize;
        let n_train = self.n - n_test;
        let xs_stride = self.x_stride();
        let ys_stride = self.y_stride();
        let mut test = self.clone();
        test.xs = self.xs.split_off(n_train * xs_stride);
        if self.y_dtype == DType::I32 {
            test.ys_i = self.ys_i.split_off(n_train);
            test.ys_f.clear();
        } else {
            test.ys_f = self.ys_f.split_off(n_train * ys_stride);
            test.ys_i.clear();
        }
        test.n = n_test;
        self.n = n_train;
        (self, test)
    }
}

/// Epoch iterator producing fixed-size batches, optionally shuffled and
/// optionally capped at `max_batches` per epoch (the paper fixes 40
/// batches/epoch across tasks, §5.1).
pub struct DataLoader {
    pub data: Dataset,
    pub batch_size: usize,
    pub shuffle: bool,
    pub max_batches: Option<usize>,
    rng: Rng,
    order: Vec<usize>,
}

impl DataLoader {
    pub fn new(data: Dataset, batch_size: usize, shuffle: bool, seed: u64) -> DataLoader {
        assert!(batch_size > 0 && data.n >= batch_size,
                "dataset of {} can't fill a batch of {batch_size}", data.n);
        let order = (0..data.n).collect();
        DataLoader {
            data,
            batch_size,
            shuffle,
            max_batches: None,
            rng: Rng::new(seed),
            order,
        }
    }

    pub fn with_max_batches(mut self, m: usize) -> DataLoader {
        self.max_batches = Some(m);
        self
    }

    pub fn batches_per_epoch(&self) -> usize {
        let full = self.data.n / self.batch_size;
        match self.max_batches {
            Some(m) => full.min(m),
            None => full,
        }
    }

    /// Materialize one epoch of batches.
    pub fn epoch(&mut self) -> Vec<Batch> {
        if self.shuffle {
            self.rng.shuffle(&mut self.order);
        }
        let nb = self.batches_per_epoch();
        (0..nb)
            .map(|b| {
                let idxs = &self.order[b * self.batch_size..(b + 1) * self.batch_size];
                self.data.gather(idxs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut d = Dataset::new_f32(vec![2], vec![1]);
        for i in 0..n {
            d.push_f32(&[i as f32, -(i as f32)], &[2.0 * i as f32]);
        }
        d
    }

    #[test]
    fn gather_shapes() {
        let d = toy(10);
        let b = d.gather(&[1, 3, 5]);
        assert_eq!(b.x.shape, vec![3, 2]);
        assert_eq!(b.y.shape, vec![3, 1]);
        assert_eq!(b.x.as_f32()[2], 3.0);
        assert_eq!(b.y.as_f32()[1], 6.0);
    }

    #[test]
    fn classify_batches_are_i32() {
        let mut d = Dataset::new_classify(vec![4]);
        for i in 0..8 {
            d.push_classify(&[0.0; 4], i % 3);
        }
        let b = d.gather(&[0, 1, 2]);
        assert_eq!(b.y.as_i32(), &[0, 1, 2]);
        assert_eq!(b.y.shape, vec![3]);
    }

    #[test]
    fn loader_covers_epoch_without_repeats() {
        let mut dl = DataLoader::new(toy(10), 3, true, 42);
        let batches = dl.epoch();
        assert_eq!(batches.len(), 3); // 10/3, last ragged batch dropped
        let mut seen: Vec<f32> = batches
            .iter()
            .flat_map(|b| b.x.as_f32().chunks(2).map(|c| c[0]).collect::<Vec<_>>())
            .collect();
        seen.sort_by(f32::total_cmp);
        seen.dedup();
        assert_eq!(seen.len(), 9, "no sample repeated within an epoch");
    }

    #[test]
    fn max_batches_caps() {
        let mut dl = DataLoader::new(toy(100), 10, false, 0).with_max_batches(4);
        assert_eq!(dl.batches_per_epoch(), 4);
        assert_eq!(dl.epoch().len(), 4);
    }

    #[test]
    fn unshuffled_is_deterministic() {
        let mut a = DataLoader::new(toy(9), 3, false, 0);
        let mut b = DataLoader::new(toy(9), 3, false, 99);
        assert_eq!(a.epoch()[0].x, b.epoch()[0].x);
    }

    #[test]
    fn split_partitions() {
        let (tr, te) = toy(10).split(0.3);
        assert_eq!(tr.n, 7);
        assert_eq!(te.n, 3);
        assert_eq!(te.xs[0], 7.0);
    }
}
