//! Synthetic dataset generators (paper-dataset substitutes).

use crate::data::loader::Dataset;
use crate::util::rng::Rng;

/// MNIST substitute: 10 deterministic class templates on a 28x28 grid
/// (frequency/phase patterns unique per class) + Gaussian pixel noise.
/// Learnable to >95% by small models but not trivially linearly separable
/// at high noise.
pub fn mnist_like(n: usize, noise: f32, seed: u64) -> Dataset {
    let side = 28usize;
    let dim = side * side;
    // class templates: radial + plane-wave mixtures, fixed by class id
    let templates: Vec<Vec<f32>> = (0..10)
        .map(|c| {
            let cf = c as f32;
            (0..dim)
                .map(|i| {
                    let x = (i % side) as f32 / side as f32 - 0.5;
                    let y = (i / side) as f32 / side as f32 - 0.5;
                    let r = (x * x + y * y).sqrt();
                    let a = (2.0 * std::f32::consts::PI * (cf * 0.5 + 1.0) * r).cos();
                    let b = ((cf + 2.0) * 3.0 * x + cf * 2.0 * y).sin();
                    0.6 * a + 0.4 * b
                })
                .collect()
        })
        .collect();
    let mut rng = Rng::new(seed).fold_in(0x6d6e7374);
    let mut d = Dataset::new_classify(vec![dim]);
    let mut x = vec![0.0f32; dim];
    for i in 0..n {
        let label = (i % 10) as i32;
        let t = &templates[label as usize];
        for (xi, ti) in x.iter_mut().zip(t) {
            *xi = ti + noise * rng.normal();
        }
        d.push_classify(&x, label);
    }
    d
}

/// MD17 substitute: `atoms` particles jittered around a deterministic
/// equilibrium geometry; energy/forces from a Morse-style pair potential
/// V(r) = De (1 - exp(-a (r - r0)))^2. Packs x[A, 3+S] (positions +
/// species one-hot) and y[1 + 3A] (energy, forces) — the CGCNN contract.
/// Energies are shifted/scaled to ~N(0,1) so training is well-conditioned.
pub fn md17_like(n: usize, atoms: usize, species: usize, seed: u64) -> Dataset {
    let (de, a, r0) = (1.0f32, 1.2f32, 1.5f32);
    let mut rng = Rng::new(seed).fold_in(0x6d6431);
    // deterministic equilibrium geometry: points on a coarse 3-D helix
    let eq: Vec<[f32; 3]> = (0..atoms)
        .map(|i| {
            let t = i as f32 * 0.9;
            [1.4 * t.cos(), 1.4 * t.sin(), 0.5 * t]
        })
        .collect();
    let spec_of = |i: usize| i % species;

    // first pass to estimate energy scale
    let sample = |rng: &mut Rng, pos: &mut Vec<[f32; 3]>| {
        pos.clear();
        for p in &eq {
            pos.push([
                p[0] + 0.2 * rng.normal(),
                p[1] + 0.2 * rng.normal(),
                p[2] + 0.2 * rng.normal(),
            ]);
        }
    };
    let energy_forces = |pos: &[[f32; 3]]| {
        let mut e = 0.0f32;
        let mut f = vec![[0.0f32; 3]; pos.len()];
        for i in 0..pos.len() {
            for j in (i + 1)..pos.len() {
                let dx = [
                    pos[i][0] - pos[j][0],
                    pos[i][1] - pos[j][1],
                    pos[i][2] - pos[j][2],
                ];
                let r = (dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2]).sqrt().max(1e-6);
                let ex = (-a * (r - r0)).exp();
                e += de * (1.0 - ex) * (1.0 - ex);
                // dV/dr = 2 De a ex (1 - ex); F = -dV/dr * dr/dpos
                let dvdr = 2.0 * de * a * ex * (1.0 - ex);
                for k in 0..3 {
                    let drdxi = dx[k] / r;
                    f[i][k] -= dvdr * drdxi;
                    f[j][k] += dvdr * drdxi;
                }
            }
        }
        (e, f)
    };

    // estimate mean/std of energy on a probe set for normalization
    let mut probe_rng = rng.fold_in(1);
    let mut pos = Vec::with_capacity(atoms);
    let mut es = Vec::with_capacity(64);
    for _ in 0..64 {
        sample(&mut probe_rng, &mut pos);
        es.push(energy_forces(&pos).0);
    }
    let mu = es.iter().sum::<f32>() / es.len() as f32;
    let sd = (es.iter().map(|e| (e - mu) * (e - mu)).sum::<f32>() / es.len() as f32)
        .sqrt()
        .max(1e-3);

    let mut d = Dataset::new_f32(vec![atoms, 3 + species], vec![1 + 3 * atoms]);
    let mut x = vec![0.0f32; atoms * (3 + species)];
    let mut y = vec![0.0f32; 1 + 3 * atoms];
    for _ in 0..n {
        sample(&mut rng, &mut pos);
        let (e, f) = energy_forces(&pos);
        for i in 0..atoms {
            let row = i * (3 + species);
            x[row..row + 3].copy_from_slice(&pos[i]);
            for s in 0..species {
                x[row + 3 + s] = if spec_of(i) == s { 1.0 } else { 0.0 };
            }
        }
        y[0] = (e - mu) / sd;
        for i in 0..atoms {
            for k in 0..3 {
                y[1 + 3 * i + k] = f[i][k] / sd;
            }
        }
        d.push_f32(&x, &y);
    }
    d
}

/// PDEBench-Advection substitute: periodic 1-D advection du/dt + c du/dx=0.
/// Initial conditions are random Fourier series; the target is the exact
/// solution u0(x - c t) at a fixed horizon (fractional shifts interpolate).
pub fn advection(n: usize, nx: usize, c: f32, t: f32, modes: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fold_in(0x61647631);
    let mut d = Dataset::new_f32(vec![nx], vec![nx]);
    let shift = c * t; // in units of the domain [0, 1)
    let mut u0 = vec![0.0f32; nx];
    let mut ut = vec![0.0f32; nx];
    for _ in 0..n {
        let coeffs: Vec<(f32, f32, f32)> = (1..=modes)
            .map(|m| {
                let amp = rng.normal() / m as f32;
                let phase = rng.uniform_in(0.0, 2.0 * std::f32::consts::PI);
                (m as f32, amp, phase)
            })
            .collect();
        let eval = |xpos: f32| -> f32 {
            coeffs
                .iter()
                .map(|(m, a, p)| a * (2.0 * std::f32::consts::PI * m * xpos + p).sin())
                .sum()
        };
        for i in 0..nx {
            let xpos = i as f32 / nx as f32;
            u0[i] = eval(xpos);
            ut[i] = eval(xpos - shift); // exact periodic solution
        }
        d.push_f32(&u0, &ut);
    }
    d
}

/// Noisy linear regression for the MLP quickstart / SVGD demos:
/// y = <w*, x> + eps with a fixed deterministic w*.
pub fn linear(n: usize, in_dim: usize, noise: f32, seed: u64) -> Dataset {
    let mut wrng = Rng::new(0xfeed).fold_in(in_dim as u64);
    let wstar: Vec<f32> = (0..in_dim).map(|_| wrng.normal()).collect();
    let mut rng = Rng::new(seed).fold_in(0x6c696e);
    let mut d = Dataset::new_f32(vec![in_dim], vec![1]);
    let mut x = vec![0.0f32; in_dim];
    for _ in 0..n {
        for xi in x.iter_mut() {
            *xi = rng.normal();
        }
        let y = x.iter().zip(&wstar).map(|(a, b)| a * b).sum::<f32>() + noise * rng.normal();
        d.push_f32(&x, &[y]);
    }
    d
}

/// Two-class spiral: two interleaved Archimedean arms (arm k rotated by
/// π), radius growing 0.2 → 1.0 over `turns` full rotations, plus
/// Gaussian coordinate noise. With `turns >= 1` the arms wrap around each
/// other, so NO linear decision boundary separates them — the task the
/// CI accuracy gate uses to prove `mlp_native` learns something
/// `linear_spiral_native` provably cannot (see
/// `spiral_is_not_linearly_separable` below for the checked form of
/// "provably").
pub fn spiral(n: usize, turns: f32, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fold_in(0x7370_6972);
    let mut d = Dataset::new_classify(vec![2]);
    for i in 0..n {
        let label = (i % 2) as i32;
        let t = rng.uniform_in(0.0, 1.0);
        let angle = t * turns * 2.0 * std::f32::consts::PI + label as f32 * std::f32::consts::PI;
        let radius = 0.2 + 0.8 * t;
        let x = [
            radius * angle.cos() + noise * rng.normal(),
            radius * angle.sin() + noise * rng.normal(),
        ];
        d.push_classify(&x, label);
    }
    d
}

/// Nonlinear 1-D signal regression: inputs are random Fourier series on an
/// `nx` grid (the `advection` initial-condition family) and the target is
/// the signal's RMS amplitude `sqrt(mean u²)` (+ noise). The map u → RMS
/// is EVEN in u — negating a signal leaves its target unchanged — so every
/// linear predictor has zero covariance with the target and the task is
/// only learnable through a nonlinearity (|u| is exactly what paired ReLU
/// conv channels represent).
pub fn wave_energy(n: usize, nx: usize, modes: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fold_in(0x7761_7665);
    let mut d = Dataset::new_f32(vec![nx], vec![1]);
    let mut u = vec![0.0f32; nx];
    for _ in 0..n {
        let coeffs: Vec<(f32, f32, f32)> = (1..=modes)
            .map(|m| {
                let amp = rng.normal() / m as f32;
                let phase = rng.uniform_in(0.0, 2.0 * std::f32::consts::PI);
                (m as f32, amp, phase)
            })
            .collect();
        for (i, ui) in u.iter_mut().enumerate() {
            let xpos = i as f32 / nx as f32;
            *ui = coeffs
                .iter()
                .map(|(m, a, p)| a * (2.0 * std::f32::consts::PI * m * xpos + p).sin())
                .sum();
        }
        let rms = (u.iter().map(|v| v * v).sum::<f32>() / nx as f32).sqrt();
        d.push_f32(&u, &[rms + noise * rng.normal()]);
    }
    d
}

/// Energy-only variant of [`md17_like`] packing y[()]-per-sample — the
/// SchNet contract (y_shape = [B]).
pub fn md17_energy(n: usize, atoms: usize, species: usize, seed: u64) -> Dataset {
    let full = md17_like(n, atoms, species, seed);
    let mut d = Dataset::new_f32(vec![atoms, 3 + species], vec![]);
    let ys = full.y_stride();
    for i in 0..full.n {
        let x = &full.xs[i * full.x_stride()..(i + 1) * full.x_stride()];
        let y = full.ys_f[i * ys]; // energy only
        d.push_f32(x, &[y]);
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_labels() {
        let d = mnist_like(50, 0.3, 1);
        assert_eq!(d.n, 50);
        assert_eq!(d.x_stride(), 784);
        assert!(d.ys_i.iter().all(|&l| (0..10).contains(&l)));
        // balanced classes by construction
        assert_eq!(d.ys_i.iter().filter(|&&l| l == 0).count(), 5);
    }

    #[test]
    fn mnist_like_is_reproducible() {
        let a = mnist_like(10, 0.3, 7);
        let b = mnist_like(10, 0.3, 7);
        assert_eq!(a.xs, b.xs);
        let c = mnist_like(10, 0.3, 8);
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn md17_forces_are_negative_gradient() {
        // finite-difference check of the generator itself on one sample
        let d = md17_like(1, 4, 2, 3);
        assert_eq!(d.x_stride(), 4 * 5);
        assert_eq!(d.y_stride(), 1 + 12);
        // energies normalized: magnitudes sane
        assert!(d.ys_f[0].abs() < 10.0);
    }

    #[test]
    fn md17_energy_matches_full() {
        let full = md17_like(5, 4, 2, 9);
        let e = md17_energy(5, 4, 2, 9);
        for i in 0..5 {
            assert_eq!(e.ys_f[i], full.ys_f[i * full.y_stride()]);
        }
    }

    #[test]
    fn advection_zero_time_is_identity() {
        let d = advection(3, 32, 1.0, 0.0, 4, 5);
        for i in 0..3 {
            let x = &d.xs[i * 32..(i + 1) * 32];
            let y = &d.ys_f[i * 32..(i + 1) * 32];
            for (a, b) in x.iter().zip(y) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn advection_shift_is_periodic() {
        // shifting by a full period returns the initial condition
        let d = advection(2, 64, 1.0, 1.0, 3, 6);
        for i in 0..2 {
            let x = &d.xs[i * 64..(i + 1) * 64];
            let y = &d.ys_f[i * 64..(i + 1) * 64];
            for (a, b) in x.iter().zip(y) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn spiral_shapes_balance_and_reproducibility() {
        let d = spiral(100, 1.5, 0.02, 3);
        assert_eq!(d.n, 100);
        assert_eq!(d.x_stride(), 2);
        assert_eq!(d.ys_i.iter().filter(|&&l| l == 0).count(), 50);
        assert_eq!(spiral(100, 1.5, 0.02, 3).xs, d.xs);
        assert_ne!(spiral(100, 1.5, 0.02, 4).xs, d.xs);
        // points live inside the unit-ish disk
        assert!(d.xs.iter().all(|v| v.abs() < 1.2));
    }

    #[test]
    fn spiral_is_not_linearly_separable() {
        // The gate's premise, checked directly: sweep 72 boundary
        // directions and every threshold along each; the BEST linear
        // classifier must stay well below perfect.
        let d = spiral(400, 1.5, 0.02, 11);
        let mut best = 0usize;
        for k in 0..72 {
            let phi = k as f32 / 72.0 * std::f32::consts::PI;
            let (c, s) = (phi.cos(), phi.sin());
            let mut proj: Vec<(f32, i32)> = (0..d.n)
                .map(|i| (c * d.xs[2 * i] + s * d.xs[2 * i + 1], d.ys_i[i]))
                .collect();
            proj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // prefix counts: accuracy of "label 1 iff proj > threshold"
            // at every cut, both polarities
            let ones: usize = proj.iter().filter(|p| p.1 == 1).count();
            let mut ones_below = 0usize;
            for cut in 0..=d.n {
                let zeros_below = cut - ones_below;
                let correct = zeros_below + (ones - ones_below);
                best = best.max(correct).max(d.n - correct);
                if cut < d.n && proj[cut].1 == 1 {
                    ones_below += 1;
                }
            }
        }
        let acc = best as f32 / d.n as f32;
        assert!(acc < 0.8, "a linear boundary reached {acc} on the spiral");
    }

    #[test]
    fn wave_energy_targets_are_nonlinear_in_the_signal() {
        let d = wave_energy(500, 32, 4, 0.0, 8);
        assert_eq!(d.x_stride(), 32);
        assert_eq!(d.y_stride(), 1);
        // RMS targets are nonnegative and non-degenerate
        assert!(d.ys_f.iter().all(|&y| y >= 0.0));
        let mu = d.ys_f.iter().sum::<f32>() / d.n as f32;
        assert!(mu > 0.1, "mean RMS {mu}");
        // evenness: per-coordinate linear correlation with the target is
        // ~0 (a linear model has nothing to grab)
        let sd_y = {
            let v = d.ys_f.iter().map(|y| (y - mu) * (y - mu)).sum::<f32>() / d.n as f32;
            v.sqrt().max(1e-6)
        };
        let mut mean_abs_corr = 0.0f32;
        for j in 0..32 {
            let mx = (0..d.n).map(|i| d.xs[i * 32 + j]).sum::<f32>() / d.n as f32;
            let mut cov = 0.0f32;
            let mut var = 0.0f32;
            for i in 0..d.n {
                let dx = d.xs[i * 32 + j] - mx;
                cov += dx * (d.ys_f[i] - mu);
                var += dx * dx;
            }
            let sd_x = (var / d.n as f32).sqrt().max(1e-6);
            mean_abs_corr += (cov / d.n as f32 / sd_x / sd_y).abs();
        }
        mean_abs_corr /= 32.0;
        assert!(mean_abs_corr < 0.1, "mean |corr| {mean_abs_corr}");
        // reproducible
        assert_eq!(wave_energy(5, 32, 4, 0.0, 8).xs, d.xs[..5 * 32]);
    }

    #[test]
    fn linear_snr_behaves() {
        let d = linear(1000, 8, 0.0, 2);
        // noiseless: y exactly reproducible from a fixed w*; variance > 0
        let var = {
            let mu = d.ys_f.iter().sum::<f32>() / d.n as f32;
            d.ys_f.iter().map(|y| (y - mu) * (y - mu)).sum::<f32>() / d.n as f32
        };
        assert!(var > 0.5, "target variance {var}");
    }
}
