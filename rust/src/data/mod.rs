//! Synthetic datasets + DataLoader (DESIGN.md §Dataset-substitutions).
//!
//! The paper trains on MNIST, MD17 and PDEBench-Advection; none ship with
//! this testbed, so each is replaced by a deterministic generator that
//! preserves the property the experiment needs:
//!
//! * [`synth::mnist_like`] — 10-class 28x28 images from class templates +
//!   noise: same shapes/batching as MNIST and *learnable* (Tables 3/4
//!   compare accuracies).
//! * [`synth::md17_like`] — atoms jittered around an equilibrium geometry
//!   with energies/forces from a Morse-style pair potential: regression
//!   with a force term, driving the CGCNN second-order autodiff path.
//! * [`synth::advection`] — periodic 1-D advection with random-Fourier
//!   initial conditions; exact solution u(x,t) = u0(x - ct) gives the
//!   UNet's operator-learning pairs.
//! * [`synth::linear`] — noisy linear regression for the MLP quickstart /
//!   SVGD examples.

pub mod loader;
pub mod synth;

pub use loader::{Batch, BatchSource, BatchStream, DataLoader, Dataset, PrefetchLoader};
