//! Node-local handler programs (DESIGN.md §Distributed NEL).
//!
//! Handler tables are closures and can never cross the wire. What crosses
//! instead is a PROGRAM NAME plus a serializable config `Value`
//! ([`crate::pd::wire::CreateSpec`]); every node resolves the name in
//! this registry and builds the handler table locally — so an algorithm's
//! handlers are constructed from the same code on every node, and the
//! algorithm itself stays transport-oblivious (the Edward2/ZhuSuan
//! lesson: distribution is a property of the runtime seam, not of the
//! inference code).
//!
//! Built-ins:
//! * `"sgmcmc"` — the SGLD/SGHMC chain handlers
//!   (`infer::sgmcmc::chain_handler_table` from a wire config).
//! * `"echo"` — a tiny diagnostic program (PING/WHO/FAIL) used by the
//!   transport tests and micro-benches.
//!
//! Algorithms that want to span nodes register theirs via
//! [`register_program`] (last registration wins, so tests can shadow).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::particle::{handler, HandlerTable, PushError, Value};
use crate::runtime::ModelSpec;

/// Builds a particle's handler table from a wire config, node-locally.
pub type ProgramBuilder =
    Arc<dyn Fn(&Value, &ModelSpec) -> Result<HandlerTable, PushError> + Send + Sync>;

fn registry() -> &'static RwLock<BTreeMap<String, ProgramBuilder>> {
    static REG: OnceLock<RwLock<BTreeMap<String, ProgramBuilder>>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut m: BTreeMap<String, ProgramBuilder> = BTreeMap::new();
        m.insert(
            "sgmcmc".to_string(),
            Arc::new(|cfg, _model| {
                let cfg = crate::infer::sgmcmc::SgmcmcConfig::from_wire(cfg)?;
                Ok(crate::infer::sgmcmc::chain_handler_table(&cfg))
            }),
        );
        m.insert("echo".to_string(), Arc::new(|_cfg, _model| Ok(echo_handlers())));
        RwLock::new(m)
    })
}

/// Register (or shadow) a handler program under `name` on this node.
pub fn register_program(name: &str, builder: ProgramBuilder) {
    registry().write().unwrap().insert(name.to_string(), builder);
}

/// Resolve `name` and build its handler table for a particle of `model`.
pub fn build_handlers(
    name: &str,
    cfg: &Value,
    model: &ModelSpec,
) -> Result<HandlerTable, PushError> {
    let builder = registry().read().unwrap().get(name).cloned();
    match builder {
        Some(b) => b(cfg, model),
        None => {
            let known: Vec<String> = registry().read().unwrap().keys().cloned().collect();
            Err(PushError::new(format!(
                "unknown handler program {name:?} on this node (registered: {})",
                known.join(", ")
            )))
        }
    }
}

/// The diagnostic program: `PING` -> Unit, `WHO` -> Usize(pid),
/// `FAIL` -> an error naming the particle (exercises per-position error
/// propagation through broadcast batches and join_all ordering).
fn echo_handlers() -> HandlerTable {
    let ping = handler(|_ctx, _args| Ok(Value::Unit));
    let who = handler(|ctx, _args| Ok(Value::Usize(ctx.pid.0 as usize)));
    let fail = handler(|ctx, _args| {
        Err(PushError::new(format!("echo FAIL on {}", ctx.pid)))
    });
    [
        ("PING".to_string(), ping),
        ("WHO".to_string(), who),
        ("FAIL".to_string(), fail),
    ]
    .into_iter()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap as Map;
    use crate::runtime::DType;

    fn model() -> ModelSpec {
        ModelSpec {
            name: "programs_test".to_string(),
            param_count: 1,
            task: "regress".to_string(),
            x_shape: vec![1],
            y_shape: vec![1],
            y_dtype: DType::F32,
            arch: "none".to_string(),
            meta: Map::new(),
            entries: Map::new(),
        }
    }

    #[test]
    fn builtin_programs_resolve() {
        let m = model();
        let echo = build_handlers("echo", &Value::Unit, &m).unwrap();
        assert!(echo.contains_key("PING"));
        assert!(echo.contains_key("WHO"));
        assert!(echo.contains_key("FAIL"));

        let cfg = crate::infer::sgmcmc::SgmcmcConfig {
            model: crate::infer::sgmcmc::linear_native_model(),
            ..crate::infer::sgmcmc::SgmcmcConfig::default()
        };
        let chains = build_handlers("sgmcmc", &cfg.to_wire().unwrap(), &m).unwrap();
        assert!(chains.contains_key("MCMC_STEP"));
        assert!(chains.contains_key("MCMC_PREDICT"));
    }

    #[test]
    fn unknown_program_lists_known_names() {
        let err = build_handlers("nope", &Value::Unit, &model()).unwrap_err();
        assert!(err.msg.contains("unknown handler program"), "{err}");
        assert!(err.msg.contains("sgmcmc"), "{err}");
    }

    #[test]
    fn registration_shadows() {
        register_program(
            "programs_test_shadow",
            Arc::new(|_c, _m| Ok(HandlerTable::new())),
        );
        assert!(build_handlers("programs_test_shadow", &Value::Unit, &model())
            .unwrap()
            .is_empty());
    }
}
