//! The node fabric: the PD's router over one or more node transports
//! (DESIGN.md §Distributed NEL).
//!
//! The fabric is the ONLY pid authority in a multi-node PD: it allocates
//! global pids monotonically, places particles round-robin across nodes
//! (pid stripes — `pid % nodes` under pure round-robin creation), and
//! keeps a range-compressed pid→node table for O(log ranges) routing.
//! Because nodes register particles under the fabric's GLOBAL pid
//! ([`CreateOpts::pid`]), every deterministic stream keyed by
//! (seed, pid, step) — SGMCMC noise, reservoir acceptance, init — is
//! placement-invariant: a 2-node run reproduces a 1-node run exactly.
//!
//! Cross-node batching: `broadcast` groups the target pids by owning
//! node, issues ONE transport broadcast per destination node (one frame
//! on a wire transport — the node-level mirror of the device layer's
//! `charge_transfer_batch` aggregation), and reassembles the reply
//! futures in input order, so `PFuture::join_all`'s
//! first-error-by-position semantics are preserved verbatim across the
//! wire. Barriers (`drain_params`) and stats union over nodes; stats are
//! summed ONCE via [`NelStats::merged`].

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::nel::{CreateOpts, Nel, NelConfig, NelStats};
use crate::particle::{PFuture, Pid, PushError, Value};
use crate::pd::transport::{
    loopback_node, InProc, NodeTransport, TcpNode, TransportCounters,
};
use crate::pd::wire::{CreateSpec, DirectOp};
use crate::runtime::{ModelSpec, Tensor};

/// How the PD reaches its nodes.
#[derive(Debug, Clone)]
pub enum TransportKind {
    /// Every node is an in-process NEL (today's behavior; with 1 node it
    /// is bitwise-identical to the pre-fabric PD).
    InProc,
    /// Every node is a real-socket server on 127.0.0.1 (spawned
    /// in-process on ephemeral ports — hermetic, but all serialization
    /// and scheduling is the real distributed path).
    TcpLoopback,
    /// Connect to externally launched `push node-worker` servers; one
    /// address per node.
    TcpConnect(Vec<SocketAddr>),
}

/// Node topology of a PD.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub transport: TransportKind,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { nodes: 1, transport: TransportKind::InProc }
    }
}

/// Serializable creation options (the fabric adds the pid). The
/// spec-based twin of [`CreateOpts`] for particles that may land on any
/// node: handlers come from a registered program instead of closures.
#[derive(Debug, Clone, Default)]
pub struct SpecOpts {
    pub device: Option<usize>,
    pub program: Option<(String, Value)>,
    pub state: Vec<(String, Value)>,
    pub no_params: bool,
    pub init_params: Option<Tensor>,
}

/// A contiguous run of pids owned by one node. Pids are allocated
/// monotonically, so the table stays sorted by construction and
/// consecutive same-node creations merge into one range.
#[derive(Debug, Clone, Copy)]
struct PidRange {
    start: u32,
    /// exclusive
    end: u32,
    node: usize,
}

pub struct NodeFabric {
    links: Vec<Box<dyn NodeTransport>>,
    /// Name of the model every node must serve; stamped into each
    /// `CreateSpec` so a mis-pointed node worker fails at creation.
    model_name: String,
    ranges: Mutex<Vec<PidRange>>,
    next_pid: AtomicU32,
    next_node: AtomicUsize,
}

impl NodeFabric {
    pub fn new(topology: &Topology, cfg: &NelConfig, model: Arc<ModelSpec>) -> Result<NodeFabric> {
        ensure!(topology.nodes >= 1, "a PD needs at least one node");
        let mut links: Vec<Box<dyn NodeTransport>> = Vec::with_capacity(topology.nodes);
        for i in 0..topology.nodes {
            // Single-node fabrics keep node: None so every error message
            // (and everything else) matches the pre-fabric PD exactly.
            let node = (topology.nodes > 1).then_some(i);
            let node_cfg = NelConfig { node, ..cfg.clone() };
            match &topology.transport {
                TransportKind::InProc => {
                    links.push(Box::new(InProc::new(node_cfg, model.clone())?));
                }
                TransportKind::TcpLoopback => {
                    links.push(Box::new(loopback_node(node_cfg, model.clone())?));
                }
                TransportKind::TcpConnect(addrs) => {
                    ensure!(
                        addrs.len() == topology.nodes,
                        "need {} node addresses, got {}",
                        topology.nodes,
                        addrs.len()
                    );
                    links.push(Box::new(TcpNode::connect(addrs[i])?));
                }
            }
        }
        Ok(NodeFabric {
            links,
            model_name: model.name.clone(),
            ranges: Mutex::new(Vec::new()),
            next_pid: AtomicU32::new(0),
            next_node: AtomicUsize::new(0),
        })
    }

    pub fn nodes(&self) -> usize {
        self.links.len()
    }

    pub fn kind(&self) -> &'static str {
        self.links[0].kind()
    }

    /// The in-process NEL of node 0, when it has one.
    pub fn nel(&self) -> Option<&Nel> {
        self.links[0].nel()
    }

    /// Which node owns `pid` (None for pids this fabric never created).
    pub fn node_of(&self, pid: Pid) -> Option<usize> {
        let ranges = self.ranges.lock().unwrap();
        ranges
            .binary_search_by(|r| {
                if pid.0 < r.start {
                    std::cmp::Ordering::Greater
                } else if pid.0 >= r.end {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .ok()
            .map(|i| ranges[i].node)
    }

    /// All pids in creation order (ranges are sorted by start).
    pub fn particle_ids(&self) -> Vec<Pid> {
        self.ranges
            .lock()
            .unwrap()
            .iter()
            .flat_map(|r| (r.start..r.end).map(Pid))
            .collect()
    }

    fn record(&self, pid: u32, node: usize) {
        let mut ranges = self.ranges.lock().unwrap();
        // Sorted insert: creations usually arrive in pid order (the common
        // case extends the last range), but concurrent creators may finish
        // out of order — the table must stay sorted for the binary search.
        let pos = ranges.partition_point(|r| r.start < pid);
        if pos > 0 {
            let prev = &mut ranges[pos - 1];
            if prev.node == node && prev.end == pid {
                prev.end = pid + 1;
                return;
            }
        }
        ranges.insert(pos, PidRange { start: pid, end: pid + 1, node });
    }

    fn alloc(&self) -> (Pid, usize) {
        let pid = Pid(self.next_pid.fetch_add(1, Ordering::Relaxed));
        let node = self.next_node.fetch_add(1, Ordering::Relaxed) % self.links.len();
        (pid, node)
    }

    fn unknown(&self, pid: Pid) -> PushError {
        PushError::new(format!("unknown particle {pid}"))
    }

    /// In-process creation with closure handlers. Routes round-robin;
    /// wire transports reject it (closures cannot cross the wire).
    pub fn create_local(&self, opts: CreateOpts) -> Result<Pid> {
        let (pid, node) = self.alloc();
        let created = self.links[node]
            .create_local(CreateOpts { pid: Some(pid), ..opts })
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(created, pid);
        self.record(pid.0, node);
        Ok(pid)
    }

    /// Spec-based creation (program-resolved handlers); works on every
    /// transport.
    pub fn create_spec(&self, opts: SpecOpts) -> Result<Pid> {
        let (pid, node) = self.alloc();
        let spec = CreateSpec {
            pid,
            device: opts.device,
            program: opts.program,
            state: opts.state,
            no_params: opts.no_params,
            init_params: opts.init_params,
            model: self.model_name.clone(),
        };
        let created =
            self.links[node].create_spec(spec).map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(created, pid);
        self.record(pid.0, node);
        Ok(pid)
    }

    pub fn send(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        match self.node_of(pid) {
            Some(n) => self.links[n].send(pid, msg, args),
            None => PFuture::ready(Err(self.unknown(pid))),
        }
    }

    /// Batched fan-out: one transport broadcast (= one frame on a wire
    /// link) per destination node; reply futures in input order.
    pub fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        if pids.is_empty() {
            return Vec::new();
        }
        if self.links.len() == 1 {
            // Single node: hand the whole batch straight down — the
            // in-process path stays exactly `Nel::broadcast`.
            return self.links[0].broadcast(pids, msg, args);
        }
        let mut groups: BTreeMap<usize, (Vec<usize>, Vec<Pid>)> = BTreeMap::new();
        let mut slots: Vec<Option<PFuture>> = Vec::with_capacity(pids.len());
        for (i, pid) in pids.iter().enumerate() {
            match self.node_of(*pid) {
                Some(n) => {
                    let g = groups.entry(n).or_default();
                    g.0.push(i);
                    g.1.push(*pid);
                    slots.push(None);
                }
                None => slots.push(Some(PFuture::ready(Err(self.unknown(*pid))))),
            }
        }
        for (n, (positions, node_pids)) in groups {
            let futs = self.links[n].broadcast(&node_pids, msg, args.clone());
            for (pos, fut) in positions.into_iter().zip(futs) {
                slots[pos] = Some(fut);
            }
        }
        slots.into_iter().map(|f| f.expect("every slot filled")).collect()
    }

    pub fn direct(&self, op: DirectOp) -> PFuture {
        match self.node_of(op.pid()) {
            Some(n) => self.links[n].direct(op),
            None => {
                let pid = op.pid();
                PFuture::ready(Err(self.unknown(pid)))
            }
        }
    }

    /// Barrier + snapshot across every node.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        let mut out = BTreeMap::new();
        for link in &self.links {
            for (pid, t) in link.drain_params()? {
                out.insert(pid, t);
            }
        }
        Ok(out)
    }

    pub fn particle_state(
        &self,
        pid: Pid,
    ) -> Result<Option<Vec<(String, Value)>>, PushError> {
        match self.node_of(pid) {
            Some(n) => self.links[n].particle_state(pid),
            None => Ok(None),
        }
    }

    pub fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        match self.node_of(pid) {
            Some(n) => self.links[n].restore_particle_state(pid, entries),
            None => Err(self.unknown(pid)),
        }
    }

    /// Per-node stats, in node order.
    pub fn node_stats(&self) -> Result<Vec<NelStats>, PushError> {
        self.links.iter().map(|l| l.stats()).collect()
    }

    /// Fabric-wide stats: per-node stats summed exactly once.
    pub fn stats(&self) -> Result<NelStats, PushError> {
        let per_node = self.node_stats()?;
        Ok(NelStats::merged(per_node.iter()))
    }

    /// Per-node transport frame/byte counters, in node order.
    pub fn transport_counters(&self) -> Vec<TransportCounters> {
        self.links.iter().map(|l| l.counters()).collect()
    }
}
