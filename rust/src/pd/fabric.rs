//! The node fabric: the PD's router over one or more node transports
//! (DESIGN.md §Distributed NEL).
//!
//! The fabric is the ONLY pid authority in a multi-node PD: it allocates
//! global pids monotonically, places particles round-robin across nodes
//! (pid stripes — `pid % nodes` under pure round-robin creation), and
//! keeps a range-compressed pid→node table for O(log ranges) routing.
//! Because nodes register particles under the fabric's GLOBAL pid
//! ([`CreateOpts::pid`]), every deterministic stream keyed by
//! (seed, pid, step) — SGMCMC noise, reservoir acceptance, init — is
//! placement-invariant: a 2-node run reproduces a 1-node run exactly.
//!
//! Cross-node batching: `broadcast` groups the target pids by owning
//! node, issues ONE transport broadcast per destination node (one frame
//! on a wire transport — the node-level mirror of the device layer's
//! `charge_transfer_batch` aggregation), and reassembles the reply
//! futures in input order, so `PFuture::join_all`'s
//! first-error-by-position semantics are preserved verbatim across the
//! wire. Barriers (`drain_params`) and stats union over nodes; stats are
//! summed ONCE via [`NelStats::merged`].

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::nel::{CreateOpts, Nel, NelConfig, NelStats};
use crate::particle::{PFuture, Pid, PushError, Value};
use crate::pd::transport::{
    decode_state_value, loopback_node, loopback_node_evented, wait_deadline, InProc,
    LinkHealth, NodeTransport, TcpNode, TransportCounters,
};
use crate::pd::wire::{CreateSpec, DirectOp};
use crate::runtime::{ModelSpec, Tensor};

/// How the PD reaches its nodes.
#[derive(Debug, Clone)]
pub enum TransportKind {
    /// Every node is an in-process NEL (today's behavior; with 1 node it
    /// is bitwise-identical to the pre-fabric PD).
    InProc,
    /// Every node is a real-socket server on 127.0.0.1 (spawned
    /// in-process on ephemeral ports — hermetic, but all serialization
    /// and scheduling is the real distributed path).
    TcpLoopback,
    /// Connect to externally launched `push node-worker` servers; one
    /// address per node.
    TcpConnect(Vec<SocketAddr>),
    /// [`TransportKind::TcpLoopback`] on the event-driven flavor: same
    /// wire protocol and invariants, but every connection (both halves)
    /// is multiplexed onto the reactor's fixed poll pool instead of
    /// dedicated reader/writer threads.
    TcpLoopbackEvented,
    /// [`TransportKind::TcpConnect`] with evented client links.
    TcpConnectEvented(Vec<SocketAddr>),
}

/// Node topology of a PD.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub transport: TransportKind,
}

/// One pid's position in a batched reservoir snapshot
/// ([`NodeFabric::snapshot_chains`]): the particle's state entries
/// (`None` = no such particle) or the transport error that lost it.
pub type ChainStateResult = (Pid, Result<Option<Vec<(String, Value)>>, PushError>);

impl Default for Topology {
    fn default() -> Self {
        Topology { nodes: 1, transport: TransportKind::InProc }
    }
}

/// Liveness configuration of the fabric (DESIGN.md §Elastic fabric),
/// deliberately separate from [`Topology`]: WHERE the nodes are is
/// orthogonal to HOW their liveness is watched.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Heartbeat-probe cadence of the monitor thread; `None` (the
    /// default) disables the monitor — a dead link is then only noticed
    /// when a request on it fails.
    pub heartbeat_every: Option<Duration>,
    /// Silence threshold past which a link is declared dead and severed,
    /// failing its pending futures promptly instead of hanging `wait()`.
    pub dead_after: Duration,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig { heartbeat_every: None, dead_after: Duration::from_secs(2) }
    }
}

/// Serializable creation options (the fabric adds the pid). The
/// spec-based twin of [`CreateOpts`] for particles that may land on any
/// node: handlers come from a registered program instead of closures.
#[derive(Debug, Clone, Default)]
pub struct SpecOpts {
    pub device: Option<usize>,
    pub program: Option<(String, Value)>,
    pub state: Vec<(String, Value)>,
    pub no_params: bool,
    pub init_params: Option<Tensor>,
}

/// A contiguous run of pids owned by one node. Pids are allocated
/// monotonically, so the table stays sorted by construction and
/// consecutive same-node creations merge into one range.
#[derive(Debug, Clone, Copy)]
struct PidRange {
    start: u32,
    /// exclusive
    end: u32,
    node: usize,
}

/// The re-creation recipe of one spec-created particle, kept so a dead
/// node's particles can be migrated: the original [`SpecOpts`] minus the
/// volatile parts (params/state come from the caller's checkpoint, not
/// from creation time). Closure-created particles have no recipe and are
/// non-migratable by construction.
#[derive(Debug, Clone)]
struct RecreateSpec {
    device: Option<usize>,
    program: Option<(String, Value)>,
    no_params: bool,
}

pub struct NodeFabric {
    links: Vec<Arc<dyn NodeTransport>>,
    /// Name of the model every node must serve; stamped into each
    /// `CreateSpec` so a mis-pointed node worker fails at creation.
    model_name: String,
    ranges: Mutex<Vec<PidRange>>,
    next_pid: AtomicU32,
    next_node: AtomicUsize,
    recreate: Mutex<BTreeMap<u32, RecreateSpec>>,
    monitor_stop: Arc<AtomicBool>,
    monitor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NodeFabric {
    pub fn new(
        topology: &Topology,
        cfg: &NelConfig,
        model: Arc<ModelSpec>,
        fabric_cfg: &FabricConfig,
    ) -> Result<NodeFabric> {
        ensure!(topology.nodes >= 1, "a PD needs at least one node");
        let mut links: Vec<Arc<dyn NodeTransport>> = Vec::with_capacity(topology.nodes);
        for i in 0..topology.nodes {
            // Single-node fabrics keep node: None so every error message
            // (and everything else) matches the pre-fabric PD exactly.
            let node = (topology.nodes > 1).then_some(i);
            let node_cfg = NelConfig { node, ..cfg.clone() };
            match &topology.transport {
                TransportKind::InProc => {
                    links.push(Arc::new(InProc::new(node_cfg, model.clone())?));
                }
                TransportKind::TcpLoopback => {
                    links.push(Arc::new(loopback_node(node_cfg, model.clone())?));
                }
                TransportKind::TcpConnect(addrs) => {
                    ensure!(
                        addrs.len() == topology.nodes,
                        "need {} node addresses, got {}",
                        topology.nodes,
                        addrs.len()
                    );
                    // Backoff: externally launched node workers may still
                    // be binding their ports — launch order must not
                    // matter (6 tries over ~3 s).
                    links.push(Arc::new(TcpNode::connect_with_backoff(addrs[i], 6)?));
                }
                TransportKind::TcpLoopbackEvented => {
                    links.push(Arc::new(loopback_node_evented(node_cfg, model.clone())?));
                }
                TransportKind::TcpConnectEvented(addrs) => {
                    ensure!(
                        addrs.len() == topology.nodes,
                        "need {} node addresses, got {}",
                        topology.nodes,
                        addrs.len()
                    );
                    links.push(Arc::new(TcpNode::connect_evented_with_backoff(addrs[i], 6)?));
                }
            }
        }
        let monitor_stop = Arc::new(AtomicBool::new(false));
        let monitor = match fabric_cfg.heartbeat_every {
            None => None,
            Some(every) => Some(spawn_monitor(
                links.clone(),
                every,
                fabric_cfg.dead_after,
                monitor_stop.clone(),
            )?),
        };
        Ok(NodeFabric {
            links,
            model_name: model.name.clone(),
            ranges: Mutex::new(Vec::new()),
            next_pid: AtomicU32::new(0),
            next_node: AtomicUsize::new(0),
            recreate: Mutex::new(BTreeMap::new()),
            monitor_stop,
            monitor: Mutex::new(monitor),
        })
    }

    pub fn nodes(&self) -> usize {
        self.links.len()
    }

    /// Per-link liveness verdicts, in node order. With the monitor off,
    /// a wire link still reports `Dead` once its connection closed.
    pub fn link_health(&self) -> Vec<LinkHealth> {
        self.links.iter().map(|l| l.health()).collect()
    }

    /// Nodes whose links are dead (particles there need migration).
    pub fn dead_nodes(&self) -> Vec<usize> {
        self.links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.health() == LinkHealth::Dead)
            .map(|(i, _)| i)
            .collect()
    }

    /// Peer address of a wire link (None in-process).
    pub fn peer_addr(&self, node: usize) -> Option<SocketAddr> {
        self.links.get(node).and_then(|l| l.peer_addr())
    }

    pub fn kind(&self) -> &'static str {
        self.links[0].kind()
    }

    /// The in-process NEL of node 0, when it has one.
    pub fn nel(&self) -> Option<&Nel> {
        self.links[0].nel()
    }

    /// Which node owns `pid` (None for pids this fabric never created).
    pub fn node_of(&self, pid: Pid) -> Option<usize> {
        let ranges = self.ranges.lock().unwrap();
        ranges
            .binary_search_by(|r| {
                if pid.0 < r.start {
                    std::cmp::Ordering::Greater
                } else if pid.0 >= r.end {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .ok()
            .map(|i| ranges[i].node)
    }

    /// All pids in creation order (ranges are sorted by start).
    pub fn particle_ids(&self) -> Vec<Pid> {
        self.ranges
            .lock()
            .unwrap()
            .iter()
            .flat_map(|r| (r.start..r.end).map(Pid))
            .collect()
    }

    fn record(&self, pid: u32, node: usize) {
        let mut ranges = self.ranges.lock().unwrap();
        // Sorted insert: creations usually arrive in pid order (the common
        // case extends the last range), but concurrent creators may finish
        // out of order — the table must stay sorted for the binary search.
        let pos = ranges.partition_point(|r| r.start < pid);
        if pos > 0 {
            let prev = &mut ranges[pos - 1];
            if prev.node == node && prev.end == pid {
                prev.end = pid + 1;
                return;
            }
        }
        ranges.insert(pos, PidRange { start: pid, end: pid + 1, node });
    }

    fn alloc(&self) -> (Pid, usize) {
        let pid = Pid(self.next_pid.fetch_add(1, Ordering::Relaxed));
        let node = self.next_node.fetch_add(1, Ordering::Relaxed) % self.links.len();
        (pid, node)
    }

    fn unknown(&self, pid: Pid) -> PushError {
        PushError::new(format!("unknown particle {pid}"))
    }

    /// In-process creation with closure handlers. Routes round-robin;
    /// wire transports reject it (closures cannot cross the wire).
    pub fn create_local(&self, opts: CreateOpts) -> Result<Pid> {
        let (pid, node) = self.alloc();
        let created = self.links[node]
            .create_local(CreateOpts { pid: Some(pid), ..opts })
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(created, pid);
        self.record(pid.0, node);
        Ok(pid)
    }

    /// Spec-based creation (program-resolved handlers); works on every
    /// transport. The spec's non-volatile parts are remembered as the
    /// particle's re-creation recipe, making it migratable on node death.
    pub fn create_spec(&self, opts: SpecOpts) -> Result<Pid> {
        let (pid, node) = self.alloc();
        let recipe = RecreateSpec {
            device: opts.device,
            program: opts.program.clone(),
            no_params: opts.no_params,
        };
        let spec = CreateSpec {
            pid,
            device: opts.device,
            program: opts.program,
            state: opts.state,
            no_params: opts.no_params,
            init_params: opts.init_params,
            model: self.model_name.clone(),
        };
        let created =
            self.links[node].create_spec(spec).map_err(|e| anyhow::anyhow!("{e}"))?;
        debug_assert_eq!(created, pid);
        self.recreate.lock().unwrap().insert(pid.0, recipe);
        self.record(pid.0, node);
        Ok(pid)
    }

    /// Move every particle owned by `dead` nodes onto the surviving
    /// links, re-created from the caller's last checkpoint (`params` /
    /// `state`) under their ORIGINAL global pids — so every
    /// (seed, pid, step)-keyed deterministic stream continues unperturbed
    /// and a migrated run stays bit-identical to an uninterrupted one.
    /// ONE `Migrate` frame goes to each destination node. Returns the
    /// moved pids; the pid→node table is repointed on success.
    pub fn migrate(
        &self,
        dead: &[usize],
        params: &BTreeMap<Pid, Tensor>,
        state: &BTreeMap<Pid, Vec<(String, Value)>>,
    ) -> Result<Vec<Pid>> {
        ensure!(!dead.is_empty(), "no dead nodes to migrate from");
        let survivors: Vec<usize> = (0..self.links.len())
            .filter(|n| !dead.contains(n) && self.links[*n].health() != LinkHealth::Dead)
            .collect();
        ensure!(!survivors.is_empty(), "no surviving nodes to migrate to");
        let lost: Vec<Pid> = {
            let ranges = self.ranges.lock().unwrap();
            ranges
                .iter()
                .filter(|r| dead.contains(&r.node))
                .flat_map(|r| (r.start..r.end).map(Pid))
                .collect()
        };
        let recreate = self.recreate.lock().unwrap();
        let mut batches: BTreeMap<usize, Vec<CreateSpec>> = BTreeMap::new();
        let mut moves: Vec<(u32, usize)> = Vec::with_capacity(lost.len());
        for (i, pid) in lost.iter().enumerate() {
            let recipe = recreate.get(&pid.0).ok_or_else(|| {
                anyhow!(
                    "cannot migrate {pid}: created from closures, not a spec \
                     (no re-creation recipe survives the node)"
                )
            })?;
            let target = survivors[i % survivors.len()];
            batches.entry(target).or_default().push(CreateSpec {
                pid: *pid,
                device: recipe.device,
                program: recipe.program.clone(),
                state: state.get(pid).cloned().unwrap_or_default(),
                no_params: recipe.no_params,
                init_params: params.get(pid).cloned(),
                model: self.model_name.clone(),
            });
            moves.push((pid.0, target));
        }
        drop(recreate);
        for (target, specs) in batches {
            self.links[target].migrate(specs).map_err(|e| anyhow!("node {target}: {e}"))?;
        }
        self.repoint(&moves);
        Ok(moves.into_iter().map(|(p, _)| Pid(p)).collect())
    }

    /// Rewrite the pid→node table after a migration: flatten, apply the
    /// moves, re-compress (the flat list is already sorted by pid, so the
    /// compressed table stays sorted for the binary search).
    fn repoint(&self, moves: &[(u32, usize)]) {
        let mut ranges = self.ranges.lock().unwrap();
        let mut flat: Vec<(u32, usize)> = ranges
            .iter()
            .flat_map(|r| (r.start..r.end).map(|p| (p, r.node)))
            .collect();
        for (pid, node) in moves {
            if let Some(entry) = flat.iter_mut().find(|(p, _)| p == pid) {
                entry.1 = *node;
            }
        }
        let mut out: Vec<PidRange> = Vec::new();
        for (pid, node) in flat {
            match out.last_mut() {
                Some(last) if last.node == node && last.end == pid => last.end = pid + 1,
                _ => out.push(PidRange { start: pid, end: pid + 1, node }),
            }
        }
        *ranges = out;
    }

    pub fn send(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        match self.node_of(pid) {
            Some(n) => self.links[n].send(pid, msg, args),
            None => PFuture::ready(Err(self.unknown(pid))),
        }
    }

    /// Batched fan-out: one transport broadcast (= one frame on a wire
    /// link) per destination node; reply futures in input order.
    pub fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        if pids.is_empty() {
            return Vec::new();
        }
        if self.links.len() == 1 {
            // Single node: hand the whole batch straight down — the
            // in-process path stays exactly `Nel::broadcast`.
            return self.links[0].broadcast(pids, msg, args);
        }
        let mut groups: BTreeMap<usize, (Vec<usize>, Vec<Pid>)> = BTreeMap::new();
        let mut slots: Vec<Option<PFuture>> = Vec::with_capacity(pids.len());
        for (i, pid) in pids.iter().enumerate() {
            match self.node_of(*pid) {
                Some(n) => {
                    let g = groups.entry(n).or_default();
                    g.0.push(i);
                    g.1.push(*pid);
                    slots.push(None);
                }
                None => slots.push(Some(PFuture::ready(Err(self.unknown(*pid))))),
            }
        }
        for (n, (positions, node_pids)) in groups {
            let futs = self.links[n].broadcast(&node_pids, msg, args.clone());
            for (pos, fut) in positions.into_iter().zip(futs) {
                slots[pos] = Some(fut);
            }
        }
        slots.into_iter().map(|f| f.expect("every slot filled")).collect()
    }

    pub fn direct(&self, op: DirectOp) -> PFuture {
        match self.node_of(op.pid()) {
            Some(n) => self.links[n].direct(op),
            None => {
                let pid = op.pid();
                PFuture::ready(Err(self.unknown(pid)))
            }
        }
    }

    /// Barrier + snapshot across every node. Dead links are skipped: after
    /// a migration the dead node owns no pids, so asking it would only
    /// fail the barrier; a node that dies WHILE still owning pids fails
    /// the capture anyway when its particles' state is fetched.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        let mut out = BTreeMap::new();
        for link in &self.links {
            if link.health() == LinkHealth::Dead {
                continue;
            }
            for (pid, t) in link.drain_params()? {
                out.insert(pid, t);
            }
        }
        Ok(out)
    }

    pub fn particle_state(
        &self,
        pid: Pid,
    ) -> Result<Option<Vec<(String, Value)>>, PushError> {
        match self.node_of(pid) {
            Some(n) => self.links[n].particle_state(pid),
            None => Ok(None),
        }
    }

    pub fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        match self.node_of(pid) {
            Some(n) => self.links[n].restore_particle_state(pid, entries),
            None => Err(self.unknown(pid)),
        }
    }

    /// One serving refresh's worth of reservoir snapshots (one
    /// [`ChainStateResult`] per input pid): group `pids`
    /// by owning node, issue exactly ONE `SnapshotNode` request per
    /// destination node (one data frame on a wire link, regardless of
    /// chain count), then wait every reply under one SHARED `deadline`
    /// budget — all frames are in flight before the first wait, so the
    /// budget is paid once, not per node. Results come back per pid in
    /// input order; a dead or slow node fails only its own pids'
    /// positions (loudly naming the node and its address), leaving the
    /// caller to retry survivors or degrade to a stale snapshot.
    pub fn snapshot_chains(
        &self,
        pids: &[Pid],
        deadline: Option<Duration>,
    ) -> Vec<ChainStateResult> {
        if pids.is_empty() {
            return Vec::new();
        }
        let mut groups: BTreeMap<usize, (Vec<usize>, Vec<Pid>)> = BTreeMap::new();
        let mut slots: Vec<Option<PFuture>> = Vec::with_capacity(pids.len());
        for (i, pid) in pids.iter().enumerate() {
            match self.node_of(*pid) {
                Some(n) => {
                    let g = groups.entry(n).or_default();
                    g.0.push(i);
                    g.1.push(*pid);
                    slots.push(None);
                }
                None => slots.push(Some(PFuture::ready(Err(self.unknown(*pid))))),
            }
        }
        for (n, (positions, node_pids)) in groups {
            let futs = self.links[n].snapshot_node(&node_pids);
            for (pos, fut) in positions.into_iter().zip(futs) {
                slots[pos] = Some(fut);
            }
        }
        let expiry = deadline.map(|d| Instant::now() + d);
        pids.iter()
            .zip(slots)
            .map(|(pid, fut)| {
                let fut = fut.expect("every slot filled");
                let res = wait_deadline(&fut, expiry, deadline)
                    .map_err(|e| {
                        let n = self.node_of(*pid);
                        match (n, n.and_then(|n| self.peer_addr(n))) {
                            (Some(n), Some(a)) => {
                                PushError::new(format!("node {n} ({a}): {}", e.msg))
                            }
                            (Some(n), None) => {
                                PushError::new(format!("node {n}: {}", e.msg))
                            }
                            (None, _) => e,
                        }
                    })
                    .and_then(decode_state_value);
                (*pid, res)
            })
            .collect()
    }

    /// Per-node stats, in node order. Dead links report default (zero)
    /// stats instead of failing the whole read — a recovered run can still
    /// print its survivors' numbers.
    pub fn node_stats(&self) -> Result<Vec<NelStats>, PushError> {
        self.links
            .iter()
            .map(|l| {
                if l.health() == LinkHealth::Dead {
                    Ok(NelStats::default())
                } else {
                    l.stats()
                }
            })
            .collect()
    }

    /// Fabric-wide stats: per-node stats summed exactly once.
    pub fn stats(&self) -> Result<NelStats, PushError> {
        let per_node = self.node_stats()?;
        Ok(NelStats::merged(per_node.iter()))
    }

    /// Per-node transport frame/byte counters, in node order.
    pub fn transport_counters(&self) -> Vec<TransportCounters> {
        self.links.iter().map(|l| l.counters()).collect()
    }
}

impl Drop for NodeFabric {
    fn drop(&mut self) {
        self.monitor_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.monitor.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

/// The heartbeat monitor: one background thread ticking every link on the
/// configured cadence. Sleeps in small slices so fabric drop never waits
/// a full period for the thread to notice the stop flag.
fn spawn_monitor(
    links: Vec<Arc<dyn NodeTransport>>,
    every: Duration,
    dead_after: Duration,
    stop: Arc<AtomicBool>,
) -> Result<std::thread::JoinHandle<()>> {
    let handle = std::thread::Builder::new()
        .name("push-heartbeat".to_string())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for link in &links {
                    link.heartbeat_tick(dead_after);
                }
                let mut slept = Duration::from_millis(0);
                while slept < every && !stop.load(Ordering::Relaxed) {
                    let chunk = (every - slept).min(Duration::from_millis(20));
                    std::thread::sleep(chunk);
                    slept += chunk;
                }
            }
        })?;
    Ok(handle)
}
