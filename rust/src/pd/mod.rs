//! Push distribution (paper §3.3, §4.3): `P(nn_Θ)` — an input NN
//! architecture plus the set of particles that form its empirical
//! (Dirac-mixture) approximation.
//!
//! The paper runs the PD in a separate OS process from its NEL to prepare
//! for a distributed implementation; here the PD is an in-process facade
//! over one NEL (process isolation is an explicit non-goal, DESIGN.md §9 —
//! the seam is this type's API, which only moves plain `Value`s).

pub mod checkpoint;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::nel::{CreateOpts, Nel, NelConfig, NelStats};
use crate::particle::{PFuture, Pid, PushError, Value};
use crate::runtime::{Manifest, ModelSpec, Tensor};

pub struct PushDist {
    nel: Nel,
    model: Arc<ModelSpec>,
    manifest_dir: std::path::PathBuf,
    svgd: Vec<crate::runtime::SvgdSpec>,
}

impl PushDist {
    /// Wrap `model_name` from the manifest into a PD backed by a fresh NEL.
    pub fn new(manifest: &Manifest, model_name: &str, cfg: NelConfig) -> Result<PushDist> {
        let model = Arc::new(manifest.model(model_name)?.clone());
        let nel = Nel::new(cfg)?;
        Ok(PushDist {
            nel,
            model,
            manifest_dir: manifest.dir.clone(),
            svgd: manifest.svgd.clone(),
        })
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    pub fn nel(&self) -> &Nel {
        &self.nel
    }

    pub fn manifest_dir(&self) -> &std::path::Path {
        &self.manifest_dir
    }

    /// The SVGD kernel artifact for n particles of this model, if built.
    pub fn svgd_artifact(&self, n: usize) -> Option<std::path::PathBuf> {
        let d = self.model.param_count;
        self.svgd
            .iter()
            .find(|s| s.n == n && s.d == d)
            .map(|s| s.file.clone())
    }

    /// Create one particle (paper: `p_create`).
    pub fn p_create(&self, opts: CreateOpts) -> Result<Pid> {
        self.nel.p_create(self.model.clone(), opts)
    }

    /// Create `n` particles round-robin across devices with shared handlers.
    pub fn p_create_n(
        &self,
        n: usize,
        mk_opts: impl Fn(usize) -> CreateOpts,
    ) -> Result<Vec<Pid>> {
        (0..n).map(|i| self.p_create(mk_opts(i))).collect()
    }

    /// Asynchronously trigger `msg` on `pid` (paper: `p_launch`).
    pub fn p_launch(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.nel.send(None, pid, msg, args)
    }

    /// Batched `p_launch` of one message to many particles: the label is
    /// interned once, counters bump once, and the scheduler enqueues the
    /// whole fan-out in one pass (see `Nel::broadcast`). The returned
    /// futures are in `pids` order; aggregate with `PFuture::join_all`.
    pub fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        self.nel.broadcast(None, pids, msg, args)
    }

    /// Wait on futures (paper: `p_wait`).
    pub fn p_wait(&self, futs: &[PFuture]) -> Result<Vec<Value>, PushError> {
        PFuture::wait_all(futs)
    }

    pub fn particles(&self) -> Vec<Pid> {
        self.nel.particle_ids()
    }

    // ---- direct (handler-less) particle operations, used by inference
    //      drivers and baselines ----

    pub fn step(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel
            .run_entry(pid, "step", vec![x, y, Tensor::scalar_f32(lr)], Some(1))
    }

    pub fn adam_step(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.nel.run_adam(pid, x, y, lr)
    }

    pub fn forward(&self, pid: Pid, x: Tensor) -> PFuture {
        self.nel.run_entry(pid, "fwd", vec![x], None)
    }

    pub fn grad(&self, pid: Pid, x: Tensor, y: Tensor) -> PFuture {
        self.nel.run_entry(pid, "grad", vec![x, y], None)
    }

    pub fn get(&self, pid: Pid) -> PFuture {
        self.nel.get_params(None, pid)
    }

    pub fn set(&self, pid: Pid, t: Tensor) -> PFuture {
        self.nel.set_params(pid, t)
    }

    /// Posterior-mean prediction `f̂(x) = (1/n) Σ_i nn_θi(x)` (paper §3.4).
    /// Forward passes run concurrently across devices.
    pub fn mean_forward(&self, pids: &[Pid], x: &Tensor) -> Result<Tensor> {
        if pids.is_empty() {
            return Err(anyhow!("mean_forward over zero particles"));
        }
        let futs: Vec<PFuture> = pids.iter().map(|p| self.forward(*p, x.clone())).collect();
        let mut acc: Option<Tensor> = None;
        // Futures are consumed by value: each prediction ends up uniquely
        // owned when its future drops, so the axpy accumulation below runs
        // in place (no COW copies).
        for f in futs {
            let pred = f.wait().map_err(|e| anyhow!("{e}"))?.tensor().map_err(|e| anyhow!("{e}"))?;
            match &mut acc {
                None => acc = Some(pred),
                Some(a) => crate::runtime::tensor::ops::axpy(a, 1.0, &pred),
            }
        }
        let mut a = acc.unwrap();
        let n = pids.len() as f32;
        for v in a.as_f32_mut() {
            *v /= n;
        }
        Ok(a)
    }

    /// Snapshot every particle's parameters (barrier + cache flush). The
    /// returned tensors are zero-copy COW snapshots of the host store.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        self.nel.drain_params()
    }

    /// Clone one particle's local state (Adam moments, SWAG moments,
    /// SGMCMC chain state, ...). Zero-copy for tensor values.
    pub fn particle_state(&self, pid: Pid) -> Option<Vec<(String, Value)>> {
        self.nel.particle_state(pid)
    }

    /// Merge state entries back into a particle (checkpoint restore).
    pub fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        self.nel.restore_particle_state(pid, entries)
    }

    pub fn stats(&self) -> NelStats {
        self.nel.stats()
    }
}
