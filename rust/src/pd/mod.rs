//! Push distribution (paper §3.3, §4.3): `P(nn_Θ)` — an input NN
//! architecture plus the set of particles that form its empirical
//! (Dirac-mixture) approximation.
//!
//! The paper runs the PD in a separate OS process from its NEL; this PD
//! realizes that seam as a transport-backed node fabric (DESIGN.md
//! §Distributed NEL): every call routes through [`fabric::NodeFabric`],
//! whose nodes are reached either in-process ([`transport::InProc`] —
//! the degenerate single-node case, bitwise-identical to the old
//! in-process facade) or over real sockets ([`transport::TcpNode`]).
//! The API still only moves plain `Value`s, which is exactly what makes
//! the seam wire-able; inference algorithms cannot tell transports
//! apart.

pub mod checkpoint;
pub mod fabric;
pub mod poll;
pub mod programs;
pub mod transport;
pub mod wire;

pub use fabric::{ChainStateResult, FabricConfig, SpecOpts, Topology, TransportKind};
pub use transport::LinkHealth;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::nel::{CreateOpts, Nel, NelConfig, NelStats};
use crate::particle::{PFuture, Pid, PushError, Value};
use crate::pd::transport::TransportCounters;
use crate::pd::wire::DirectOp;
use crate::runtime::{Manifest, ModelSpec, Tensor};

/// Clone = an Arc bump of the shared fabric, not a new fabric: every
/// clone sees the same nodes, pids, and particles. This is what makes a
/// PD handle shareable with serving-side readers ([`PushDist::serve_handle`])
/// while the training side keeps driving the same particles.
#[derive(Clone)]
pub struct PushDist {
    fabric: Arc<fabric::NodeFabric>,
    model: Arc<ModelSpec>,
    manifest_dir: std::path::PathBuf,
    svgd: Vec<crate::runtime::SvgdSpec>,
}

impl PushDist {
    /// Wrap `model_name` from the manifest into a PD backed by a fresh
    /// single-node in-process NEL — the pre-fabric behavior, unchanged.
    pub fn new(manifest: &Manifest, model_name: &str, cfg: NelConfig) -> Result<PushDist> {
        Self::with_topology(manifest, model_name, cfg, &Topology::default())
    }

    /// Wrap `model_name` into a PD spanning `topology.nodes` nodes. Each
    /// node owns one NEL (with `cfg.num_devices` devices and its own M:N
    /// scheduler); particles are placed round-robin under fabric-assigned
    /// GLOBAL pids, so (seed, pid, step)-keyed determinism is
    /// placement-invariant.
    pub fn with_topology(
        manifest: &Manifest,
        model_name: &str,
        cfg: NelConfig,
        topology: &Topology,
    ) -> Result<PushDist> {
        Self::with_topology_and_fabric(
            manifest,
            model_name,
            cfg,
            topology,
            &FabricConfig::default(),
        )
    }

    /// [`PushDist::with_topology`] with explicit liveness configuration:
    /// `fabric_cfg.heartbeat_every` turns on the heartbeat monitor, which
    /// declares links dead after `fabric_cfg.dead_after` of silence and
    /// fails their pending futures promptly (DESIGN.md §Elastic fabric).
    pub fn with_topology_and_fabric(
        manifest: &Manifest,
        model_name: &str,
        cfg: NelConfig,
        topology: &Topology,
        fabric_cfg: &FabricConfig,
    ) -> Result<PushDist> {
        let model = Arc::new(manifest.model(model_name)?.clone());
        let fabric =
            Arc::new(fabric::NodeFabric::new(topology, &cfg, model.clone(), fabric_cfg)?);
        Ok(PushDist {
            fabric,
            model,
            manifest_dir: manifest.dir.clone(),
            svgd: manifest.svgd.clone(),
        })
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// Node count of the backing fabric (1 = the degenerate in-process
    /// case).
    pub fn nodes(&self) -> usize {
        self.fabric.nodes()
    }

    /// A shareable handle for serving-side readers: an Arc bump of the
    /// fabric (see the `Clone` note above). Snapshots taken through the
    /// handle — in-process zero-copy state clones, or `ParticleState`
    /// frames over a wire transport — observe exactly the particles the
    /// training side owns, and never block training beyond a brief
    /// per-particle state-mutex hold.
    pub fn serve_handle(&self) -> PushDist {
        self.clone()
    }

    /// Which node owns `pid`.
    pub fn node_of(&self, pid: Pid) -> Option<usize> {
        self.fabric.node_of(pid)
    }

    /// The node-0 in-process NEL. Single-node in-process PDs always have
    /// one (trace example, artifact benches); a wire-transport PD has no
    /// local NEL and this panics — route through the PD API instead.
    pub fn nel(&self) -> &Nel {
        self.fabric
            .nel()
            .expect("no in-process NEL: this PD runs behind a wire transport")
    }

    pub fn manifest_dir(&self) -> &std::path::Path {
        &self.manifest_dir
    }

    /// The SVGD kernel artifact for n particles of this model, if built.
    pub fn svgd_artifact(&self, n: usize) -> Option<std::path::PathBuf> {
        let d = self.model.param_count;
        self.svgd
            .iter()
            .find(|s| s.n == n && s.d == d)
            .map(|s| s.file.clone())
    }

    /// Create one particle (paper: `p_create`). Closure handlers stay
    /// in-process; on a wire transport use [`PushDist::p_create_spec`].
    pub fn p_create(&self, opts: CreateOpts) -> Result<Pid> {
        self.fabric.create_local(opts)
    }

    /// Create `n` particles round-robin across nodes/devices with shared
    /// handlers.
    pub fn p_create_n(
        &self,
        n: usize,
        mk_opts: impl Fn(usize) -> CreateOpts,
    ) -> Result<Vec<Pid>> {
        (0..n).map(|i| self.p_create(mk_opts(i))).collect()
    }

    /// Create one particle from a serializable spec: handlers resolve
    /// node-locally from a registered program (`pd::programs`), so this
    /// works on every transport.
    pub fn p_create_spec(&self, opts: SpecOpts) -> Result<Pid> {
        self.fabric.create_spec(opts)
    }

    /// Spec-based twin of [`PushDist::p_create_n`].
    pub fn p_create_spec_n(
        &self,
        n: usize,
        mk_opts: impl Fn(usize) -> SpecOpts,
    ) -> Result<Vec<Pid>> {
        (0..n).map(|i| self.p_create_spec(mk_opts(i))).collect()
    }

    /// Asynchronously trigger `msg` on `pid` (paper: `p_launch`).
    pub fn p_launch(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.fabric.send(pid, msg, args)
    }

    /// Batched `p_launch` of one message to many particles: the fabric
    /// issues ONE transport broadcast per destination node (one frame on
    /// a wire link — the node-level `charge_transfer_batch`), and each
    /// node's NEL runs its usual batched fan-out. The returned futures
    /// are in `pids` order; aggregate with `PFuture::join_all` — error
    /// ordering is by input position, transports included.
    pub fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        self.fabric.broadcast(pids, msg, args)
    }

    /// Wait on futures (paper: `p_wait`).
    pub fn p_wait(&self, futs: &[PFuture]) -> Result<Vec<Value>, PushError> {
        PFuture::wait_all(futs)
    }

    pub fn particles(&self) -> Vec<Pid> {
        self.fabric.particle_ids()
    }

    // ---- direct (handler-less) particle operations, used by inference
    //      drivers and baselines ----

    pub fn step(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.fabric.direct(DirectOp::Step { pid, x, y, lr })
    }

    pub fn adam_step(&self, pid: Pid, x: Tensor, y: Tensor, lr: f32) -> PFuture {
        self.fabric.direct(DirectOp::AdamStep { pid, x, y, lr })
    }

    pub fn forward(&self, pid: Pid, x: Tensor) -> PFuture {
        self.fabric.direct(DirectOp::Forward { pid, x })
    }

    pub fn grad(&self, pid: Pid, x: Tensor, y: Tensor) -> PFuture {
        self.fabric.direct(DirectOp::Grad { pid, x, y })
    }

    pub fn get(&self, pid: Pid) -> PFuture {
        self.fabric.direct(DirectOp::Get { pid })
    }

    pub fn set(&self, pid: Pid, t: Tensor) -> PFuture {
        self.fabric.direct(DirectOp::Set { pid, t })
    }

    /// Posterior-mean prediction `f̂(x) = (1/n) Σ_i nn_θi(x)` (paper §3.4).
    /// Forward passes run concurrently across devices (and nodes).
    pub fn mean_forward(&self, pids: &[Pid], x: &Tensor) -> Result<Tensor> {
        if pids.is_empty() {
            return Err(anyhow!("mean_forward over zero particles"));
        }
        let futs: Vec<PFuture> = pids.iter().map(|p| self.forward(*p, x.clone())).collect();
        let mut acc: Option<Tensor> = None;
        // Futures are consumed by value: each prediction ends up uniquely
        // owned when its future drops, so the axpy accumulation below runs
        // in place (no COW copies).
        for f in futs {
            let pred = f.wait().map_err(|e| anyhow!("{e}"))?.tensor().map_err(|e| anyhow!("{e}"))?;
            match &mut acc {
                None => acc = Some(pred),
                Some(a) => crate::runtime::tensor::ops::axpy(a, 1.0, &pred),
            }
        }
        let mut a = acc.unwrap();
        let n = pids.len() as f32;
        for v in a.as_f32_mut() {
            *v /= n;
        }
        Ok(a)
    }

    /// Snapshot every particle's parameters (barrier + cache flush on
    /// every node). On the in-process path the returned tensors are
    /// zero-copy COW snapshots of the host store; over a wire transport
    /// they are owned decodes of the nodes' snapshots.
    pub fn drain_params(&self) -> Result<BTreeMap<Pid, Tensor>, PushError> {
        self.fabric.drain_params()
    }

    /// Clone one particle's local state (Adam moments, SWAG moments,
    /// SGMCMC chain state, ...). Zero-copy for tensor values in-process.
    /// None for unknown pids — and, for API compatibility, on transport
    /// failure; checkpoint capture uses the checked variant.
    pub fn particle_state(&self, pid: Pid) -> Option<Vec<(String, Value)>> {
        self.fabric.particle_state(pid).ok().flatten()
    }

    /// [`PushDist::particle_state`] with transport errors surfaced
    /// (checkpointing must fail loudly rather than silently drop a
    /// node's chain state).
    pub fn particle_state_checked(
        &self,
        pid: Pid,
    ) -> Result<Option<Vec<(String, Value)>>, PushError> {
        self.fabric.particle_state(pid)
    }

    /// Batched state snapshot of many particles for the serving tier:
    /// exactly ONE `SnapshotNode` frame per destination node (vs one
    /// `ParticleState` round-trip per pid), all frames in flight before
    /// the first wait, and one SHARED `deadline` budget across nodes. A
    /// dead or slow node fails only its own pids' positions — per-pid
    /// results let the caller serve what survived and record what is
    /// missing. See DESIGN.md §Serving under failure.
    pub fn snapshot_chains(
        &self,
        pids: &[Pid],
        deadline: Option<std::time::Duration>,
    ) -> Vec<ChainStateResult> {
        self.fabric.snapshot_chains(pids, deadline)
    }

    /// Merge state entries back into a particle (checkpoint restore).
    pub fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        self.fabric.restore_particle_state(pid, entries)
    }

    /// Fabric-wide statistics: per-node `NelStats` summed exactly once
    /// (see [`NelStats::merged`]); device breakdowns concatenate in node
    /// order. The single-node result is identical to the old direct NEL
    /// read. A transport failure (dead node link) cannot be signalled
    /// through this infallible signature, so it is reported on stderr and
    /// zeros are returned — callers that must distinguish "no traffic"
    /// from "node unreachable" use [`PushDist::stats_checked`].
    pub fn stats(&self) -> NelStats {
        match self.fabric.stats() {
            Ok(s) => s,
            Err(e) => {
                crate::log_warn!("fabric stats unavailable ({e}); reporting zeros");
                NelStats::default()
            }
        }
    }

    /// [`PushDist::stats`] with transport errors surfaced.
    pub fn stats_checked(&self) -> Result<NelStats, PushError> {
        self.fabric.stats()
    }

    /// Per-node stats, in node order (the un-merged inputs of
    /// [`PushDist::stats`]).
    pub fn node_stats(&self) -> Result<Vec<NelStats>, PushError> {
        self.fabric.node_stats()
    }

    /// Per-node transport frame/byte counters (all zero in-process).
    pub fn transport_counters(&self) -> Vec<TransportCounters> {
        self.fabric.transport_counters()
    }

    /// Per-link liveness, in node order (in-process links are always
    /// `Healthy`). See DESIGN.md §Elastic fabric.
    pub fn link_health(&self) -> Vec<LinkHealth> {
        self.fabric.link_health()
    }

    /// Nodes whose links are dead (their particles need migration).
    pub fn dead_nodes(&self) -> Vec<usize> {
        self.fabric.dead_nodes()
    }

    /// Peer socket address of a wire link (None in-process).
    pub fn peer_addr(&self, node: usize) -> Option<std::net::SocketAddr> {
        self.fabric.peer_addr(node)
    }

    /// Recover from dead node(s): migrate their particles onto survivors
    /// (re-created from `ckpt` under their ORIGINAL global pids, so every
    /// deterministic stream continues unperturbed), then rewind the
    /// SURVIVING particles to the same checkpoint — after which the whole
    /// ensemble sits at one consistent round and the caller replays from
    /// there. Errors if no link is actually dead: recovery is a response
    /// to detected node death, not a general rollback.
    pub fn recover(&self, ckpt: &checkpoint::Checkpoint) -> Result<()> {
        if self.model.name != ckpt.model {
            return Err(anyhow!(
                "checkpoint is for model {:?}, PD wraps {:?}",
                ckpt.model,
                self.model.name
            ));
        }
        let dead = self.fabric.dead_nodes();
        if dead.is_empty() {
            return Err(anyhow!("recover called but every node link is alive"));
        }
        let moved: std::collections::BTreeSet<Pid> =
            self.fabric.migrate(&dead, &ckpt.params, &ckpt.state)?.into_iter().collect();
        // Migrated particles were re-created directly from the checkpoint;
        // only the survivors still hold post-checkpoint params/state and
        // need the explicit rewind.
        let futs: Vec<PFuture> = ckpt
            .params
            .iter()
            .filter(|(pid, _)| !moved.contains(pid))
            .map(|(pid, t)| self.set(*pid, t.clone()))
            .collect();
        PFuture::wait_all(&futs).map_err(|e| anyhow!("{e}"))?;
        for (pid, entries) in &ckpt.state {
            if !moved.contains(pid) {
                self.restore_particle_state(*pid, entries.clone())
                    .map_err(|e| anyhow!("{e}"))?;
            }
        }
        Ok(())
    }
}
