//! Readiness-driven socket multiplexing for the wire transport
//! (DESIGN.md §Event-driven transport).
//!
//! The threaded transport parks one reader thread per client link and a
//! reader/writer thread pair per server connection — fine for a handful
//! of training nodes, fatal for a serving tier holding thousands of
//! client connections. This module multiplexes EVERY evented connection
//! (client and server side) onto a small fixed pool of poll threads:
//!
//! * a [`Reactor`] owns `REACTOR_THREADS` shards, each one poll thread
//!   with its own interest set; connections are round-robined across
//!   shards at registration;
//! * each shard sleeps in `poll(2)` on its fds plus a self-wake socket
//!   pair, reads readable connections to `WouldBlock`, reassembles
//!   length-prefixed frames incrementally, and hands each complete frame
//!   to the connection's [`Sink`];
//! * server-side writes go through a per-connection outbox
//!   ([`WriteHandle::send_frame`] queues whole frames; the OWNING shard
//!   flushes them to `WouldBlock` under `POLLOUT` interest) — a shard
//!   never parks waiting for a peer to drain, so two connections that
//!   happen to share a shard (the loopback `push serve` shape, where
//!   client and server halves ride one global reactor) can never
//!   deadlock it;
//! * client-side senders write on their own threads under the link's
//!   existing write mutex ([`write_frame_nb`] parks in `poll(POLLOUT)`
//!   when the socket buffer is full, bounded by [`WRITE_STALL_LIMIT`]),
//!   so the per-sender FIFO order of the threaded transport is preserved
//!   verbatim.
//!
//! No `libc` crate: the one foreign call is a `poll(2)` FFI shim behind
//! the [`sys`] module, everything else is `std` (`set_nonblocking` +
//! `AsRawFd`). The completion side reuses `PFuture::on_ready`
//! continuations unchanged — readiness is the only new concept.
//!
//! The no-deadlock/no-starvation argument has two legs. (1) Shard
//! threads NEVER block: reads stop at `WouldBlock`, outbox flushes stop
//! at `WouldBlock` (resuming on `POLLOUT` readiness), and a peer that
//! stops draining for [`WRITE_STALL_LIMIT`] is severed, mirroring the
//! threaded writer thread's failure path. (2) [`Sink::on_frame`] must
//! not run long synchronous work on the shard — heavy operations
//! (building a NEL, batched snapshot/migrate dispatch, NEL teardown)
//! belong on the fixed [`offload`] pool, whose workers may block freely
//! because NELs and senders make progress on their own threads. Frame
//! demux itself never waits on another connection.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::pd::wire::MAX_FRAME;

/// Fixed poll-thread pool size. The `connections_256_evented` bench gate
/// pins "256 idle links on <8 transport threads"; 4 shards (plus the
/// [`EXEC_THREADS`] offload workers) leave headroom while still
/// spreading busy connections across cores.
pub const REACTOR_THREADS: usize = 4;

/// Longest a socket write may sit in `poll(POLLOUT)` without moving ONE
/// byte before the write fails with `TimedOut`. This is a stall bound,
/// not a throughput bound: any progress resets it. Severing beats
/// waiting — a peer that stopped draining is indistinguishable from a
/// dead one, and the link-severing error paths fail pending futures
/// promptly instead of parking a sender (or, worse, a flush) forever.
pub const WRITE_STALL_LIMIT: Duration = Duration::from_secs(15);

// ---- transport thread census ----------------------------------------------

static LIVE_THREADS: AtomicUsize = AtomicUsize::new(0);
static FIXED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// RAII census of live transport-owned threads (reader loops, server
/// read/write threads, loopback accept threads, reactor shards, offload
/// workers). The `connections_256_{threaded,evented}` bench pair asserts
/// the thread-count win through this counter, so every transport thread
/// body holds a gauge.
pub struct ThreadGauge(());

impl ThreadGauge {
    pub fn enter() -> ThreadGauge {
        LIVE_THREADS.fetch_add(1, Ordering::AcqRel);
        ThreadGauge(())
    }
}

impl Drop for ThreadGauge {
    fn drop(&mut self) {
        LIVE_THREADS.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Number of transport-owned threads alive right now.
pub fn live_transport_threads() -> usize {
    LIVE_THREADS.load(Ordering::Acquire)
}

/// Threads belonging to the transport's FIXED pools (reactor shards plus
/// offload workers) spawned so far. Unlike [`live_transport_threads`]
/// this never shrinks — it is the settled baseline the per-link
/// thread-scaling claim is measured against: evented transports add
/// ZERO threads per link on top of this number.
pub fn resident_transport_threads() -> usize {
    FIXED_THREADS.load(Ordering::Acquire)
}

// ---- poll(2) shim ----------------------------------------------------------

/// The one foreign call. `PollFd` and the event bits have identical
/// layout/values on Linux and the BSDs (macOS included), so no `libc`
/// crate is needed — just the prototype.
mod sys {
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    #[cfg(target_os = "linux")]
    type Nfds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type Nfds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
    }

    /// `poll(2)` with EINTR retried. Returns the number of ready fds
    /// (0 on timeout).
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

// ---- nonblocking writes ----------------------------------------------------

/// [`write_all_nb_within`] with the default [`WRITE_STALL_LIMIT`].
pub fn write_all_nb(stream: &TcpStream, buf: &[u8]) -> std::io::Result<()> {
    write_all_nb_within(stream, buf, WRITE_STALL_LIMIT)
}

/// Write all of `buf` on a nonblocking socket, parking in `poll(POLLOUT)`
/// whenever the kernel buffer is full. Blocking-write semantics on a
/// nonblocking fd — callers keep the threaded transport's behavior (and
/// its per-sender FIFO, since they already serialize under a write
/// mutex) — EXCEPT that a peer which stops draining for `stall_limit`
/// fails the write with `TimedOut` instead of stalling the caller
/// silently forever. Any forward progress resets the stall clock; on
/// error the stream is no longer frame-aligned and the caller must
/// sever the link.
pub fn write_all_nb_within(
    stream: &TcpStream,
    mut buf: &[u8],
    stall_limit: Duration,
) -> std::io::Result<()> {
    let mut s = stream;
    let mut stall_deadline = Instant::now() + stall_limit;
    while !buf.is_empty() {
        match s.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket write returned zero",
                ))
            }
            Ok(n) => {
                buf = &buf[n..];
                stall_deadline = Instant::now() + stall_limit;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                let now = Instant::now();
                if now >= stall_deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!(
                            "socket write stalled for {stall_limit:?} with no progress \
                             (peer not draining)"
                        ),
                    ));
                }
                let wait = stall_deadline
                    .saturating_duration_since(now)
                    .min(Duration::from_millis(1_000));
                let mut fds = [sys::PollFd {
                    fd: stream.as_raw_fd(),
                    events: sys::POLLOUT,
                    revents: 0,
                }];
                // POLLERR/POLLHUP surface as a hard error on the next write
                sys::poll_fds(&mut fds, (wait.as_millis() as i32).max(1))?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// One length-prefixed frame ([`crate::pd::wire::write_frame`]'s layout)
/// on a nonblocking socket: `len: u32 le | payload`.
pub fn write_frame_nb(stream: &TcpStream, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    write_all_nb(stream, &(payload.len() as u32).to_le_bytes())?;
    write_all_nb(stream, payload)
}

// ---- connection sinks ------------------------------------------------------

/// What a sink tells the reactor after handling a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameVerdict {
    Continue,
    /// Close the connection; [`Sink::on_close`] fires next.
    Close,
}

/// The read-side owner of one evented connection. `on_frame` receives
/// every complete frame (length prefix stripped) in arrival order;
/// `on_close` fires exactly once when the connection dies (EOF, socket
/// error, oversized frame header, a write stall, or an `on_frame`
/// verdict of `Close`). Both callbacks run ON THE SHARD THREAD and must
/// not block or run long synchronous work — push anything heavy onto
/// [`offload`].
pub trait Sink: Send {
    fn on_frame(&mut self, frame: Vec<u8>) -> FrameVerdict;
    fn on_close(&mut self);
}

// ---- outbox ----------------------------------------------------------------

struct OutState {
    /// Bytes queued for the shard to flush (whole frames, header
    /// included). Appended by [`WriteHandle::send_frame`] from any
    /// thread; drained only by the owning shard.
    buf: VecDeque<u8>,
    /// Flush everything queued, then close the connection (graceful
    /// server shutdown: the response to a `Shutdown` request must still
    /// reach the peer before the fd drops).
    closing: bool,
    /// The connection is gone (socket error, stall, or removal): sends
    /// fail and the shard closes the conn on its next pass.
    dead: bool,
    /// Last instant the kernel accepted outbox bytes (or the outbox went
    /// from empty to non-empty). A non-empty outbox with no progress for
    /// [`WRITE_STALL_LIMIT`] marks the connection dead.
    last_progress: Instant,
}

struct Outbox {
    state: Mutex<OutState>,
}

impl Outbox {
    fn fresh() -> Outbox {
        Outbox {
            state: Mutex::new(OutState {
                buf: VecDeque::new(),
                closing: false,
                dead: false,
                last_progress: Instant::now(),
            }),
        }
    }
}

/// The write half of an evented connection: queues whole frames for the
/// owning reactor shard to flush under `POLLOUT` readiness. Cloneable
/// and callable from any thread; NEVER blocks — which is exactly why
/// the evented server responds through it instead of writing inline
/// (an inline write parked in `poll(POLLOUT)` on a shard thread could
/// deadlock the shard against a same-shard peer).
#[derive(Clone)]
pub struct WriteHandle {
    out: Arc<Outbox>,
    shard: &'static Shard,
}

impl WriteHandle {
    /// Queue one length-prefixed frame. Returns an error once the
    /// connection is dead — queued-but-unflushed frames on a dying
    /// connection are dropped, exactly like the threaded writer thread's
    /// undelivered queue.
    pub fn send_frame(&self, payload: &[u8]) -> std::io::Result<()> {
        if payload.len() > MAX_FRAME {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
            ));
        }
        {
            let mut s = self.out.state.lock().unwrap();
            if s.dead {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "evented connection closed",
                ));
            }
            if s.buf.is_empty() {
                s.last_progress = Instant::now();
            }
            s.buf.extend((payload.len() as u32).to_le_bytes());
            s.buf.extend(payload);
        }
        self.shard.wake();
        Ok(())
    }

    /// Flush everything queued so far, then close the connection.
    pub fn close_after_flush(&self) {
        self.out.state.lock().unwrap().closing = true;
        self.shard.wake();
    }
}

// ---- reactor ---------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    /// Partial-read accumulator; complete frames are drained off the front.
    buf: VecDeque<u8>,
    sink: Box<dyn Sink>,
    out: Arc<Outbox>,
}

impl Conn {
    fn wants_flush(&self) -> bool {
        let s = self.out.state.lock().unwrap();
        !s.buf.is_empty() || s.closing || s.dead
    }
}

struct Lis {
    listener: TcpListener,
    on_accept: Box<dyn FnMut(TcpStream) + Send>,
}

enum Cmd {
    Conn(Conn),
    Lis(Lis),
}

struct Shard {
    inbox: Mutex<Vec<Cmd>>,
    /// Write end of the shard's self-wake socket pair; one byte unparks
    /// the poll thread so a fresh registration or outbox append is
    /// picked up immediately.
    waker: Mutex<TcpStream>,
}

impl Shard {
    fn push(&self, cmd: Cmd) {
        self.inbox.lock().unwrap().push(cmd);
        self.wake();
    }

    fn wake(&self) {
        // WouldBlock means wake bytes are already queued — the poll thread
        // is guaranteed to wake and rescan either way.
        let _ = self.waker.lock().unwrap().write(&[1u8]);
    }
}

/// The process-wide event loop: a fixed pool of poll threads multiplexing
/// every evented connection and listener. Lives for the life of the
/// process (transport links come and go; the pool does not).
pub struct Reactor {
    shards: Vec<&'static Shard>,
    next: AtomicUsize,
}

impl Reactor {
    /// The global reactor, spawned on first use.
    pub fn global() -> &'static Reactor {
        static GLOBAL: OnceLock<Reactor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let mut shards = Vec::with_capacity(REACTOR_THREADS);
            for i in 0..REACTOR_THREADS {
                let (wake_tx, wake_rx) =
                    wake_pair().expect("reactor: loopback wake pair");
                let shard: &'static Shard = Box::leak(Box::new(Shard {
                    inbox: Mutex::new(Vec::new()),
                    waker: Mutex::new(wake_tx),
                }));
                FIXED_THREADS.fetch_add(1, Ordering::AcqRel);
                std::thread::Builder::new()
                    .name(format!("push-poll-{i}"))
                    .spawn(move || shard_loop(shard, wake_rx))
                    .expect("reactor: spawn poll thread");
                shards.push(shard);
            }
            Reactor { shards, next: AtomicUsize::new(0) }
        })
    }

    /// Hand `stream` to the reactor: it becomes nonblocking, joins a
    /// shard's interest set, and `sink` receives its frames. For a
    /// read-mostly connection whose writes happen on caller threads
    /// (the evented CLIENT shape — senders keep their own cloned handle
    /// and [`write_frame_nb`]).
    pub fn register(&self, stream: TcpStream, sink: Box<dyn Sink>) -> std::io::Result<()> {
        self.register_duplex(stream, move |_handle| sink).map(|_| ())
    }

    /// Full-duplex registration: like [`Reactor::register`], but the
    /// sink is built FROM the connection's [`WriteHandle`], so responses
    /// can be queued on the outbox the owning shard flushes (the evented
    /// SERVER shape). The handle is also returned for callers that keep
    /// one outside the sink.
    pub fn register_duplex<F>(
        &self,
        stream: TcpStream,
        mk_sink: F,
    ) -> std::io::Result<WriteHandle>
    where
        F: FnOnce(WriteHandle) -> Box<dyn Sink>,
    {
        stream.set_nonblocking(true)?;
        let shard = self.shard();
        let out = Arc::new(Outbox::fresh());
        let handle = WriteHandle { out: out.clone(), shard };
        let sink = mk_sink(handle.clone());
        shard.push(Cmd::Conn(Conn { stream, buf: VecDeque::new(), sink, out }));
        Ok(handle)
    }

    /// Register an accept loop: `on_accept` runs on the shard thread for
    /// every accepted connection (typically to `register` it right back).
    /// The listener stays in the interest set for the life of the process.
    pub fn register_listener(
        &self,
        listener: TcpListener,
        on_accept: Box<dyn FnMut(TcpStream) + Send>,
    ) -> std::io::Result<()> {
        listener.set_nonblocking(true)?;
        self.shard().push(Cmd::Lis(Lis { listener, on_accept }));
        Ok(())
    }

    /// Poll threads in the pool (the bench's thread-count claim).
    pub fn threads(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self) -> &'static Shard {
        self.shards[self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len()]
    }
}

/// A self-wake channel from plain std: a loopback TCP pair (no `pipe(2)`,
/// which would need more FFI). Returns (write end, read end).
fn wake_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = l.local_addr()?;
    let tx = TcpStream::connect(addr)?;
    let me = tx.local_addr()?;
    // Accept until we see OUR OWN connect: the bind->accept window is
    // open to any local process, and installing a stranger as the
    // shard's waker read end would leave the real write end unpaired —
    // registrations would only be noticed on the 1 s poll tick.
    // Strangers are dropped (their connection resets on close).
    let rx = loop {
        let (s, peer) = l.accept()?;
        if peer == me {
            break s;
        }
    };
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    tx.set_nodelay(true).ok();
    Ok((tx, rx))
}

fn shard_loop(shard: &'static Shard, wake_rx: TcpStream) {
    let _gauge = ThreadGauge::enter();
    let mut conns: Vec<Conn> = Vec::new();
    let mut listeners: Vec<Lis> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let ready = sys::POLLIN | sys::POLLERR | sys::POLLHUP;
    loop {
        for cmd in shard.inbox.lock().unwrap().drain(..) {
            match cmd {
                Cmd::Conn(c) => conns.push(c),
                Cmd::Lis(l) => listeners.push(l),
            }
        }

        let mut fds = Vec::with_capacity(1 + listeners.len() + conns.len());
        fds.push(sys::PollFd { fd: wake_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        for l in &listeners {
            fds.push(sys::PollFd {
                fd: l.listener.as_raw_fd(),
                events: sys::POLLIN,
                revents: 0,
            });
        }
        for c in &conns {
            // POLLOUT interest only while the outbox has pending bytes:
            // an idle connection costs a POLLIN slot, nothing more.
            let mut events = sys::POLLIN;
            if c.wants_flush() {
                events |= sys::POLLOUT;
            }
            fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events, revents: 0 });
        }
        // 1 s tick even with nothing ready, so a poll error can't spin,
        // write stalls are detected on quiet shards, and a missed wake
        // byte (can't happen, but cheap insurance) heals.
        if sys::poll_fds(&mut fds, 1_000).is_err() {
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }

        if fds[0].revents != 0 {
            drain_wake(&wake_rx, &mut scratch);
        }

        for (i, l) in listeners.iter_mut().enumerate() {
            if fds[1 + i].revents & ready == 0 {
                continue;
            }
            loop {
                match l.listener.accept() {
                    Ok((stream, _peer)) => (l.on_accept)(stream),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    // Transient accept errors (ECONNABORTED etc.): the
                    // listener itself is fine, retry on the next tick.
                    Err(_) => break,
                }
            }
        }

        let base = 1 + listeners.len();
        let mut dead = Vec::new();
        for (i, c) in conns.iter_mut().enumerate() {
            let mut verdict = FrameVerdict::Continue;
            if fds[base + i].revents & ready != 0 {
                verdict = service_conn(c, &mut scratch);
            }
            // Flush every pass, not just on POLLOUT revents: a wake byte
            // (fresh outbox append) lands here with this fd's revents 0.
            if verdict == FrameVerdict::Continue {
                verdict = flush_conn(c);
            }
            if verdict == FrameVerdict::Close {
                dead.push(i);
            }
        }
        // Highest index first: swap_remove never disturbs a smaller index.
        for i in dead.into_iter().rev() {
            let mut c = conns.swap_remove(i);
            c.out.state.lock().unwrap().dead = true;
            c.sink.on_close();
        }
    }
}

fn drain_wake(wake_rx: &TcpStream, scratch: &mut [u8]) {
    let mut rx = wake_rx;
    loop {
        match rx.read(scratch) {
            Ok(0) => return, // waker gone: process teardown
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return, // WouldBlock: drained
        }
    }
}

/// Read a readable connection to `WouldBlock`, dispatching every complete
/// frame in order. The frame layout is exactly `wire::read_frame`'s —
/// `len: u32 le | payload` with the same `MAX_FRAME` bound.
fn service_conn(c: &mut Conn, scratch: &mut [u8]) -> FrameVerdict {
    loop {
        match (&c.stream).read(scratch) {
            Ok(0) => return FrameVerdict::Close, // EOF
            Ok(n) => {
                c.buf.extend(&scratch[..n]);
                loop {
                    if c.buf.len() < 4 {
                        break;
                    }
                    let header: Vec<u8> = c.buf.iter().take(4).copied().collect();
                    let len =
                        u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
                    if len > MAX_FRAME {
                        return FrameVerdict::Close; // framing is unrecoverable
                    }
                    if c.buf.len() < 4 + len {
                        break; // frame still in flight
                    }
                    c.buf.drain(..4);
                    let frame: Vec<u8> = c.buf.drain(..len).collect();
                    if c.sink.on_frame(frame) == FrameVerdict::Close {
                        return FrameVerdict::Close;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return FrameVerdict::Continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return FrameVerdict::Close,
        }
    }
}

/// Drain a connection's outbox to `WouldBlock`. NEVER parks: `POLLOUT`
/// interest (held while the outbox is non-empty) resumes the flush when
/// the kernel buffer frees up, and a peer that accepts nothing for
/// [`WRITE_STALL_LIMIT`] gets the connection severed — the same verdict
/// the threaded writer thread's failure path reaches, minus the parked
/// thread.
fn flush_conn(c: &mut Conn) -> FrameVerdict {
    let mut s = c.out.state.lock().unwrap();
    if s.dead {
        return FrameVerdict::Close;
    }
    while !s.buf.is_empty() {
        let wrote = {
            let (front, _) = s.buf.as_slices();
            match (&c.stream).write(front) {
                Ok(0) => 0,
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => 0,
            }
        };
        if wrote == 0 {
            s.dead = true;
            return FrameVerdict::Close;
        }
        s.buf.drain(..wrote);
        s.last_progress = Instant::now();
    }
    if s.buf.is_empty() {
        if s.closing {
            s.dead = true;
            return FrameVerdict::Close;
        }
    } else if s.last_progress.elapsed() > WRITE_STALL_LIMIT {
        s.dead = true;
        return FrameVerdict::Close;
    }
    FrameVerdict::Continue
}

// ---- offload executor ------------------------------------------------------

/// Workers in the fixed [`offload`] pool. Together with
/// [`REACTOR_THREADS`] this is the whole resident cost of the evented
/// transport (4 + 2 = 6, under the bench's <8 gate) — per-connection
/// cost stays zero threads.
pub const EXEC_THREADS: usize = 2;

/// Run `job` on the transport's small fixed offload pool — the escape
/// hatch for work that must NOT occupy a reactor shard: NEL
/// construction, synchronous batched dispatch (snapshot/migrate), NEL
/// teardown. Offload workers may block freely (NELs and senders make
/// progress on their own threads). Jobs run in submission order per
/// worker; callers needing per-connection FIFO serialize their own
/// queue and keep at most one job in flight (see
/// `transport::drain_conn`).
pub fn offload(job: Box<dyn FnOnce() + Send + 'static>) {
    type Job = Box<dyn FnOnce() + Send + 'static>;
    static POOL: OnceLock<Mutex<mpsc::Sender<Job>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..EXEC_THREADS {
            let rx = rx.clone();
            FIXED_THREADS.fetch_add(1, Ordering::AcqRel);
            std::thread::Builder::new()
                .name(format!("push-exec-{i}"))
                .spawn(move || {
                    let _gauge = ThreadGauge::enter();
                    loop {
                        // The guard drops at the end of this statement,
                        // so workers run jobs concurrently — the lock
                        // covers only the dequeue.
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return,
                        };
                        job();
                    }
                })
                .expect("spawn offload worker");
        }
        Mutex::new(tx)
    });
    let _ = pool.lock().unwrap().send(job);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_all_nb_fails_timed_out_after_bounded_stall_against_mute_peer() {
        // A peer that stops draining must surface as an ERROR on the
        // writer within the stall bound, not park the caller forever
        // (on the client that is a sender thread; pre-fix it silently
        // re-polled with no bound at all).
        let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = l.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (_mute_rx, _) = l.accept().unwrap(); // held open, never read
        tx.set_nonblocking(true).unwrap();

        let chunk = vec![0u8; 1 << 20];
        let limit = Duration::from_millis(200);
        let t0 = Instant::now();
        let err = loop {
            match write_all_nb_within(&tx, &chunk, limit) {
                // kernel buffers still absorbing: keep filling
                Ok(()) => assert!(
                    t0.elapsed() < Duration::from_secs(30),
                    "socket buffers never filled"
                ),
                Err(e) => break e,
            }
        };
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "stall bound never engaged ({:?})",
            t0.elapsed()
        );
    }
}
