//! Readiness-driven socket multiplexing for the wire transport
//! (DESIGN.md §Event-driven transport).
//!
//! The threaded transport parks one reader thread per client link and a
//! reader/writer thread pair per server connection — fine for a handful
//! of training nodes, fatal for a serving tier holding thousands of
//! client connections. This module multiplexes EVERY evented connection
//! (client and server side) onto a small fixed pool of poll threads:
//!
//! * a [`Reactor`] owns `REACTOR_THREADS` shards, each one poll thread
//!   with its own interest set; connections are round-robined across
//!   shards at registration;
//! * each shard sleeps in `poll(2)` on its fds plus a self-wake socket
//!   pair, reads readable connections to `WouldBlock`, reassembles
//!   length-prefixed frames incrementally, and hands each complete frame
//!   to the connection's [`Sink`];
//! * writes never go through the reactor: senders write on their own
//!   thread under the link's existing write mutex ([`write_frame_nb`]
//!   parks in `poll(POLLOUT)` when the socket buffer is full), so the
//!   per-sender FIFO order of the threaded transport is preserved
//!   verbatim.
//!
//! No `libc` crate: the one foreign call is a `poll(2)` FFI shim behind
//! the [`sys`] module, everything else is `std` (`set_nonblocking` +
//! `AsRawFd`). The completion side reuses `PFuture::on_ready`
//! continuations unchanged — readiness is the only new concept.
//!
//! A [`Sink::on_frame`] may block its shard (the node server's
//! synchronous ops wait on NEL completion); that is a latency cost for
//! connections sharing the shard, never a deadlock, because NELs and
//! senders make progress on their own threads. Frame demux itself never
//! waits on another connection.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::pd::wire::MAX_FRAME;

/// Fixed poll-thread pool size. The `connections_256_evented` bench gate
/// pins "256 idle links on <8 transport threads"; 4 shards leave headroom
/// while still spreading busy connections across cores.
pub const REACTOR_THREADS: usize = 4;

// ---- transport thread census ----------------------------------------------

static LIVE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// RAII census of live transport-owned threads (reader loops, server
/// read/write threads, loopback accept threads, reactor shards). The
/// `connections_256_{threaded,evented}` bench pair asserts the thread-count
/// win through this counter, so every transport thread body holds a gauge.
pub struct ThreadGauge(());

impl ThreadGauge {
    pub fn enter() -> ThreadGauge {
        LIVE_THREADS.fetch_add(1, Ordering::AcqRel);
        ThreadGauge(())
    }
}

impl Drop for ThreadGauge {
    fn drop(&mut self) {
        LIVE_THREADS.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Number of transport-owned threads alive right now.
pub fn live_transport_threads() -> usize {
    LIVE_THREADS.load(Ordering::Acquire)
}

// ---- poll(2) shim ----------------------------------------------------------

/// The one foreign call. `PollFd` and the event bits have identical
/// layout/values on Linux and the BSDs (macOS included), so no `libc`
/// crate is needed — just the prototype.
mod sys {
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    #[cfg(target_os = "linux")]
    type Nfds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type Nfds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
    }

    /// `poll(2)` with EINTR retried. Returns the number of ready fds
    /// (0 on timeout).
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

// ---- nonblocking writes ----------------------------------------------------

/// Write all of `buf` on a nonblocking socket, parking in `poll(POLLOUT)`
/// whenever the kernel buffer is full. Blocking-write semantics on a
/// nonblocking fd — callers keep the threaded transport's behavior (and
/// its per-sender FIFO, since they already serialize under a write mutex).
pub fn write_all_nb(stream: &TcpStream, mut buf: &[u8]) -> std::io::Result<()> {
    let mut s = stream;
    while !buf.is_empty() {
        match s.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket write returned zero",
                ))
            }
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                let mut fds = [sys::PollFd {
                    fd: stream.as_raw_fd(),
                    events: sys::POLLOUT,
                    revents: 0,
                }];
                // POLLERR/POLLHUP surface as a hard error on the next write
                sys::poll_fds(&mut fds, 5_000)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// One length-prefixed frame ([`crate::pd::wire::write_frame`]'s layout)
/// on a nonblocking socket: `len: u32 le | payload`.
pub fn write_frame_nb(stream: &TcpStream, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    write_all_nb(stream, &(payload.len() as u32).to_le_bytes())?;
    write_all_nb(stream, payload)
}

// ---- connection sinks ------------------------------------------------------

/// What a sink tells the reactor after handling a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameVerdict {
    Continue,
    /// Close the connection; [`Sink::on_close`] fires next.
    Close,
}

/// The read-side owner of one evented connection. `on_frame` receives
/// every complete frame (length prefix stripped) in arrival order;
/// `on_close` fires exactly once when the connection dies (EOF, socket
/// error, oversized frame header, or an `on_frame` verdict of `Close`).
pub trait Sink: Send {
    fn on_frame(&mut self, frame: Vec<u8>) -> FrameVerdict;
    fn on_close(&mut self);
}

// ---- reactor ---------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    /// Partial-read accumulator; complete frames are drained off the front.
    buf: VecDeque<u8>,
    sink: Box<dyn Sink>,
}

struct Lis {
    listener: TcpListener,
    on_accept: Box<dyn FnMut(TcpStream) + Send>,
}

enum Cmd {
    Conn(Conn),
    Lis(Lis),
}

struct Shard {
    inbox: Mutex<Vec<Cmd>>,
    /// Write end of the shard's self-wake socket pair; one byte unparks
    /// the poll thread so a fresh registration is picked up immediately.
    waker: Mutex<TcpStream>,
}

impl Shard {
    fn push(&self, cmd: Cmd) {
        self.inbox.lock().unwrap().push(cmd);
        // WouldBlock means wake bytes are already queued — the poll thread
        // is guaranteed to wake and drain the inbox either way.
        let _ = self.waker.lock().unwrap().write(&[1u8]);
    }
}

/// The process-wide event loop: a fixed pool of poll threads multiplexing
/// every evented connection and listener. Lives for the life of the
/// process (transport links come and go; the pool does not).
pub struct Reactor {
    shards: Vec<&'static Shard>,
    next: AtomicUsize,
}

impl Reactor {
    /// The global reactor, spawned on first use.
    pub fn global() -> &'static Reactor {
        static GLOBAL: OnceLock<Reactor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let mut shards = Vec::with_capacity(REACTOR_THREADS);
            for i in 0..REACTOR_THREADS {
                let (wake_tx, wake_rx) =
                    wake_pair().expect("reactor: loopback wake pair");
                let shard: &'static Shard = Box::leak(Box::new(Shard {
                    inbox: Mutex::new(Vec::new()),
                    waker: Mutex::new(wake_tx),
                }));
                std::thread::Builder::new()
                    .name(format!("push-poll-{i}"))
                    .spawn(move || shard_loop(shard, wake_rx))
                    .expect("reactor: spawn poll thread");
                shards.push(shard);
            }
            Reactor { shards, next: AtomicUsize::new(0) }
        })
    }

    /// Hand `stream` to the reactor: it becomes nonblocking, joins a
    /// shard's interest set, and `sink` receives its frames. Writers keep
    /// using their own (cloned) handle with [`write_frame_nb`].
    pub fn register(&self, stream: TcpStream, sink: Box<dyn Sink>) -> std::io::Result<()> {
        stream.set_nonblocking(true)?;
        self.shard().push(Cmd::Conn(Conn { stream, buf: VecDeque::new(), sink }));
        Ok(())
    }

    /// Register an accept loop: `on_accept` runs on the shard thread for
    /// every accepted connection (typically to `register` it right back).
    /// The listener stays in the interest set for the life of the process.
    pub fn register_listener(
        &self,
        listener: TcpListener,
        on_accept: Box<dyn FnMut(TcpStream) + Send>,
    ) -> std::io::Result<()> {
        listener.set_nonblocking(true)?;
        self.shard().push(Cmd::Lis(Lis { listener, on_accept }));
        Ok(())
    }

    /// Poll threads in the pool (the bench's thread-count claim).
    pub fn threads(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self) -> &'static Shard {
        self.shards[self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len()]
    }
}

/// A self-wake channel from plain std: a loopback TCP pair (no `pipe(2)`,
/// which would need more FFI). Returns (write end, read end).
fn wake_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let l = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = l.local_addr()?;
    let tx = TcpStream::connect(addr)?;
    let (rx, _) = l.accept()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    tx.set_nodelay(true).ok();
    Ok((tx, rx))
}

fn shard_loop(shard: &'static Shard, wake_rx: TcpStream) {
    let _gauge = ThreadGauge::enter();
    let mut conns: Vec<Conn> = Vec::new();
    let mut listeners: Vec<Lis> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let ready = sys::POLLIN | sys::POLLERR | sys::POLLHUP;
    loop {
        for cmd in shard.inbox.lock().unwrap().drain(..) {
            match cmd {
                Cmd::Conn(c) => conns.push(c),
                Cmd::Lis(l) => listeners.push(l),
            }
        }

        let mut fds = Vec::with_capacity(1 + listeners.len() + conns.len());
        fds.push(sys::PollFd { fd: wake_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        for l in &listeners {
            fds.push(sys::PollFd {
                fd: l.listener.as_raw_fd(),
                events: sys::POLLIN,
                revents: 0,
            });
        }
        for c in &conns {
            fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        }
        // 1 s tick even with nothing ready, so a poll error can't spin and
        // a missed wake byte (can't happen, but cheap insurance) heals.
        if sys::poll_fds(&mut fds, 1_000).is_err() {
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }

        if fds[0].revents != 0 {
            drain_wake(&wake_rx, &mut scratch);
        }

        for (i, l) in listeners.iter_mut().enumerate() {
            if fds[1 + i].revents & ready == 0 {
                continue;
            }
            loop {
                match l.listener.accept() {
                    Ok((stream, _peer)) => (l.on_accept)(stream),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    // Transient accept errors (ECONNABORTED etc.): the
                    // listener itself is fine, retry on the next tick.
                    Err(_) => break,
                }
            }
        }

        let base = 1 + listeners.len();
        let mut dead = Vec::new();
        for (i, c) in conns.iter_mut().enumerate() {
            if fds[base + i].revents & ready == 0 {
                continue;
            }
            if service_conn(c, &mut scratch) == FrameVerdict::Close {
                dead.push(i);
            }
        }
        // Highest index first: swap_remove never disturbs a smaller index.
        for i in dead.into_iter().rev() {
            let mut c = conns.swap_remove(i);
            c.sink.on_close();
        }
    }
}

fn drain_wake(wake_rx: &TcpStream, scratch: &mut [u8]) {
    let mut rx = wake_rx;
    loop {
        match rx.read(scratch) {
            Ok(0) => return, // waker gone: process teardown
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return, // WouldBlock: drained
        }
    }
}

/// Read a readable connection to `WouldBlock`, dispatching every complete
/// frame in order. The frame layout is exactly `wire::read_frame`'s —
/// `len: u32 le | payload` with the same `MAX_FRAME` bound.
fn service_conn(c: &mut Conn, scratch: &mut [u8]) -> FrameVerdict {
    loop {
        match (&c.stream).read(scratch) {
            Ok(0) => return FrameVerdict::Close, // EOF
            Ok(n) => {
                c.buf.extend(&scratch[..n]);
                loop {
                    if c.buf.len() < 4 {
                        break;
                    }
                    let header: Vec<u8> = c.buf.iter().take(4).copied().collect();
                    let len =
                        u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
                    if len > MAX_FRAME {
                        return FrameVerdict::Close; // framing is unrecoverable
                    }
                    if c.buf.len() < 4 + len {
                        break; // frame still in flight
                    }
                    c.buf.drain(..4);
                    let frame: Vec<u8> = c.buf.drain(..len).collect();
                    if c.sink.on_frame(frame) == FrameVerdict::Close {
                        return FrameVerdict::Close;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return FrameVerdict::Continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return FrameVerdict::Close,
        }
    }
}
