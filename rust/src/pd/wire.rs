//! The PD seam's wire representation, shared by checkpointing and the
//! node transports (DESIGN.md §Distributed NEL).
//!
//! Three layers, all hand-rolled (no serde in the vendored crate set) and
//! round-trip/property tested:
//!
//! * **Value codec** — the tagged recursive encoding of [`Value`]
//!   (tag u8: 0 Unit; 1 Bool; 2 F32; 3 Usize(u64); 4 Str; 5 Tensor
//!   (dtype u8, rank u32, dims u64, raw 4-byte elements); 6 List).
//!   Extracted from `pd::checkpoint` v2 byte-for-byte, so checkpoint
//!   files and transport frames speak the same dialect and the v1/v2
//!   compatibility tests pin both at once.
//! * **Frames** — length-prefixed (`len u32 | payload`), bounded by
//!   [`MAX_FRAME`]; a truncated or oversized frame is a clean decode
//!   error, never a multi-GB allocation.
//! * **Messages** — versioned request/response payloads
//!   (`version u8 | kind u8 | req_id u64 | body`) covering every
//!   operation the PD API moves across the seam: particle creation from
//!   a serializable [`CreateSpec`], sends, batched broadcasts (ONE frame
//!   per destination node regardless of fan-out), the handler-less
//!   direct ops, parameter drains, particle-state capture/restore, and
//!   stats.
//!
//! Tensor payloads are decoded into freshly owned buffers (the wire is a
//! copy by nature); on the in-process path the transport never touches
//! this module — `Value`s move as zero-copy Arc clones through the
//! existing parameter plane.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::device::DeviceStats;
use crate::nel::{NelStats, SchedStats};
use crate::particle::{Pid, Value};
use crate::runtime::{DType, Tensor, TensorData};

/// Wire protocol version of the request/response framing. Bumped when the
/// message layout changes; the Value codec itself is versioned by the
/// checkpoint header (v1/v2) and must stay stable.
pub const WIRE_VERSION: u8 = 1;

/// Deepest `Value::List` nesting the codec accepts (defensive bound; real
/// state is depth <= 2: a list of tensors).
pub const MAX_DEPTH: usize = 32;

/// Max elements per decoded tensor (1 GiB of f32): a corrupt length field
/// must produce a clean error, not a multi-GB allocation or an overflowed
/// shape product.
pub const MAX_ELEMS: u64 = 1 << 28;

/// Max frame payload (2 GiB): bounds the single allocation a frame header
/// can demand before any of its content is validated.
pub const MAX_FRAME: usize = 1 << 31;

// ---- primitive readers/writers ------------------------------------------

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 1 << 20 {
        bail!("implausible string length {len}");
    }
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    String::from_utf8(b).context("wire string not utf-8")
}

// ---- Value codec (byte-identical to the checkpoint v2 encoding) ---------

pub fn write_value(w: &mut impl Write, v: &Value, depth: usize) -> Result<()> {
    if depth > MAX_DEPTH {
        bail!("value nesting exceeds {MAX_DEPTH}");
    }
    match v {
        Value::Unit => w.write_all(&[0u8])?,
        Value::Bool(b) => {
            w.write_all(&[1u8])?;
            w.write_all(&[*b as u8])?;
        }
        Value::F32(f) => {
            w.write_all(&[2u8])?;
            w.write_all(&f.to_le_bytes())?;
        }
        Value::Usize(n) => {
            w.write_all(&[3u8])?;
            w.write_all(&(*n as u64).to_le_bytes())?;
        }
        Value::Str(s) => {
            w.write_all(&[4u8])?;
            write_str(w, s)?;
        }
        Value::Tensor(t) => {
            w.write_all(&[5u8])?;
            let tag = match t.dtype() {
                DType::F32 => 0u8,
                DType::I32 => 1u8,
                DType::U32 => 2u8,
            };
            w.write_all(&[tag])?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for d in &t.shape {
                w.write_all(&(*d as u64).to_le_bytes())?;
            }
            match t.dtype() {
                DType::F32 => {
                    for v in t.as_f32() {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                DType::I32 => {
                    for v in t.as_i32() {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                DType::U32 => {
                    for v in t.as_u32() {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Value::List(vs) => {
            w.write_all(&[6u8])?;
            w.write_all(&(vs.len() as u32).to_le_bytes())?;
            for v in vs {
                write_value(w, v, depth + 1)?;
            }
        }
    }
    Ok(())
}

pub fn read_value(r: &mut impl Read, depth: usize) -> Result<Value> {
    if depth > MAX_DEPTH {
        bail!("value nesting exceeds {MAX_DEPTH}");
    }
    let tag = read_u8(r)?;
    Ok(match tag {
        0 => Value::Unit,
        1 => Value::Bool(read_u8(r)? != 0),
        2 => {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            Value::F32(f32::from_le_bytes(b))
        }
        3 => Value::Usize(read_u64(r)? as usize),
        4 => Value::Str(read_str(r)?),
        5 => {
            let dt = read_u8(r)?;
            let rank = read_u32(r)? as usize;
            if rank > 32 {
                bail!("implausible tensor rank {rank}");
            }
            let mut shape = Vec::with_capacity(rank);
            let mut elems: u64 = 1;
            for _ in 0..rank {
                let dim = read_u64(r)?;
                elems = elems.saturating_mul(dim.max(1));
                if dim > MAX_ELEMS || elems > MAX_ELEMS {
                    bail!("implausible tensor shape (dim {dim}, {elems}+ elements)");
                }
                shape.push(dim as usize);
            }
            let n: usize = shape.iter().product();
            let data = match dt {
                0 => TensorData::f32(read_f32s(r, n)?),
                1 => {
                    let mut bytes = vec![0u8; n * 4];
                    r.read_exact(&mut bytes)?;
                    TensorData::i32(
                        bytes
                            .chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                2 => {
                    let mut bytes = vec![0u8; n * 4];
                    r.read_exact(&mut bytes)?;
                    TensorData::u32(
                        bytes
                            .chunks_exact(4)
                            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                other => bail!("unknown tensor dtype tag {other}"),
            };
            Value::Tensor(Tensor::new(shape, data))
        }
        6 => {
            let len = read_u32(r)? as usize;
            if len > 1 << 24 {
                bail!("implausible list length {len}");
            }
            let mut vs = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                vs.push(read_value(r, depth + 1)?);
            }
            Value::List(vs)
        }
        other => bail!("unknown value tag {other}"),
    })
}

fn write_values(w: &mut impl Write, vs: &[Value]) -> Result<()> {
    w.write_all(&(vs.len() as u32).to_le_bytes())?;
    for v in vs {
        write_value(w, v, 0)?;
    }
    Ok(())
}

fn read_values(r: &mut impl Read) -> Result<Vec<Value>> {
    let n = read_u32(r)? as usize;
    if n > 1 << 24 {
        bail!("implausible value count {n}");
    }
    let mut vs = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        vs.push(read_value(r, 0)?);
    }
    Ok(vs)
}

fn write_tensor(w: &mut impl Write, t: &Tensor) -> Result<()> {
    write_value(w, &Value::Tensor(t.clone()), 0)
}

fn read_tensor(r: &mut impl Read) -> Result<Tensor> {
    match read_value(r, 0)? {
        Value::Tensor(t) => Ok(t),
        other => bail!("expected tensor on the wire, got {other:?}"),
    }
}

fn write_entries(w: &mut impl Write, entries: &[(String, Value)]) -> Result<()> {
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (k, v) in entries {
        write_str(w, k)?;
        write_value(w, v, 0)?;
    }
    Ok(())
}

fn read_entries(r: &mut impl Read) -> Result<Vec<(String, Value)>> {
    let n = read_u32(r)? as usize;
    if n > 1 << 16 {
        bail!("implausible entry count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let k = read_str(r)?;
        let v = read_value(r, 0)?;
        out.push((k, v));
    }
    Ok(out)
}

// ---- frames --------------------------------------------------------------

/// Write one length-prefixed frame. The caller flushes.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame of {} bytes exceeds MAX_FRAME", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame. Oversized lengths error before any
/// payload allocation; a short read (truncated frame) errors cleanly.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let len = read_u32(r)? as usize;
    if len > MAX_FRAME {
        bail!("frame header claims {len} bytes (> MAX_FRAME)");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("truncated frame")?;
    Ok(buf)
}

// ---- messages ------------------------------------------------------------

/// Everything needed to create a particle on a remote node. Handlers are
/// NOT closures here: `program` names a node-locally registered handler
/// program (see `pd::programs`) plus its serializable config — the
/// ZhuSuan/Edward2 lesson that algorithms must stay transport-oblivious
/// while the runtime owns distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct CreateSpec {
    /// Fabric-assigned GLOBAL pid — the node registers the particle under
    /// exactly this id, so pids (and every (seed, pid, step) random
    /// stream) are identical no matter how particles are placed.
    pub pid: Pid,
    /// Pin to a device on the owning node; default round-robin by pid.
    pub device: Option<usize>,
    /// Handler program name + config; None registers no handlers (the
    /// particle only answers direct ops).
    pub program: Option<(String, Value)>,
    pub state: Vec<(String, Value)>,
    pub no_params: bool,
    pub init_params: Option<Tensor>,
    /// Model the client believes this node serves. The node rejects a
    /// mismatch: a standalone `push node-worker` loads its OWN manifest,
    /// and training a different model against it must fail loudly at
    /// creation, not as a shape error deep inside the NEL.
    pub model: String,
}

/// Handler-less particle operations (the PD's direct API).
#[derive(Debug, Clone, PartialEq)]
pub enum DirectOp {
    Step { pid: Pid, x: Tensor, y: Tensor, lr: f32 },
    AdamStep { pid: Pid, x: Tensor, y: Tensor, lr: f32 },
    Forward { pid: Pid, x: Tensor },
    Grad { pid: Pid, x: Tensor, y: Tensor },
    Get { pid: Pid },
    Set { pid: Pid, t: Tensor },
}

impl DirectOp {
    pub fn pid(&self) -> Pid {
        match self {
            DirectOp::Step { pid, .. }
            | DirectOp::AdamStep { pid, .. }
            | DirectOp::Forward { pid, .. }
            | DirectOp::Grad { pid, .. }
            | DirectOp::Get { pid }
            | DirectOp::Set { pid, .. } => *pid,
        }
    }
}

/// One client->server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Create(CreateSpec),
    Send { pid: Pid, msg: String, args: Vec<Value> },
    /// Batched fan-out: ONE frame for the whole pid set; the response is
    /// one `Response::Many` with a result per pid in input order.
    Broadcast { pids: Vec<Pid>, msg: String, args: Vec<Value> },
    Direct(DirectOp),
    DrainParams,
    ParticleState { pid: Pid },
    RestoreState { pid: Pid, entries: Vec<(String, Value)> },
    Stats,
    Shutdown,
    /// Liveness probe from the fabric's heartbeat monitor. A fixed-size
    /// message that NEVER carries tensors; the node echoes the nonce back
    /// as `Response::One(Ok(Usize(nonce)))`. Heartbeat frames bypass the
    /// data-path transport counters so frame accounting stays exact.
    Heartbeat { nonce: u64 },
    /// Batched re-creation of a dead node's particles on a survivor: ONE
    /// frame per destination node carrying every migrated spec (original
    /// global pids, checkpointed params as `init_params`, checkpointed
    /// chain state). The response is one `Response::Many` with a result
    /// per spec in input order.
    Migrate { specs: Vec<CreateSpec> },
    /// Batched posterior-reservoir snapshot: ONE frame per node carrying
    /// that node's local pid set, replacing the per-chain `ParticleState`
    /// round-trip loop in `PosteriorServer::refresh`. The response is one
    /// `Response::Many` with, per pid in input order, the particle's
    /// state entries re-encoded through the shared Value codec (the same
    /// dialect checkpoint files use), so a refresh costs exactly one
    /// frame per node regardless of chain count.
    SnapshotNode { pids: Vec<Pid> },
}

/// One server->client message, tagged with the request id it answers.
#[derive(Debug, Clone)]
pub enum Response {
    One(Result<Value, String>),
    /// Per-position results of a broadcast; individual positions may fail
    /// without failing the batch (join_all's first-error-by-position
    /// semantics are applied client-side, exactly as in-process).
    Many(Vec<Result<Value, String>>),
    Stats(Box<NelStats>),
}

const K_CREATE: u8 = 1;
const K_SEND: u8 = 2;
const K_BROADCAST: u8 = 3;
const K_DIRECT: u8 = 4;
const K_DRAIN: u8 = 5;
const K_STATE: u8 = 6;
const K_RESTORE: u8 = 7;
const K_STATS: u8 = 8;
const K_SHUTDOWN: u8 = 9;
const K_HEARTBEAT: u8 = 10;
const K_MIGRATE: u8 = 11;
const K_SNAPSHOT_NODE: u8 = 12;

const R_ONE: u8 = 1;
const R_MANY: u8 = 2;
const R_STATS: u8 = 3;

/// Cheap peek: is this encoded request frame a heartbeat probe? The
/// evented server's shard thread uses this to pong liveness probes
/// inline (a heartbeat touches no NEL state, so jumping the offload
/// queue is safe) while everything else leaves the shard — keeping pong
/// latency independent of how busy the connection's dispatch queue is,
/// which is exactly what a LIVENESS probe must measure.
pub fn request_is_heartbeat(buf: &[u8]) -> bool {
    buf.len() >= 2 && buf[0] == WIRE_VERSION && buf[1] == K_HEARTBEAT
}

fn write_opt_tensor(w: &mut impl Write, t: &Option<Tensor>) -> Result<()> {
    match t {
        None => w.write_all(&[0u8])?,
        Some(t) => {
            w.write_all(&[1u8])?;
            write_tensor(w, t)?;
        }
    }
    Ok(())
}

fn read_opt_tensor(r: &mut impl Read) -> Result<Option<Tensor>> {
    Ok(match read_u8(r)? {
        0 => None,
        _ => Some(read_tensor(r)?),
    })
}

// The CreateSpec body is shared by K_CREATE (one spec) and K_MIGRATE (a
// batch of specs) — one codec, so migrated particles are re-created from
// byte-identical material.

fn write_create_spec(w: &mut impl Write, spec: &CreateSpec) -> Result<()> {
    w.write_all(&spec.pid.0.to_le_bytes())?;
    match spec.device {
        None => w.write_all(&[0u8])?,
        Some(d) => {
            w.write_all(&[1u8])?;
            w.write_all(&(d as u64).to_le_bytes())?;
        }
    }
    match &spec.program {
        None => w.write_all(&[0u8])?,
        Some((name, cfg)) => {
            w.write_all(&[1u8])?;
            write_str(w, name)?;
            write_value(w, cfg, 0)?;
        }
    }
    write_entries(w, &spec.state)?;
    w.write_all(&[spec.no_params as u8])?;
    write_opt_tensor(w, &spec.init_params)?;
    write_str(w, &spec.model)?;
    Ok(())
}

fn read_create_spec(r: &mut impl Read) -> Result<CreateSpec> {
    let pid = Pid(read_u32(r)?);
    let device = match read_u8(r)? {
        0 => None,
        _ => Some(read_u64(r)? as usize),
    };
    let program = match read_u8(r)? {
        0 => None,
        _ => {
            let name = read_str(r)?;
            let cfg = read_value(r, 0)?;
            Some((name, cfg))
        }
    };
    let state = read_entries(r)?;
    let no_params = read_u8(r)? != 0;
    let init_params = read_opt_tensor(r)?;
    let model = read_str(r)?;
    Ok(CreateSpec { pid, device, program, state, no_params, init_params, model })
}

pub fn encode_request(req_id: u64, req: &Request) -> Result<Vec<u8>> {
    let mut w = Vec::new();
    w.write_all(&[WIRE_VERSION])?;
    let kind = match req {
        Request::Create(_) => K_CREATE,
        Request::Send { .. } => K_SEND,
        Request::Broadcast { .. } => K_BROADCAST,
        Request::Direct(_) => K_DIRECT,
        Request::DrainParams => K_DRAIN,
        Request::ParticleState { .. } => K_STATE,
        Request::RestoreState { .. } => K_RESTORE,
        Request::Stats => K_STATS,
        Request::Shutdown => K_SHUTDOWN,
        Request::Heartbeat { .. } => K_HEARTBEAT,
        Request::Migrate { .. } => K_MIGRATE,
        Request::SnapshotNode { .. } => K_SNAPSHOT_NODE,
    };
    w.write_all(&[kind])?;
    w.write_all(&req_id.to_le_bytes())?;
    match req {
        Request::Create(spec) => write_create_spec(&mut w, spec)?,
        Request::Send { pid, msg, args } => {
            w.write_all(&pid.0.to_le_bytes())?;
            write_str(&mut w, msg)?;
            write_values(&mut w, args)?;
        }
        Request::Broadcast { pids, msg, args } => {
            w.write_all(&(pids.len() as u32).to_le_bytes())?;
            for p in pids {
                w.write_all(&p.0.to_le_bytes())?;
            }
            write_str(&mut w, msg)?;
            write_values(&mut w, args)?;
        }
        Request::Direct(op) => {
            let (tag, pid) = match op {
                DirectOp::Step { pid, .. } => (1u8, pid),
                DirectOp::AdamStep { pid, .. } => (2u8, pid),
                DirectOp::Forward { pid, .. } => (3u8, pid),
                DirectOp::Grad { pid, .. } => (4u8, pid),
                DirectOp::Get { pid } => (5u8, pid),
                DirectOp::Set { pid, .. } => (6u8, pid),
            };
            w.write_all(&[tag])?;
            w.write_all(&pid.0.to_le_bytes())?;
            match op {
                DirectOp::Step { x, y, lr, .. } | DirectOp::AdamStep { x, y, lr, .. } => {
                    w.write_all(&lr.to_le_bytes())?;
                    write_tensor(&mut w, x)?;
                    write_tensor(&mut w, y)?;
                }
                DirectOp::Forward { x, .. } => write_tensor(&mut w, x)?,
                DirectOp::Grad { x, y, .. } => {
                    write_tensor(&mut w, x)?;
                    write_tensor(&mut w, y)?;
                }
                DirectOp::Get { .. } => {}
                DirectOp::Set { t, .. } => write_tensor(&mut w, t)?,
            }
        }
        Request::DrainParams | Request::Stats | Request::Shutdown => {}
        Request::ParticleState { pid } => w.write_all(&pid.0.to_le_bytes())?,
        Request::RestoreState { pid, entries } => {
            w.write_all(&pid.0.to_le_bytes())?;
            write_entries(&mut w, entries)?;
        }
        Request::Heartbeat { nonce } => w.write_all(&nonce.to_le_bytes())?,
        Request::Migrate { specs } => {
            w.write_all(&(specs.len() as u32).to_le_bytes())?;
            for spec in specs {
                write_create_spec(&mut w, spec)?;
            }
        }
        Request::SnapshotNode { pids } => {
            w.write_all(&(pids.len() as u32).to_le_bytes())?;
            for p in pids {
                w.write_all(&p.0.to_le_bytes())?;
            }
        }
    }
    Ok(w)
}

pub fn decode_request(buf: &[u8]) -> Result<(u64, Request)> {
    let mut r = buf;
    let version = read_u8(&mut r)?;
    if version != WIRE_VERSION {
        bail!("unsupported wire version {version} (have {WIRE_VERSION})");
    }
    let kind = read_u8(&mut r)?;
    let req_id = read_u64(&mut r)?;
    let req = match kind {
        K_CREATE => Request::Create(read_create_spec(&mut r)?),
        K_SEND => {
            let pid = Pid(read_u32(&mut r)?);
            let msg = read_str(&mut r)?;
            let args = read_values(&mut r)?;
            Request::Send { pid, msg, args }
        }
        K_BROADCAST => {
            let n = read_u32(&mut r)? as usize;
            if n > 1 << 24 {
                bail!("implausible broadcast fan-out {n}");
            }
            let mut pids = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                pids.push(Pid(read_u32(&mut r)?));
            }
            let msg = read_str(&mut r)?;
            let args = read_values(&mut r)?;
            Request::Broadcast { pids, msg, args }
        }
        K_DIRECT => {
            let tag = read_u8(&mut r)?;
            let pid = Pid(read_u32(&mut r)?);
            let op = match tag {
                1 | 2 => {
                    let mut lrb = [0u8; 4];
                    r.read_exact(&mut lrb)?;
                    let lr = f32::from_le_bytes(lrb);
                    let x = read_tensor(&mut r)?;
                    let y = read_tensor(&mut r)?;
                    if tag == 1 {
                        DirectOp::Step { pid, x, y, lr }
                    } else {
                        DirectOp::AdamStep { pid, x, y, lr }
                    }
                }
                3 => DirectOp::Forward { pid, x: read_tensor(&mut r)? },
                4 => {
                    let x = read_tensor(&mut r)?;
                    let y = read_tensor(&mut r)?;
                    DirectOp::Grad { pid, x, y }
                }
                5 => DirectOp::Get { pid },
                6 => DirectOp::Set { pid, t: read_tensor(&mut r)? },
                other => bail!("unknown direct-op tag {other}"),
            };
            Request::Direct(op)
        }
        K_DRAIN => Request::DrainParams,
        K_STATE => Request::ParticleState { pid: Pid(read_u32(&mut r)?) },
        K_RESTORE => {
            let pid = Pid(read_u32(&mut r)?);
            let entries = read_entries(&mut r)?;
            Request::RestoreState { pid, entries }
        }
        K_STATS => Request::Stats,
        K_SHUTDOWN => Request::Shutdown,
        K_HEARTBEAT => Request::Heartbeat { nonce: read_u64(&mut r)? },
        K_MIGRATE => {
            let n = read_u32(&mut r)? as usize;
            if n > 1 << 16 {
                bail!("implausible migration batch {n}");
            }
            let mut specs = Vec::with_capacity(n);
            for _ in 0..n {
                specs.push(read_create_spec(&mut r)?);
            }
            Request::Migrate { specs }
        }
        K_SNAPSHOT_NODE => {
            let n = read_u32(&mut r)? as usize;
            if n > 1 << 24 {
                bail!("implausible snapshot fan-out {n}");
            }
            let mut pids = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                pids.push(Pid(read_u32(&mut r)?));
            }
            Request::SnapshotNode { pids }
        }
        other => bail!("unknown request kind {other}"),
    };
    Ok((req_id, req))
}

fn write_result(w: &mut impl Write, res: &Result<Value, String>) -> Result<()> {
    match res {
        Ok(v) => {
            w.write_all(&[0u8])?;
            write_value(w, v, 0)?;
        }
        Err(e) => {
            w.write_all(&[1u8])?;
            write_str(w, e)?;
        }
    }
    Ok(())
}

fn read_result(r: &mut impl Read) -> Result<Result<Value, String>> {
    Ok(match read_u8(r)? {
        0 => Ok(read_value(r, 0)?),
        _ => Err(read_str(r)?),
    })
}

pub fn encode_response(req_id: u64, resp: &Response) -> Result<Vec<u8>> {
    let mut w = Vec::new();
    w.write_all(&[WIRE_VERSION])?;
    let kind = match resp {
        Response::One(_) => R_ONE,
        Response::Many(_) => R_MANY,
        Response::Stats(_) => R_STATS,
    };
    w.write_all(&[kind])?;
    w.write_all(&req_id.to_le_bytes())?;
    match resp {
        Response::One(res) => write_result(&mut w, res)?,
        Response::Many(results) => {
            w.write_all(&(results.len() as u32).to_le_bytes())?;
            for res in results {
                write_result(&mut w, res)?;
            }
        }
        Response::Stats(stats) => write_nel_stats(&mut w, stats)?,
    }
    Ok(w)
}

pub fn decode_response(buf: &[u8]) -> Result<(u64, Response)> {
    let mut r = buf;
    let version = read_u8(&mut r)?;
    if version != WIRE_VERSION {
        bail!("unsupported wire version {version} (have {WIRE_VERSION})");
    }
    let kind = read_u8(&mut r)?;
    let req_id = read_u64(&mut r)?;
    let resp = match kind {
        R_ONE => Response::One(read_result(&mut r)?),
        R_MANY => {
            let n = read_u32(&mut r)? as usize;
            if n > 1 << 24 {
                bail!("implausible response batch {n}");
            }
            let mut results = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                results.push(read_result(&mut r)?);
            }
            Response::Many(results)
        }
        R_STATS => Response::Stats(Box::new(read_nel_stats(&mut r)?)),
        other => bail!("unknown response kind {other}"),
    };
    Ok((req_id, resp))
}

// ---- NelStats codec (exact: u64/f64 fields, no Value round-off) ----------

fn write_nel_stats(w: &mut impl Write, s: &NelStats) -> Result<()> {
    for v in [s.msgs_sent, s.msgs_cross_device, s.msg_payload_bytes, s.handler_errors] {
        w.write_all(&v.to_le_bytes())?;
    }
    let sc = &s.sched;
    for v in [
        sc.pool_target as u64,
        sc.max_workers as u64,
        sc.workers_live as u64,
        sc.workers_blocked as u64,
        sc.workers_peak as u64,
        sc.spawns,
        sc.retires,
        sc.compensations,
        sc.handler_runs,
        sc.turns,
        sc.steals,
        sc.priority_turns,
        sc.helps,
    ] {
        w.write_all(&v.to_le_bytes())?;
    }
    w.write_all(&(s.devices.len() as u32).to_le_bytes())?;
    for d in &s.devices {
        for v in [
            d.jobs,
            d.cache_hits,
            d.cache_misses,
            d.swaps_in,
            d.swaps_out,
            d.swap_bytes,
            d.views,
            d.view_bytes,
            d.transfers,
            d.transfer_bytes,
            d.client.compiles,
            d.client.executions,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        for v in [
            d.busy_secs,
            d.modeled_swap_secs,
            d.modeled_transfer_secs,
            d.client.compile_secs,
            d.client.execute_secs,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_nel_stats(r: &mut impl Read) -> Result<NelStats> {
    let msgs_sent = read_u64(r)?;
    let msgs_cross_device = read_u64(r)?;
    let msg_payload_bytes = read_u64(r)?;
    let handler_errors = read_u64(r)?;
    let sched = SchedStats {
        pool_target: read_u64(r)? as usize,
        max_workers: read_u64(r)? as usize,
        workers_live: read_u64(r)? as usize,
        workers_blocked: read_u64(r)? as usize,
        workers_peak: read_u64(r)? as usize,
        spawns: read_u64(r)?,
        retires: read_u64(r)?,
        compensations: read_u64(r)?,
        handler_runs: read_u64(r)?,
        turns: read_u64(r)?,
        steals: read_u64(r)?,
        priority_turns: read_u64(r)?,
        helps: read_u64(r)?,
    };
    let n_dev = read_u32(r)? as usize;
    if n_dev > 1 << 16 {
        bail!("implausible device count {n_dev}");
    }
    let mut devices = Vec::with_capacity(n_dev);
    for _ in 0..n_dev {
        let mut d = DeviceStats {
            jobs: read_u64(r)?,
            cache_hits: read_u64(r)?,
            cache_misses: read_u64(r)?,
            swaps_in: read_u64(r)?,
            swaps_out: read_u64(r)?,
            swap_bytes: read_u64(r)?,
            views: read_u64(r)?,
            view_bytes: read_u64(r)?,
            transfers: read_u64(r)?,
            transfer_bytes: read_u64(r)?,
            ..DeviceStats::default()
        };
        d.client.compiles = read_u64(r)?;
        d.client.executions = read_u64(r)?;
        d.busy_secs = read_f64(r)?;
        d.modeled_swap_secs = read_f64(r)?;
        d.modeled_transfer_secs = read_f64(r)?;
        d.client.compile_secs = read_f64(r)?;
        d.client.execute_secs = read_f64(r)?;
        devices.push(d);
    }
    Ok(NelStats {
        msgs_sent,
        msgs_cross_device,
        msg_payload_bytes,
        handler_errors,
        sched,
        devices,
    })
}

// ---- test/bench support ---------------------------------------------------

/// Seeded generator of arbitrary nested `Value`s (no proptest in the
/// vendored crate set). Used by the codec property tests and the wire
/// throughput micro-bench.
pub fn arbitrary_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(6) } else { rng.below(7) } {
        0 => Value::Unit,
        1 => Value::Bool(rng.below(2) == 0),
        2 => Value::F32(rng.normal() * 100.0),
        3 => Value::Usize(rng.below(1 << 20)),
        4 => {
            let n = rng.below(12);
            Value::Str((0..n).map(|_| (rng.below(94) as u8 + 33) as char).collect())
        }
        5 => {
            let n = 1 + rng.below(16);
            match rng.below(3) {
                0 => Value::Tensor(Tensor::f32(vec![n], rng.normal_vec(n))),
                1 => Value::Tensor(Tensor::i32(
                    vec![n],
                    (0..n).map(|_| rng.next_u32() as i32).collect(),
                )),
                _ => Value::Tensor(Tensor::u32(
                    vec![n],
                    (0..n).map(|_| rng.next_u32()).collect(),
                )),
            }
        }
        _ => {
            let n = rng.below(5);
            Value::List((0..n).map(|_| arbitrary_value(rng, depth - 1)).collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_value(v: &Value) -> Value {
        let mut buf = Vec::new();
        write_value(&mut buf, v, 0).unwrap();
        let got = read_value(&mut buf.as_slice(), 0).unwrap();
        // every byte must be consumed
        assert_eq!(
            {
                let mut r = buf.as_slice();
                let _ = read_value(&mut r, 0).unwrap();
                r.len()
            },
            0,
            "trailing bytes after decode"
        );
        got
    }

    #[test]
    fn prop_value_codec_roundtrip() {
        for seed in 0..120u64 {
            let mut rng = Rng::new(seed ^ 0x31e3);
            let v = arbitrary_value(&mut rng, 3);
            assert_eq!(roundtrip_value(&v), v, "seed {seed}");
        }
    }

    #[test]
    fn prop_truncated_values_rejected() {
        for seed in 0..120u64 {
            let mut rng = Rng::new(seed ^ 0x7a11);
            let v = arbitrary_value(&mut rng, 3);
            let mut buf = Vec::new();
            write_value(&mut buf, &v, 0).unwrap();
            if buf.len() <= 1 {
                continue; // Unit: 1 byte, nothing to truncate meaningfully
            }
            let cut = 1 + rng.below(buf.len() - 1);
            let truncated = &buf[..cut];
            let mut r = truncated;
            // decoding may legitimately succeed on a PREFIX value only if
            // the remainder would then be trailing garbage — for a single
            // value write, any strict prefix must fail to decode fully.
            if let Ok(prefix) = read_value(&mut r, 0) {
                assert!(
                    !r.is_empty() || prefix != v,
                    "seed {seed}: truncation to {cut}/{} bytes went unnoticed",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn frame_roundtrip_and_bounds() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let got = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(got, b"hello");

        // truncated payload
        let mut short = buf.clone();
        short.truncate(buf.len() - 2);
        let err = read_frame(&mut short.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");

        // oversized header must error before allocating
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut huge.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("MAX_FRAME"), "{err:#}");
    }

    #[test]
    fn request_roundtrip_all_kinds() {
        let spec = CreateSpec {
            pid: Pid(7),
            device: Some(1),
            program: Some(("sgmcmc".to_string(), Value::Usize(3))),
            state: vec![("k".to_string(), Value::F32(1.5))],
            no_params: false,
            init_params: Some(Tensor::f32(vec![2], vec![0.5, -0.5])),
            model: "mlp_tiny".to_string(),
        };
        let reqs = vec![
            Request::Create(spec.clone()),
            Request::Send {
                pid: Pid(3),
                msg: "STEP".to_string(),
                args: vec![Value::Unit, Value::Tensor(Tensor::scalar_f32(2.0))],
            },
            Request::Broadcast {
                pids: vec![Pid(1), Pid(4), Pid(2)],
                msg: "MCMC_STEP".to_string(),
                args: vec![Value::Bool(true)],
            },
            Request::Direct(DirectOp::Step {
                pid: Pid(0),
                x: Tensor::f32(vec![2], vec![1.0, 2.0]),
                y: Tensor::f32(vec![1], vec![3.0]),
                lr: 1e-2,
            }),
            Request::Direct(DirectOp::AdamStep {
                pid: Pid(1),
                x: Tensor::scalar_f32(0.0),
                y: Tensor::scalar_f32(1.0),
                lr: 1e-3,
            }),
            Request::Direct(DirectOp::Forward { pid: Pid(2), x: Tensor::scalar_f32(4.0) }),
            Request::Direct(DirectOp::Grad {
                pid: Pid(3),
                x: Tensor::scalar_f32(4.0),
                y: Tensor::scalar_f32(5.0),
            }),
            Request::Direct(DirectOp::Get { pid: Pid(4) }),
            Request::Direct(DirectOp::Set { pid: Pid(5), t: Tensor::zeros(vec![3]) }),
            Request::DrainParams,
            Request::ParticleState { pid: Pid(9) },
            Request::RestoreState {
                pid: Pid(9),
                entries: vec![("t".to_string(), Value::Usize(11))],
            },
            Request::Stats,
            Request::Shutdown,
            Request::Heartbeat { nonce: 0xDEAD_BEEF_0042 },
            Request::Migrate {
                specs: vec![
                    spec,
                    CreateSpec {
                        pid: Pid(11),
                        device: None,
                        program: None,
                        state: vec![("sgmcmc_t".to_string(), Value::Usize(6))],
                        no_params: true,
                        init_params: None,
                        model: "mlp_tiny".to_string(),
                    },
                ],
            },
            Request::SnapshotNode { pids: vec![Pid(2), Pid(0), Pid(5)] },
            Request::SnapshotNode { pids: vec![] },
        ];
        for (i, req) in reqs.into_iter().enumerate() {
            let buf = encode_request(i as u64, &req).unwrap();
            let (id, back) = decode_request(&buf).unwrap();
            assert_eq!(id, i as u64);
            assert_eq!(back, req, "request kind {i}");
        }
    }

    #[test]
    fn response_roundtrip_and_error_positions() {
        let resp = Response::Many(vec![
            Ok(Value::Usize(1)),
            Err("boom at 1".to_string()),
            Ok(Value::Unit),
            Err("boom at 3".to_string()),
        ]);
        let buf = encode_response(42, &resp).unwrap();
        let (id, back) = decode_response(&buf).unwrap();
        assert_eq!(id, 42);
        let Response::Many(results) = back else { panic!("expected Many") };
        assert_eq!(results.len(), 4);
        assert_eq!(results[0], Ok(Value::Usize(1)));
        assert_eq!(results[1], Err("boom at 1".to_string()));
        assert_eq!(results[3], Err("boom at 3".to_string()));
    }

    #[test]
    fn stats_roundtrip_exact() {
        let mut s = NelStats {
            msgs_sent: 10,
            msgs_cross_device: 3,
            msg_payload_bytes: 1 << 33,
            handler_errors: 1,
            ..NelStats::default()
        };
        s.sched.pool_target = 4;
        s.sched.handler_runs = 99;
        s.sched.workers_peak = 7;
        let mut d = DeviceStats {
            jobs: 17,
            busy_secs: 0.123456789012345,
            swap_bytes: 1 << 40,
            ..DeviceStats::default()
        };
        d.client.executions = 5;
        d.client.execute_secs = 1e-9;
        s.devices.push(d);
        let buf = encode_response(1, &Response::Stats(Box::new(s.clone()))).unwrap();
        let (_, back) = decode_response(&buf).unwrap();
        let Response::Stats(got) = back else { panic!("expected Stats") };
        assert_eq!(got.msgs_sent, s.msgs_sent);
        assert_eq!(got.msg_payload_bytes, s.msg_payload_bytes);
        assert_eq!(got.sched.handler_runs, 99);
        assert_eq!(got.sched.workers_peak, 7);
        assert_eq!(got.devices.len(), 1);
        assert_eq!(got.devices[0].jobs, 17);
        assert_eq!(got.devices[0].busy_secs, 0.123456789012345, "f64 must be exact");
        assert_eq!(got.devices[0].swap_bytes, 1 << 40);
        assert_eq!(got.devices[0].client.execute_secs, 1e-9);
    }

    #[test]
    fn unknown_version_and_kind_rejected() {
        let mut buf = encode_request(0, &Request::Stats).unwrap();
        buf[0] = 99; // version
        assert!(decode_request(&buf).is_err());
        let mut buf = encode_request(0, &Request::Stats).unwrap();
        buf[1] = 250; // kind
        assert!(decode_request(&buf).is_err());
    }
}
