//! PD checkpointing: save/restore every particle's parameters, the model
//! identity, and (since v2) each particle's local *state* — Adam moments,
//! SWAG moments, SGMCMC chain state (step clock, SGHMC momentum, the
//! posterior-sample reservoir) — to a single binary file.
//!
//! Format (little-endian, versioned):
//!
//! ```text
//! magic  u32 = 0x50555348 ("PUSH")      version u32 = 2
//! model-name len u32 + utf8 bytes
//! particle count u32
//! per particle: pid u32, elem count u64, f32 data
//! -- v2 only --
//! state count u32
//! per state entry: pid u32, key count u32,
//!   per key: key len u32 + utf8 bytes, value (tagged, recursive)
//! ```
//!
//! The tagged Value encoding is the SHARED wire codec in [`crate::pd::wire`]
//! (tag u8: 0 Unit; 1 Bool(u8); 2 F32(f32); 3 Usize(u64); 4 Str(len u32 +
//! utf8); 5 Tensor(dtype u8 {0 f32, 1 i32, 2 u32}, rank u32, dims u64
//! each, raw 4-byte elements); 6 List(count u32 + values)) — checkpoint
//! files and transport frames speak one dialect, so the v1/v2 tests here
//! pin both. Version-1 files (params only) still load, with empty state.
//!
//! No serde/npy in the vendored crate set, so the codec is hand-rolled and
//! round-trip tested. Capture is zero-copy (COW snapshots); restore merges
//! state keys into live particles without touching unrelated keys.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::particle::{Pid, Value};
use crate::pd::wire::{read_f32s, read_u32, read_u64, read_value, write_value, MAX_ELEMS};
use crate::pd::PushDist;
use crate::runtime::Tensor;

const MAGIC: u32 = 0x5055_5348;
const VERSION: u32 = 2;

/// A saved PD snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub params: BTreeMap<Pid, Tensor>,
    /// Per-particle local state (only particles with non-empty state).
    pub state: BTreeMap<Pid, Vec<(String, Value)>>,
}

impl Checkpoint {
    /// Snapshot a PD (drains device caches first). Captured tensors —
    /// parameters AND tensor-valued state entries — share storage with the
    /// live values (COW): capturing costs no parameter-sized copies, and
    /// later training steps detach on write. Call at a quiescent point
    /// (no in-flight training round), as with `drain_params`.
    pub fn capture(pd: &PushDist) -> Result<Checkpoint> {
        let params = pd.drain_params().map_err(|e| anyhow!("{e}"))?;
        let mut state = BTreeMap::new();
        for pid in pd.particles() {
            // checked: a transport failure must fail the capture, not
            // silently drop one node's chain state from the snapshot
            if let Some(entries) = pd.particle_state_checked(pid).map_err(|e| anyhow!("{e}"))? {
                if !entries.is_empty() {
                    state.insert(pid, entries);
                }
            }
        }
        Ok(Checkpoint { model: pd.model().name.clone(), params, state })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let file = std::fs::File::create(path).with_context(|| format!("{path:?}"))?;
        let mut w = std::io::BufWriter::new(file);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        let name = self.model.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for (pid, t) in &self.params {
            w.write_all(&pid.0.to_le_bytes())?;
            w.write_all(&(t.element_count() as u64).to_le_bytes())?;
            for v in t.as_f32() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        w.write_all(&(self.state.len() as u32).to_le_bytes())?;
        for (pid, entries) in &self.state {
            w.write_all(&pid.0.to_le_bytes())?;
            w.write_all(&(entries.len() as u32).to_le_bytes())?;
            for (key, value) in entries {
                let kb = key.as_bytes();
                w.write_all(&(kb.len() as u32).to_le_bytes())?;
                w.write_all(kb)?;
                write_value(&mut w, value, 0)
                    .with_context(|| format!("state key {key:?} of {pid}"))?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let file = std::fs::File::open(path).with_context(|| format!("{path:?}"))?;
        let mut r = std::io::BufReader::new(file);
        if read_u32(&mut r)? != MAGIC {
            bail!("{path:?} is not a Push checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != 1 && version != VERSION {
            bail!("{path:?}: unsupported checkpoint version {version}");
        }
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("{path:?}: implausible model-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let model = String::from_utf8(name).context("model name not utf-8")?;
        let count = read_u32(&mut r)? as usize;
        let mut params = BTreeMap::new();
        for _ in 0..count {
            let pid = Pid(read_u32(&mut r)?);
            let n = read_u64(&mut r)?;
            if n > MAX_ELEMS {
                bail!("{path:?}: implausible parameter count {n} for {pid}");
            }
            let n = n as usize;
            params.insert(pid, Tensor::f32(vec![n], read_f32s(&mut r, n)?));
        }
        let mut state = BTreeMap::new();
        if version >= 2 {
            let n_state = read_u32(&mut r)? as usize;
            if n_state > 1 << 20 {
                bail!("{path:?}: implausible state-entry count {n_state}");
            }
            for _ in 0..n_state {
                let pid = Pid(read_u32(&mut r)?);
                let n_keys = read_u32(&mut r)? as usize;
                if n_keys > 1 << 16 {
                    bail!("{path:?}: implausible key count {n_keys} for {pid}");
                }
                let mut entries = Vec::with_capacity(n_keys);
                for _ in 0..n_keys {
                    let klen = read_u32(&mut r)? as usize;
                    if klen > 4096 {
                        bail!("{path:?}: implausible state-key length {klen}");
                    }
                    let mut kb = vec![0u8; klen];
                    r.read_exact(&mut kb)?;
                    let key = String::from_utf8(kb).context("state key not utf-8")?;
                    let value = read_value(&mut r, 0)
                        .with_context(|| format!("state key {key:?} of {pid}"))?;
                    entries.push((key, value));
                }
                state.insert(pid, entries);
            }
        }
        Ok(Checkpoint { model, params, state })
    }

    /// Restore parameters and particle state into a PD whose particles
    /// were created in the same order (pids must match; model name must
    /// match). State keys merge over the live state; parameters overwrite.
    pub fn restore(&self, pd: &PushDist) -> Result<()> {
        if pd.model().name != self.model {
            bail!(
                "checkpoint is for model {:?}, PD wraps {:?}",
                self.model,
                pd.model().name
            );
        }
        let futs: Vec<crate::PFuture> = self
            .params
            .iter()
            .map(|(pid, t)| pd.set(*pid, t.clone()))
            .collect();
        crate::PFuture::wait_all(&futs).map_err(|e| anyhow!("{e}"))?;
        for (pid, entries) in &self.state {
            pd.restore_particle_state(*pid, entries.clone())
                .map_err(|e| anyhow!("{e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(label: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("push-ckpt-{label}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_in_memory_format() {
        let mut params = BTreeMap::new();
        params.insert(Pid(0), Tensor::f32(vec![3], vec![1.0, -2.0, 3.5]));
        params.insert(Pid(7), Tensor::f32(vec![2], vec![0.25, f32::MIN_POSITIVE]));
        let mut state = BTreeMap::new();
        // a chain-shaped state: clock + momentum + reservoir + extras of
        // every codec type
        state.insert(
            Pid(0),
            vec![
                ("sgmcmc_t".to_string(), Value::Usize(42)),
                (
                    "sgmcmc_mom".to_string(),
                    Value::Tensor(Tensor::f32(vec![3], vec![0.1, 0.2, -0.3])),
                ),
                (
                    "sgmcmc_samples".to_string(),
                    Value::List(vec![
                        Value::Tensor(Tensor::f32(vec![3], vec![1.0, 2.0, 3.0])),
                        Value::Tensor(Tensor::f32(vec![3], vec![4.0, 5.0, 6.0])),
                    ]),
                ),
                ("flag".to_string(), Value::Bool(true)),
                ("note".to_string(), Value::Str("chain".to_string())),
                ("nil".to_string(), Value::Unit),
                ("lr".to_string(), Value::F32(0.125)),
                ("labels".to_string(), Value::Tensor(Tensor::i32(vec![2], vec![-1, 7]))),
                ("key".to_string(), Value::Tensor(Tensor::u32(vec![2], vec![0, 9]))),
            ],
        );
        let ck = Checkpoint { model: "mlp_tiny".into(), params, state };
        let dir = tmp_dir("rt");
        let path = dir.join("t.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_version1_files_with_empty_state() {
        // Hand-rolled v1 bytes: magic, version 1, name, one particle.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(4u32).to_le_bytes());
        bytes.extend_from_slice(b"mlp1");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes()); // pid 3
        bytes.extend_from_slice(&2u64.to_le_bytes()); // 2 elems
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.5f32).to_le_bytes());
        let dir = tmp_dir("v1");
        let path = dir.join("v1.ckpt");
        std::fs::write(&path, &bytes).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.model, "mlp1");
        assert_eq!(ck.params[&Pid(3)], Tensor::f32(vec![2], vec![1.5, -2.5]));
        assert!(ck.state.is_empty(), "v1 has no state section");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp_dir("bad");
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_unknown_version() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&99u32.to_le_bytes());
        let dir = tmp_dir("v99");
        let path = dir.join("v99.ckpt");
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
