//! PD checkpointing: save/restore every particle's parameters (and the
//! model identity) to a single binary file.
//!
//! Format (little-endian, versioned):
//!
//! ```text
//! magic  u32 = 0x50555348 ("PUSH")      version u32 = 1
//! model-name len u32 + utf8 bytes
//! particle count u32
//! per particle: pid u32, elem count u64, f32 data
//! ```
//!
//! No serde/npy in the vendored crate set, so the codec is hand-rolled and
//! round-trip tested.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::particle::Pid;
use crate::pd::PushDist;
use crate::runtime::Tensor;

const MAGIC: u32 = 0x5055_5348;
const VERSION: u32 = 1;

/// A saved PD snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub params: BTreeMap<Pid, Tensor>,
}

impl Checkpoint {
    /// Snapshot a PD (drains device caches first). The captured tensors
    /// share storage with the live parameters (COW) — capturing costs no
    /// parameter-sized copies, and later training steps detach on write.
    pub fn capture(pd: &PushDist) -> Result<Checkpoint> {
        let params = pd.drain_params().map_err(|e| anyhow!("{e}"))?;
        Ok(Checkpoint { model: pd.model().name.clone(), params })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(path).with_context(|| format!("{path:?}"))?);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        let name = self.model.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
        w.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for (pid, t) in &self.params {
            w.write_all(&pid.0.to_le_bytes())?;
            w.write_all(&(t.element_count() as u64).to_le_bytes())?;
            for v in t.as_f32() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut r =
            std::io::BufReader::new(std::fs::File::open(path).with_context(|| format!("{path:?}"))?);
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        let mut read_u32 = |r: &mut dyn Read| -> Result<u32> {
            r.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        if read_u32(&mut r)? != MAGIC {
            bail!("{path:?} is not a Push checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("{path:?}: unsupported checkpoint version {version}");
        }
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("{path:?}: implausible model-name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let model = String::from_utf8(name).context("model name not utf-8")?;
        let count = read_u32(&mut r)? as usize;
        let mut params = BTreeMap::new();
        for _ in 0..count {
            let pid = Pid(read_u32(&mut r)?);
            r.read_exact(&mut u64buf)?;
            let n = u64::from_le_bytes(u64buf) as usize;
            let mut data = vec![0f32; n];
            // bulk read as bytes, then reinterpret
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            params.insert(pid, Tensor::f32(vec![n], data));
        }
        Ok(Checkpoint { model, params })
    }

    /// Restore parameters into a PD whose particles were created in the
    /// same order (pids must match; model name must match).
    pub fn restore(&self, pd: &PushDist) -> Result<()> {
        if pd.model().name != self.model {
            bail!(
                "checkpoint is for model {:?}, PD wraps {:?}",
                self.model,
                pd.model().name
            );
        }
        let futs: Vec<crate::PFuture> = self
            .params
            .iter()
            .map(|(pid, t)| pd.set(*pid, t.clone()))
            .collect();
        crate::PFuture::wait_all(&futs).map_err(|e| anyhow!("{e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory_format() {
        let mut params = BTreeMap::new();
        params.insert(Pid(0), Tensor::f32(vec![3], vec![1.0, -2.0, 3.5]));
        params.insert(Pid(7), Tensor::f32(vec![2], vec![0.25, f32::MIN_POSITIVE]));
        let ck = Checkpoint { model: "mlp_tiny".into(), params };
        let dir = std::env::temp_dir().join(format!("push-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("push-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
