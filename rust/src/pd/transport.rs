//! Node transports behind the PD seam (DESIGN.md §Distributed NEL).
//!
//! A [`NodeTransport`] is the PD's view of ONE node: a NEL plus its M:N
//! scheduler and devices, reachable either in-process ([`InProc`] — the
//! degenerate case, bitwise-identical to the pre-fabric PD) or over a
//! real socket ([`TcpNode`] — length-prefixed [`wire`] frames on a
//! loopback or remote TCP connection, one server loop owning one NEL per
//! node). The inference algorithms never see this layer; they talk to
//! [`crate::pd::PushDist`], which routes through the
//! [`crate::pd::fabric::NodeFabric`].
//!
//! Protocol (client side):
//! * every request is ONE frame carrying a fresh `req_id`;
//! * a reader thread demultiplexes responses back to parked futures via
//!   a pending map, so any number of requests pipeline on one socket;
//! * a broadcast is ONE frame out regardless of fan-out and ONE batched
//!   response back with a result per pid in input order — per-position
//!   errors survive the wire, so `PFuture::join_all`'s
//!   first-error-by-position semantics are preserved unchanged.
//!
//! Server side, each connection gets: one NEL (created with the node's
//! config), a reader loop that dispatches ops without blocking on
//! handler completion (responses are sent from `on_ready` continuations
//! through a writer thread), and FIFO write-out of completed responses.
//! Everything binds 127.0.0.1 ephemeral ports in tests/benches, so CI
//! exercises real serialization and real sockets hermetically.
//!
//! Both halves come in two mechanically-equivalent flavors sharing one
//! demux/dispatch path (DESIGN.md §Event-driven transport):
//! * **threaded** (the reference): one reader thread per client link, one
//!   reader loop + writer thread per server connection;
//! * **evented** ([`TcpNode::connect_evented`], [`serve_evented`]): all
//!   connections multiplexed onto [`crate::pd::poll::Reactor`]'s fixed
//!   poll-thread pool; `PFuture::on_ready` continuations are the
//!   completion mechanism on both sides, server NELs are created lazily
//!   on the first data frame, and the accept loop holds N concurrent
//!   connections per node instead of exactly one. Request dispatch runs
//!   on the reactor's [`poll::offload`] pool (heartbeat pongs excepted —
//!   they answer straight from the shard) and responses queue on a
//!   per-connection outbox the owning shard flushes under `POLLOUT`, so
//!   a shard thread never blocks on a peer.
//!
//! Liveness (DESIGN.md §Elastic fabric): the fabric's monitor calls
//! [`NodeTransport::heartbeat_tick`] on a cadence; a TCP link tracks
//! [`LinkHealth`] from heartbeat pongs, and a link silent past
//! `dead_after` is severed so its pending futures fail promptly instead
//! of hanging. Heartbeat frames never carry tensors and never touch the
//! data-path counters. The [`fault`] module (tests and the `faultinject`
//! feature only) kills chosen links deterministically.

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::nel::{CreateOpts, Nel, NelConfig, NelStats};
use crate::particle::{HandlerTable, PFuture, Pid, PushError, Value};
use crate::pd::poll::{self, FrameVerdict, Sink, ThreadGauge};
use crate::pd::programs;
use crate::pd::wire::{self, CreateSpec, DirectOp, Request, Response};
use crate::runtime::{ModelSpec, Tensor};

/// Frame/byte counters of one node link. The in-process link never
/// frames anything (zero-copy Arc handoff), so its counters stay zero —
/// which is itself the invariant the single-node perf gate pins.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TransportCounters {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    /// Link failures observed on this node link: failed sends, futures
    /// failed by a connection-closed drain, and dead-link declarations.
    /// Monitoring sees link trouble here, not just on stderr.
    pub errors: u64,
    /// Heartbeat probes sent on this link. Heartbeats are accounted HERE
    /// only — they never touch the data-path frame/byte counters above,
    /// so frame-accounting invariants hold with the monitor running.
    pub heartbeats: u64,
}

#[derive(Default)]
struct CounterCells {
    frames_sent: AtomicU64,
    frames_received: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    errors: AtomicU64,
    heartbeats: AtomicU64,
}

impl CounterCells {
    fn snapshot(&self) -> TransportCounters {
        TransportCounters {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_received: self.frames_received.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_received: self.bytes_received.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            heartbeats: self.heartbeats.load(Ordering::Relaxed),
        }
    }
}

/// Liveness verdict of one node link, driven by the fabric's heartbeat
/// monitor (see `crate::pd::fabric::FabricConfig`). Links without a wire
/// ([`InProc`]) are trivially always healthy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkHealth {
    Healthy,
    /// No pong for more than half of `dead_after`: the link is slow or
    /// the peer is gone; not yet actionable.
    Suspect,
    /// The link is closed, or silent past `dead_after` and severed by the
    /// monitor. Every pending future on it has been failed promptly; the
    /// node's particles need migration.
    Dead,
}

/// The PD seam's per-node contract. Everything the PD can ask of a node
/// goes through here; `Value`s are the only payload type, exactly as the
/// paper's PD API prescribes.
pub trait NodeTransport: Send + Sync {
    fn kind(&self) -> &'static str;

    /// In-process creation with closure handlers. Only the local
    /// transport can do this — closures cannot cross the wire; remote
    /// nodes need [`NodeTransport::create_spec`] with a registered
    /// handler program.
    fn create_local(&self, opts: CreateOpts) -> Result<Pid, PushError>;

    /// Creation from a serializable spec (fabric-assigned global pid,
    /// node-locally resolved handler program).
    fn create_spec(&self, spec: CreateSpec) -> Result<Pid, PushError>;

    fn send(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture;

    /// Batched fan-out to pids ON THIS NODE: exactly one frame on a wire
    /// transport. Returned futures are in `pids` order.
    fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture>;

    fn direct(&self, op: DirectOp) -> PFuture;

    fn drain_params(&self) -> Result<Vec<(Pid, Tensor)>, PushError>;

    fn particle_state(&self, pid: Pid) -> Result<Option<Vec<(String, Value)>>, PushError>;

    fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError>;

    fn stats(&self) -> Result<NelStats, PushError>;

    fn counters(&self) -> TransportCounters;

    /// The node-local NEL, when there is one in this process (used by the
    /// trace example and artifact-backed benches; None over the wire).
    fn nel(&self) -> Option<&Nel> {
        None
    }

    /// One monitor tick: assess liveness against `dead_after` and, on a
    /// wire link, send one heartbeat probe. A link silent past
    /// `dead_after` is declared [`LinkHealth::Dead`] and severed so every
    /// pending future fails promptly instead of hanging. Links without a
    /// wire are always healthy and probe-free.
    fn heartbeat_tick(&self, dead_after: Duration) -> LinkHealth {
        let _ = dead_after;
        LinkHealth::Healthy
    }

    /// Last known liveness verdict (no probe). A closed wire link reports
    /// [`LinkHealth::Dead`] even when no monitor is running.
    fn health(&self) -> LinkHealth {
        LinkHealth::Healthy
    }

    /// Peer address of a wire link (None in-process): lets the fabric and
    /// tests name links in fault plans and recovery errors.
    fn peer_addr(&self) -> Option<SocketAddr> {
        None
    }

    /// Batched re-creation of migrated particles on this node. A wire
    /// transport sends ONE `Migrate` frame for the whole batch; the
    /// default simply loops [`NodeTransport::create_spec`].
    fn migrate(&self, specs: Vec<CreateSpec>) -> Result<(), PushError> {
        for spec in specs {
            self.create_spec(spec)?;
        }
        Ok(())
    }

    /// Batched reservoir snapshot of `pids` ON THIS NODE: exactly one
    /// data frame on a wire transport regardless of chain count (the
    /// serving tier's refresh is one `SnapshotNode` frame per node, not
    /// O(chains) `ParticleState` round-trips). Each returned future — in
    /// `pids` order — resolves to the particle's state encoded exactly
    /// like a `ParticleState` response (`Unit` = no such particle, else a
    /// List of `[key, value]` pairs; decode with [`decode_state_value`]).
    /// The default (in-process) implementation answers from the local NEL
    /// with already-completed futures.
    fn snapshot_node(&self, pids: &[Pid]) -> Vec<PFuture> {
        pids.iter()
            .map(|pid| {
                let fut = PFuture::new();
                fut.complete(self.particle_state(*pid).map(encode_state_value));
                fut
            })
            .collect()
    }
}

/// Wait on a transport future no longer than `expiry` allows. `None`
/// waits indefinitely (the pre-deadline behavior); a lapsed deadline
/// fails LOUDLY with a deadline error instead of blocking until the
/// heartbeat monitor declares the link dead — the caller owns retry and
/// failover policy. The future itself stays registered with the reader
/// demux; a late response completes it harmlessly with nobody waiting.
///
/// `configured` is the caller's whole deadline budget. The error names
/// BOTH it and the residual wait this future actually got: when a shared
/// expiry lapsed while earlier futures in the batch were being drained,
/// the residual is ~0 — reported alone it reads as "expired after 3ns"
/// and sends operators hunting a phantom misconfiguration.
pub fn wait_deadline(
    fut: &PFuture,
    expiry: Option<Instant>,
    configured: Option<Duration>,
) -> Result<Value, PushError> {
    match expiry {
        None => fut.wait(),
        Some(t) => {
            let remaining = t.saturating_duration_since(Instant::now());
            match fut.wait_timeout(remaining) {
                Some(res) => res,
                None => {
                    let budget = configured
                        .map(|d| format!("{d:?}"))
                        .unwrap_or_else(|| "unspecified".to_string());
                    Err(PushError::new(format!(
                        "request deadline expired (configured {budget}, residual wait \
                         {remaining:?}; node slow or unreachable)"
                    )))
                }
            }
        }
    }
}

/// Decode a pid that crossed the wire as a tagged `usize`. Pids are u32
/// everywhere else; a bare `as u32` here would silently wrap a corrupt or
/// hostile value (pid 4294967296 becomes pid 0) and hand one particle's
/// traffic to another. Out-of-range values are a decode error naming the
/// offending value instead.
pub fn decode_wire_pid(raw: usize) -> Result<Pid, PushError> {
    u32::try_from(raw).map(Pid).map_err(|_| {
        PushError::new(format!(
            "wire pid {raw} exceeds the u32 pid space (max {}); refusing silent truncation",
            u32::MAX
        ))
    })
}

/// Encode a particle's state entries the way `ParticleState` responses
/// always have: `Unit` for a missing particle, a List of `[key, value]`
/// pairs otherwise. Shared by the per-chain and batched snapshot paths
/// (both sides of the wire), so the two snapshot shapes speak one
/// dialect.
pub(crate) fn encode_state_value(entries: Option<Vec<(String, Value)>>) -> Value {
    match entries {
        None => Value::Unit,
        Some(entries) => Value::List(
            entries
                .into_iter()
                .map(|(k, v)| Value::List(vec![Value::Str(k), v]))
                .collect(),
        ),
    }
}

/// Inverse of [`encode_state_value`]: the client-side decode of one
/// particle's snapshot position.
pub(crate) fn decode_state_value(
    v: Value,
) -> Result<Option<Vec<(String, Value)>>, PushError> {
    match v {
        Value::Unit => Ok(None),
        Value::List(items) => {
            let mut entries = Vec::with_capacity(items.len());
            for item in items {
                let mut pair = item.list()?;
                if pair.len() != 2 {
                    return Err(PushError::new("malformed state entry"));
                }
                let v = pair.remove(1);
                let k = match pair.remove(0) {
                    Value::Str(s) => s,
                    other => return Err(PushError::new(format!("state key {other:?}"))),
                };
                entries.push((k, v));
            }
            Ok(Some(entries))
        }
        other => Err(PushError::new(format!("particle state returned {other:?}"))),
    }
}

// ---- in-process transport ------------------------------------------------

/// Today's behavior as the degenerate transport: direct calls into one
/// in-process NEL, no serialization, payloads move as zero-copy Arc
/// clones through the parameter plane.
pub struct InProc {
    nel: Nel,
    model: Arc<ModelSpec>,
}

impl InProc {
    pub fn new(cfg: NelConfig, model: Arc<ModelSpec>) -> Result<InProc> {
        Ok(InProc { nel: Nel::new(cfg)?, model })
    }
}

impl NodeTransport for InProc {
    fn kind(&self) -> &'static str {
        "inproc"
    }

    fn create_local(&self, opts: CreateOpts) -> Result<Pid, PushError> {
        self.nel.p_create(self.model.clone(), opts).map_err(PushError::from)
    }

    fn create_spec(&self, spec: CreateSpec) -> Result<Pid, PushError> {
        check_model(&spec, &self.model)?;
        let receive = match &spec.program {
            Some((name, cfg)) => programs::build_handlers(name, cfg, &self.model)?,
            None => HandlerTable::new(),
        };
        self.nel
            .p_create(
                self.model.clone(),
                CreateOpts {
                    pid: Some(spec.pid),
                    device: spec.device,
                    receive,
                    state: spec.state,
                    no_params: spec.no_params,
                    init_params: spec.init_params,
                },
            )
            .map_err(PushError::from)
    }

    fn send(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.nel.send(None, pid, msg, args)
    }

    fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        self.nel.broadcast(None, pids, msg, args)
    }

    fn direct(&self, op: DirectOp) -> PFuture {
        dispatch_direct(&self.nel, op)
    }

    fn drain_params(&self) -> Result<Vec<(Pid, Tensor)>, PushError> {
        Ok(self.nel.drain_params()?.into_iter().collect())
    }

    fn particle_state(&self, pid: Pid) -> Result<Option<Vec<(String, Value)>>, PushError> {
        Ok(self.nel.particle_state(pid))
    }

    fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        self.nel.restore_particle_state(pid, entries)
    }

    fn stats(&self) -> Result<NelStats, PushError> {
        Ok(self.nel.stats())
    }

    fn counters(&self) -> TransportCounters {
        TransportCounters::default()
    }

    fn nel(&self) -> Option<&Nel> {
        Some(&self.nel)
    }
}

/// Run one direct (handler-less) op on a NEL — the single dispatch point
/// shared by the in-process transport and the node server, so both sides
/// of the wire execute identical code paths.
pub(crate) fn dispatch_direct(nel: &Nel, op: DirectOp) -> PFuture {
    match op {
        DirectOp::Step { pid, x, y, lr } => {
            nel.run_entry(pid, "step", vec![x, y, Tensor::scalar_f32(lr)], Some(1))
        }
        DirectOp::AdamStep { pid, x, y, lr } => nel.run_adam(pid, x, y, lr),
        DirectOp::Forward { pid, x } => nel.run_entry(pid, "fwd", vec![x], None),
        DirectOp::Grad { pid, x, y } => nel.run_entry(pid, "grad", vec![x, y], None),
        DirectOp::Get { pid } => nel.get_params(None, pid),
        DirectOp::Set { pid, t } => nel.set_params(pid, t),
    }
}

// ---- TCP transport: client -----------------------------------------------

enum Pending {
    One(PFuture),
    Many(Vec<PFuture>),
    Stats(mpsc::Sender<Result<NelStats, PushError>>),
    /// A heartbeat probe in flight. The pong refreshes the link's health
    /// from the reader thread; no caller waits on it, and neither
    /// direction touches the data-path frame counters.
    Heartbeat,
}

/// Per-link liveness cells: verdict + time of the last pong (or, before
/// the first probe, the connect time).
struct HealthCells {
    state: AtomicU8,
    last_pong: Mutex<Instant>,
}

impl HealthCells {
    fn fresh() -> HealthCells {
        HealthCells {
            state: AtomicU8::new(LinkHealth::Healthy as u8),
            last_pong: Mutex::new(Instant::now()),
        }
    }

    fn set(&self, h: LinkHealth) {
        self.state.store(h as u8, Ordering::Relaxed);
    }

    fn get(&self) -> LinkHealth {
        match self.state.load(Ordering::Relaxed) {
            0 => LinkHealth::Healthy,
            1 => LinkHealth::Suspect,
            _ => LinkHealth::Dead,
        }
    }

    fn pong(&self) {
        *self.last_pong.lock().unwrap() = Instant::now();
        self.set(LinkHealth::Healthy);
    }
}

/// The write half of a TCP link. Both flavors serialize whole frames
/// under the link's write mutex, so per-sender FIFO order is identical.
enum WriteHalf {
    /// Blocking socket + BufWriter, flushed per frame (threaded reader).
    Buffered(BufWriter<TcpStream>),
    /// Nonblocking socket shared with the reactor's poll set; writes park
    /// in `poll(POLLOUT)` when the kernel buffer is full, bounded by
    /// [`poll::WRITE_STALL_LIMIT`] — a peer that stops draining fails the
    /// send (and the link is severed) instead of parking the sender
    /// forever.
    Evented(TcpStream),
}

impl WriteHalf {
    fn send_frame(&mut self, payload: &[u8]) -> Result<()> {
        match self {
            WriteHalf::Buffered(w) => {
                wire::write_frame(w, payload)?;
                w.flush()?;
                Ok(())
            }
            WriteHalf::Evented(s) => {
                poll::write_frame_nb(s, payload)?;
                Ok(())
            }
        }
    }
}

/// A node reached over TCP. Cloned per fabric; owns the write half of the
/// connection plus a demux for responses — a dedicated reader thread
/// (threaded flavor) or a reactor registration (evented flavor).
pub struct TcpNode {
    stream: TcpStream,
    writer: Mutex<WriteHalf>,
    pending: Arc<Mutex<HashMap<u64, Pending>>>,
    /// Set by the reader thread when the connection dies. Checked around
    /// every pending-map insert: a request registered after the reader
    /// exited would otherwise wait forever on a map nobody drains (TCP
    /// writes to a dead peer can still "succeed").
    closed: Arc<std::sync::atomic::AtomicBool>,
    next_id: AtomicU64,
    counters: Arc<CounterCells>,
    health: Arc<HealthCells>,
    peer: SocketAddr,
    evented: bool,
}

impl TcpNode {
    /// Connect to a node server at `addr` (threaded reference flavor: a
    /// dedicated reader thread demultiplexes responses).
    pub fn connect(addr: SocketAddr) -> Result<TcpNode> {
        TcpNode::connect_via(addr, false)
    }

    /// Connect to a node server at `addr` on the evented flavor: the
    /// response demux runs on the global reactor's poll pool instead of a
    /// dedicated thread, so any number of links cost zero parked threads.
    /// Same wire protocol, counters, fault hooks, and FIFO guarantees.
    pub fn connect_evented(addr: SocketAddr) -> Result<TcpNode> {
        TcpNode::connect_via(addr, true)
    }

    fn connect_via(addr: SocketAddr, evented: bool) -> Result<TcpNode> {
        #[cfg(any(test, feature = "faultinject"))]
        fault::on_connect(addr)?;
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let pending: Arc<Mutex<HashMap<u64, Pending>>> = Arc::new(Mutex::new(HashMap::new()));
        let closed = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let counters = Arc::new(CounterCells::default());
        let health = Arc::new(HealthCells::fresh());
        let writer = if evented {
            // Registration flips the shared fd nonblocking, so the write
            // half must be the poll-assisted one.
            poll::Reactor::global().register(
                stream.try_clone()?,
                Box::new(ClientDemux {
                    pending: pending.clone(),
                    closed: closed.clone(),
                    counters: counters.clone(),
                    health: health.clone(),
                }),
            )?;
            Mutex::new(WriteHalf::Evented(stream.try_clone()?))
        } else {
            let rstream = stream.try_clone()?;
            let pending = pending.clone();
            let closed = closed.clone();
            let counters = counters.clone();
            let health = health.clone();
            std::thread::Builder::new()
                .name(format!("push-tcp-client-{addr}"))
                .spawn(move || reader_loop(rstream, pending, closed, counters, health))?;
            Mutex::new(WriteHalf::Buffered(BufWriter::new(stream.try_clone()?)))
        };
        Ok(TcpNode {
            stream,
            writer,
            pending,
            closed,
            next_id: AtomicU64::new(0),
            counters,
            health,
            peer: addr,
            evented,
        })
    }

    /// [`TcpNode::connect`] with bounded exponential backoff + jitter:
    /// `attempts` tries spread over ~3 s for the default 6, so the launch
    /// order of `push node-worker` processes and the coordinator stops
    /// mattering (the worker may still be binding its port).
    pub fn connect_with_backoff(addr: SocketAddr, attempts: u32) -> Result<TcpNode> {
        TcpNode::backoff_via(addr, attempts, false)
    }

    /// [`TcpNode::connect_evented`] behind the same backoff schedule.
    pub fn connect_evented_with_backoff(addr: SocketAddr, attempts: u32) -> Result<TcpNode> {
        TcpNode::backoff_via(addr, attempts, true)
    }

    fn backoff_via(addr: SocketAddr, attempts: u32, evented: bool) -> Result<TcpNode> {
        let attempts = attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            match TcpNode::connect_via(addr, evented) {
                Ok(node) => return Ok(node),
                Err(e) => {
                    crate::log_debug!(
                        "node {addr}: connect attempt {}/{attempts} failed ({e:#})",
                        attempt + 1
                    );
                    last = Some(e);
                }
            }
            if attempt + 1 < attempts {
                // 100ms * 2^attempt, +-25% deterministic jitter keyed by
                // (port, attempt) — the vendored crate set has no rand
                let base_ms: u64 = 100u64 << attempt.min(8);
                let mut rng = crate::util::rng::Rng::new(0x636f_6e6e ^ addr.port() as u64)
                    .fold_in(attempt as u64);
                let jitter = rng.below((base_ms / 2 + 1) as usize) as u64;
                std::thread::sleep(Duration::from_millis(base_ms - base_ms / 4 + jitter));
            }
        }
        let e = last.expect("at least one attempt");
        Err(anyhow!("node {addr}: unreachable after {attempts} attempts: {e:#}"))
    }

    pub fn peer(&self) -> SocketAddr {
        self.peer
    }

    /// Send one request frame, registering `pending` for its response.
    /// On a write failure the pending entry is removed and the error
    /// returned — the caller owns failing any futures it handed in.
    fn request(&self, req: &Request, pending: Pending) -> Result<u64, PushError> {
        self.request_inner(req, pending, true)
    }

    /// `request` with the data-path frame/byte counting made optional:
    /// heartbeat probes pass `count: false` so the monitor's background
    /// traffic never perturbs frame-accounting invariants (a broadcast is
    /// still exactly one counted frame per destination node).
    fn request_inner(
        &self,
        req: &Request,
        pending: Pending,
        count: bool,
    ) -> Result<u64, PushError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let buf = wire::encode_request(id, req).map_err(PushError::from)?;
        if self.closed.load(Ordering::Acquire) {
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::new(format!("node {}: connection closed", self.peer)));
        }
        #[cfg(any(test, feature = "faultinject"))]
        {
            if count {
                let verdict =
                    fault::on_send(self.peer, self.counters.frames_sent.load(Ordering::Relaxed));
                if let Some(delay) = verdict.delay {
                    std::thread::sleep(delay);
                }
                if verdict.kill {
                    // Sever both halves: the reader thread wakes on EOF
                    // and drains every pending future — exactly the code
                    // path a real mid-run node death takes. Health flips
                    // Dead HERE (not just in the reader's exit path) so
                    // the caller sees the verdict as soon as its request
                    // fails, without racing the reader thread.
                    self.counters.errors.fetch_add(1, Ordering::Relaxed);
                    self.health.set(LinkHealth::Dead);
                    let _ = self.stream.shutdown(std::net::Shutdown::Both);
                    return Err(PushError::new(format!(
                        "node {}: connection closed (fault injected)",
                        self.peer
                    )));
                }
            }
        }
        self.pending.lock().unwrap().insert(id, pending);
        // Re-check AFTER the insert: the reader sets `closed` BEFORE its
        // final drain, so an entry that slipped in after the drain is
        // caught here, and one that slipped in before it is drained.
        if self.closed.load(Ordering::Acquire) {
            self.pending.lock().unwrap().remove(&id);
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::new(format!("node {}: connection closed", self.peer)));
        }
        let sent = self.writer.lock().unwrap().send_frame(&buf);
        if let Err(e) = sent {
            // The frame may be HALF-sent (header landed, payload failed,
            // or a mid-payload stall): the stream is no longer
            // frame-aligned, so the link cannot be reused — the next
            // frame's bytes would be parsed as the tail of this one.
            // Sever both halves: the reader/reactor drain fails every
            // other pending future promptly instead of leaving them to
            // misparse against a corrupt stream. This entry is removed
            // FIRST so the drain doesn't double-count its error.
            self.pending.lock().unwrap().remove(&id);
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
            self.health.set(LinkHealth::Dead);
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            return Err(PushError::new(format!("node {}: {e:#}", self.peer)));
        }
        if count {
            self.counters.frames_sent.fetch_add(1, Ordering::Relaxed);
            self.counters.bytes_sent.fetch_add(buf.len() as u64 + 4, Ordering::Relaxed);
        } else {
            self.counters.heartbeats.fetch_add(1, Ordering::Relaxed);
        }
        Ok(id)
    }

    /// Fire a request whose reply resolves ONE future.
    fn call(&self, req: &Request) -> PFuture {
        let fut = PFuture::new();
        if let Err(e) = self.request(req, Pending::One(fut.clone())) {
            fut.complete(Err(e));
        }
        fut
    }

    /// Blocking call for the synchronous PD surface (create, drain,
    /// state capture/restore).
    fn call_wait(&self, req: &Request) -> Result<Value, PushError> {
        self.call(req).wait()
    }
}

impl Drop for TcpNode {
    fn drop(&mut self) {
        // Politely tell the server to wind down its NEL, then drop the
        // connection: shutdown unblocks our reader thread AND the server's
        // read loop even though both hold socket dups.
        let _ = self.request(&Request::Shutdown, Pending::One(PFuture::new()));
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

fn reader_loop(
    stream: TcpStream,
    pending: Arc<Mutex<HashMap<u64, Pending>>>,
    closed: Arc<std::sync::atomic::AtomicBool>,
    counters: Arc<CounterCells>,
    health: Arc<HealthCells>,
) {
    let _gauge = ThreadGauge::enter();
    let mut r = BufReader::new(stream);
    loop {
        let buf = match wire::read_frame(&mut r) {
            Ok(b) => b,
            Err(_) => break, // EOF or a framing error: connection is done
        };
        if demux_response(&buf, &pending, &counters, &health) == FrameVerdict::Close {
            break;
        }
    }
    sever_link(&pending, &closed, &counters, &health);
}

/// Demultiplex one response frame to its parked future(s). THE client
/// demux — the threaded reader thread and the evented reactor sink both
/// run exactly this, so the two flavors cannot drift.
fn demux_response(
    buf: &[u8],
    pending: &Mutex<HashMap<u64, Pending>>,
    counters: &CounterCells,
    health: &HealthCells,
) -> FrameVerdict {
    let (id, resp) = match wire::decode_response(buf) {
        Ok(x) => x,
        Err(_) => return FrameVerdict::Close,
    };
    let entry = pending.lock().unwrap().remove(&id);
    // Heartbeat pongs stay off the data-path counters, mirroring the
    // uncounted send side.
    if !matches!(entry, Some(Pending::Heartbeat)) {
        counters.frames_received.fetch_add(1, Ordering::Relaxed);
        counters.bytes_received.fetch_add(buf.len() as u64 + 4, Ordering::Relaxed);
    }
    match (entry, resp) {
        (Some(Pending::Heartbeat), _) => health.pong(),
        (Some(Pending::One(fut)), Response::One(res)) => {
            fut.complete(res.map_err(PushError::new));
        }
        (Some(Pending::Many(futs)), Response::Many(results)) => {
            let n = results.len();
            for (fut, res) in futs.iter().zip(results) {
                fut.complete(res.map_err(PushError::new));
            }
            // a short batch (protocol bug) must not strand futures
            for fut in futs.iter().skip(n) {
                fut.complete(Err(PushError::new("short broadcast response")));
            }
        }
        (Some(Pending::Stats(tx)), Response::Stats(stats)) => {
            let _ = tx.send(Ok(*stats));
        }
        (Some(Pending::One(fut)), _) => {
            fut.complete(Err(PushError::new("mismatched response kind")));
        }
        (Some(Pending::Many(futs)), _) => {
            for fut in futs {
                fut.complete(Err(PushError::new("mismatched response kind")));
            }
        }
        (Some(Pending::Stats(tx)), _) => {
            let _ = tx.send(Err(PushError::new("mismatched response kind")));
        }
        (None, _) => {} // response for an abandoned request
    }
    FrameVerdict::Continue
}

/// The connection-closed drain. Flag first, THEN drain: `request`
/// re-checks the flag after its insert, so every pending entry is either
/// drained here or rejected there — nothing can wait on an unwatched map.
fn sever_link(
    pending: &Mutex<HashMap<u64, Pending>>,
    closed: &std::sync::atomic::AtomicBool,
    counters: &CounterCells,
    health: &HealthCells,
) {
    closed.store(true, Ordering::Release);
    health.set(LinkHealth::Dead);
    let drained: Vec<Pending> = pending.lock().unwrap().drain().map(|(_, p)| p).collect();
    for p in drained {
        let err = PushError::new("node connection closed");
        match p {
            Pending::One(fut) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                fut.complete(Err(err));
            }
            Pending::Many(futs) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                for fut in futs {
                    fut.complete(Err(err.clone()));
                }
            }
            Pending::Stats(tx) => {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(Err(err));
            }
            Pending::Heartbeat => {}
        }
    }
}

/// The evented client's read side: the reactor hands it frames, it runs
/// the shared [`demux_response`] / [`sever_link`] pair.
struct ClientDemux {
    pending: Arc<Mutex<HashMap<u64, Pending>>>,
    closed: Arc<std::sync::atomic::AtomicBool>,
    counters: Arc<CounterCells>,
    health: Arc<HealthCells>,
}

impl Sink for ClientDemux {
    fn on_frame(&mut self, frame: Vec<u8>) -> FrameVerdict {
        demux_response(&frame, &self.pending, &self.counters, &self.health)
    }

    fn on_close(&mut self) {
        sever_link(&self.pending, &self.closed, &self.counters, &self.health);
    }
}

impl NodeTransport for TcpNode {
    fn kind(&self) -> &'static str {
        if self.evented {
            "tcp-evented"
        } else {
            "tcp"
        }
    }

    fn create_local(&self, _opts: CreateOpts) -> Result<Pid, PushError> {
        Err(PushError::new(format!(
            "node {}: handler closures cannot cross the wire — create remote particles \
             from a registered handler program (CreateSpec)",
            self.peer
        )))
    }

    fn create_spec(&self, spec: CreateSpec) -> Result<Pid, PushError> {
        match self.call_wait(&Request::Create(spec))? {
            Value::Usize(pid) => decode_wire_pid(pid),
            other => Err(PushError::new(format!("create returned {other:?}"))),
        }
    }

    fn send(&self, pid: Pid, msg: &str, args: Vec<Value>) -> PFuture {
        self.call(&Request::Send { pid, msg: msg.to_string(), args })
    }

    fn broadcast(&self, pids: &[Pid], msg: &str, args: Vec<Value>) -> Vec<PFuture> {
        let futs: Vec<PFuture> = pids.iter().map(|_| PFuture::new()).collect();
        if pids.is_empty() {
            return futs;
        }
        let req = Request::Broadcast {
            pids: pids.to_vec(),
            msg: msg.to_string(),
            args,
        };
        if let Err(e) = self.request(&req, Pending::Many(futs.clone())) {
            for fut in &futs {
                fut.complete(Err(e.clone()));
            }
        }
        futs
    }

    fn direct(&self, op: DirectOp) -> PFuture {
        self.call(&Request::Direct(op))
    }

    fn drain_params(&self) -> Result<Vec<(Pid, Tensor)>, PushError> {
        let v = self.call_wait(&Request::DrainParams)?;
        let items = v.list()?;
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            let mut pair = item.list()?;
            if pair.len() != 2 {
                return Err(PushError::new("malformed drain_params pair"));
            }
            let t = pair.remove(1).tensor()?;
            let pid = pair[0].usize()?;
            out.push((decode_wire_pid(pid)?, t));
        }
        Ok(out)
    }

    fn particle_state(&self, pid: Pid) -> Result<Option<Vec<(String, Value)>>, PushError> {
        decode_state_value(self.call_wait(&Request::ParticleState { pid })?)
    }

    fn restore_particle_state(
        &self,
        pid: Pid,
        entries: Vec<(String, Value)>,
    ) -> Result<(), PushError> {
        self.call_wait(&Request::RestoreState { pid, entries }).map(|_| ())
    }

    fn stats(&self) -> Result<NelStats, PushError> {
        let (tx, rx) = mpsc::channel();
        self.request(&Request::Stats, Pending::Stats(tx))?;
        rx.recv()
            .map_err(|_| PushError::new("node connection closed during stats"))?
    }

    fn counters(&self) -> TransportCounters {
        self.counters.snapshot()
    }

    fn heartbeat_tick(&self, dead_after: Duration) -> LinkHealth {
        if self.closed.load(Ordering::Acquire) {
            self.health.set(LinkHealth::Dead);
            return LinkHealth::Dead;
        }
        let silent = self.health.last_pong.lock().unwrap().elapsed();
        if silent > dead_after {
            // Declare the link dead and sever it: the shutdown wakes the
            // reader thread, whose exit path fails every pending future
            // promptly — `wait()` never hangs on a dead node.
            self.health.set(LinkHealth::Dead);
            self.counters.errors.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!(
                "node {}: silent for {:.0?} (> dead_after {:.0?}); declaring link dead",
                self.peer,
                silent,
                dead_after
            );
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            return LinkHealth::Dead;
        }
        let verdict = if silent > dead_after / 2 {
            LinkHealth::Suspect
        } else {
            LinkHealth::Healthy
        };
        self.health.set(verdict);
        // Fire one probe; the pong refreshes `last_pong` from the reader
        // thread. Probe failures surface as `closed` on the next tick.
        let nonce = self.counters.heartbeats.load(Ordering::Relaxed);
        let _ = self.request_inner(&Request::Heartbeat { nonce }, Pending::Heartbeat, false);
        verdict
    }

    fn health(&self) -> LinkHealth {
        if self.closed.load(Ordering::Acquire) {
            return LinkHealth::Dead;
        }
        self.health.get()
    }

    fn peer_addr(&self) -> Option<SocketAddr> {
        Some(self.peer)
    }

    fn migrate(&self, specs: Vec<CreateSpec>) -> Result<(), PushError> {
        if specs.is_empty() {
            return Ok(());
        }
        let futs: Vec<PFuture> = specs.iter().map(|_| PFuture::new()).collect();
        let n = specs.len();
        self.request(&Request::Migrate { specs }, Pending::Many(futs.clone()))?;
        for (i, fut) in futs.into_iter().enumerate() {
            fut.wait().map_err(|e| {
                PushError::new(format!(
                    "migrating particle {}/{n} to node {}: {}",
                    i + 1,
                    self.peer,
                    e.msg
                ))
            })?;
        }
        Ok(())
    }

    fn snapshot_node(&self, pids: &[Pid]) -> Vec<PFuture> {
        let futs: Vec<PFuture> = pids.iter().map(|_| PFuture::new()).collect();
        if pids.is_empty() {
            return futs;
        }
        let req = Request::SnapshotNode { pids: pids.to_vec() };
        if let Err(e) = self.request(&req, Pending::Many(futs.clone())) {
            for fut in &futs {
                fut.complete(Err(e.clone()));
            }
        }
        futs
    }
}

// ---- TCP transport: server -----------------------------------------------

/// Bind 127.0.0.1 on an ephemeral port and serve ONE connection on a
/// background thread (the hermetic loopback-node shape used by tests,
/// benches, and `push train --transport tcp`). Returns the address to
/// connect to.
pub fn spawn_loopback_node(
    cfg: NelConfig,
    model: Arc<ModelSpec>,
) -> Result<(SocketAddr, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let handle = std::thread::Builder::new()
        .name(format!("push-node-{addr}"))
        .spawn(move || {
            let _gauge = ThreadGauge::enter();
            let _ = serve_one(&listener, cfg, model);
        })?;
    Ok((addr, handle))
}

/// Accept one connection and serve it to completion. The standalone
/// `push node-worker` subcommand's `--once` mode uses this; its default
/// is the evented accept loop ([`serve_evented`]).
pub fn serve_one(listener: &TcpListener, cfg: NelConfig, model: Arc<ModelSpec>) -> Result<()> {
    let (stream, _peer) = listener.accept()?;
    serve_connection(stream, cfg, model)
}

/// Where a node server writes completed responses: the threaded flavor's
/// FIFO writer thread, or an evented connection's outbox — frames are
/// QUEUED (never written inline) and the connection's owning shard
/// flushes them under `POLLOUT` readiness. Queuing is what makes
/// responding safe from ANY thread, shard threads included: an inline
/// write parked in `poll(POLLOUT)` on the shard that also owns the
/// destination peer's read side (the loopback `push serve` shape, where
/// both halves round-robin onto one global reactor) would deadlock the
/// shard — the response can only drain once the peer reads, and the peer
/// is only read by the parked shard. Both responders are FIFO: whole
/// frames enqueue atomically in completion order.
#[derive(Clone)]
enum Responder {
    Thread(mpsc::Sender<Vec<u8>>),
    Evented(poll::WriteHandle),
}

impl Responder {
    fn send(&self, payload: Vec<u8>) {
        match self {
            Responder::Thread(tx) => {
                let _ = tx.send(payload);
            }
            Responder::Evented(handle) => {
                // An error means the connection is already dead/closing;
                // the client's matching futures fail through its
                // closed-link drain, exactly like a response the writer
                // thread never got to deliver.
                let _ = handle.send_frame(&payload);
            }
        }
    }
}

/// What the read side does after dispatching one request.
enum Dispatch {
    Continue,
    /// The client asked the node to wind down.
    Shutdown,
}

/// Dispatch one decoded request against this connection's NEL — THE
/// request path, shared by the threaded per-connection server and the
/// evented accept loop so the two flavors cannot drift. Never blocks on
/// handler completion: `Send`/`Broadcast`/`Direct` respond from
/// `on_ready` continuations.
fn dispatch_request(
    nel: &Nel,
    model: &Arc<ModelSpec>,
    out: &Responder,
    id: u64,
    req: Request,
) -> Dispatch {
    match req {
        Request::Shutdown => {
            respond(out, id, Response::One(Ok(Value::Unit)));
            return Dispatch::Shutdown;
        }
        Request::Create(spec) => {
            let res = create_from_spec(nel, model, spec);
            respond(out, id, Response::One(res));
        }
        Request::Send { pid, msg, args } => {
            complete_async(out, id, nel.send(None, pid, &msg, args));
        }
        Request::Broadcast { pids, msg, args } => {
            let futs = nel.broadcast(None, &pids, &msg, args);
            respond_batch(out, id, &futs);
        }
        Request::Direct(op) => {
            complete_async(out, id, dispatch_direct(nel, op));
        }
        Request::DrainParams => {
            let res = nel.drain_params().map(|params| {
                Value::List(
                    params
                        .into_iter()
                        .map(|(pid, t)| {
                            Value::List(vec![Value::Usize(pid.0 as usize), Value::Tensor(t)])
                        })
                        .collect(),
                )
            });
            respond(out, id, Response::One(res.map_err(|e| e.msg)));
        }
        Request::ParticleState { pid } => {
            let res = encode_state_value(nel.particle_state(pid));
            respond(out, id, Response::One(Ok(res)));
        }
        Request::RestoreState { pid, entries } => {
            let res = nel
                .restore_particle_state(pid, entries)
                .map(|_| Value::Unit)
                .map_err(|e| e.msg);
            respond(out, id, Response::One(res));
        }
        Request::Stats => {
            let msg = Response::Stats(Box::new(nel.stats()));
            respond_raw(out, id, &msg);
        }
        Request::Heartbeat { nonce } => {
            // Echo the nonce straight from the read side: a loaded node
            // still pongs promptly (liveness, not readiness).
            respond(out, id, Response::One(Ok(Value::Usize(nonce as usize))));
        }
        Request::Migrate { specs } => {
            let results: Vec<Result<Value, String>> = specs
                .into_iter()
                .map(|spec| create_from_spec(nel, model, spec))
                .collect();
            respond(out, id, Response::Many(results));
        }
        Request::SnapshotNode { pids } => {
            // Answered straight from the read side: `particle_state` is
            // one map clone per pid (atomic wrt any state commit, so
            // reservoir versions are never torn), and the batch goes back
            // as ONE `Response::Many` in input order.
            let results: Vec<Result<Value, String>> = pids
                .into_iter()
                .map(|pid| Ok(encode_state_value(nel.particle_state(pid))))
                .collect();
            respond(out, id, Response::Many(results));
        }
    }
    Dispatch::Continue
}

/// The per-connection node server (threaded reference flavor): one fresh
/// NEL (this node's scheduler + devices), a read loop that never blocks
/// on handler completion, and a writer thread draining completed
/// responses FIFO.
pub fn serve_connection(stream: TcpStream, cfg: NelConfig, model: Arc<ModelSpec>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let nel = Nel::new(cfg)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    let writer = std::thread::Builder::new()
        .name("push-node-writer".to_string())
        .spawn(move || {
            let _gauge = ThreadGauge::enter();
            let mut w = BufWriter::new(stream);
            while let Ok(buf) = rx.recv() {
                if wire::write_frame(&mut w, &buf).is_err() || w.flush().is_err() {
                    // A dead write half must kill the WHOLE connection:
                    // otherwise the read loop keeps accepting requests
                    // whose responses can never be delivered, and the
                    // client's matching futures hang instead of failing
                    // through its reader's connection-closed drain.
                    let _ = w.get_ref().shutdown(std::net::Shutdown::Both);
                    break;
                }
            }
        })?;
    let out = Responder::Thread(tx);

    loop {
        let buf = match wire::read_frame(&mut reader) {
            Ok(b) => b,
            Err(_) => break, // client hung up
        };
        let (id, req) = match wire::decode_request(&buf) {
            Ok(x) => x,
            // Undecodable frame: we cannot even know the req_id, so the
            // connection is unrecoverable. Drop it.
            Err(_) => break,
        };
        if matches!(dispatch_request(&nel, &model, &out, id, req), Dispatch::Shutdown) {
            break;
        }
    }
    drop(out); // writer drains queued responses, then exits
    drop(nel); // fail any undelivered envelopes, wind the node down
    let _ = writer.join();
    Ok(())
}

/// One accepted connection on the evented node server. The NEL is
/// created LAZILY on the first data frame, so an idle connection (a
/// serving-tier client parked between refreshes) costs one registered fd
/// and nothing else — no NEL, no scheduler, no device threads, no parked
/// reader/writer pair.
///
/// The shard thread only ENQUEUES frames here; decoding and dispatch run
/// on [`poll::offload`] workers. Synchronous request work — `Nel::new`
/// on the first frame, `SnapshotNode`/`Migrate` batches — can take
/// longer than a fabric `dead_after` (hundreds of ms), and a shard stuck
/// in it would starve heartbeat pongs for EVERY other connection on that
/// shard, making the monitor falsely sever healthy links. Heartbeats
/// themselves are the one exception: they are answered straight from the
/// shard ([`wire::request_is_heartbeat`]), both because a liveness probe
/// must not queue behind data work and because that keeps pong latency
/// load-independent, matching the threaded read loop's behavior.
struct ServerConn {
    shared: Arc<ConnShared>,
}

/// State shared between a [`ServerConn`]'s shard-side sink and the
/// offload jobs draining its dispatch queue.
struct ConnShared {
    cfg: NelConfig,
    model: Arc<ModelSpec>,
    /// Created lazily by the FIRST offload drain that sees a data frame;
    /// torn down by the LAST drain after close (never on the shard —
    /// `Nel` teardown joins scheduler/device threads and may block).
    nel: Mutex<Option<Nel>>,
    out: Responder,
    handle: poll::WriteHandle,
    work: Mutex<ConnWork>,
}

/// The connection's dispatch queue. At most ONE offload drain job is in
/// flight per connection (`scheduled`), and that job pops frames in
/// arrival order — per-sender FIFO dispatch, exactly the threaded read
/// loop's order, while still letting different connections' queues drain
/// concurrently on the pool.
struct ConnWork {
    frames: VecDeque<Vec<u8>>,
    scheduled: bool,
    closed: bool,
}

impl Sink for ServerConn {
    fn on_frame(&mut self, frame: Vec<u8>) -> FrameVerdict {
        if wire::request_is_heartbeat(&frame) {
            // Pong inline: req_id-matched, touches no NEL state, so
            // jumping the dispatch queue cannot reorder anything a
            // client can observe (heartbeats resolve their own Pending
            // slot, never a data future).
            if let Ok((id, Request::Heartbeat { nonce })) = wire::decode_request(&frame) {
                respond(&self.shared.out, id, Response::One(Ok(Value::Usize(nonce as usize))));
                return FrameVerdict::Continue;
            }
            // Peek matched but full decode failed: corrupt frame.
            return FrameVerdict::Close;
        }
        let mut work = self.shared.work.lock().unwrap();
        if work.closed {
            return FrameVerdict::Continue; // draining toward close
        }
        work.frames.push_back(frame);
        if !work.scheduled {
            work.scheduled = true;
            let shared = self.shared.clone();
            poll::offload(Box::new(move || drain_conn(shared)));
        }
        FrameVerdict::Continue
    }

    fn on_close(&mut self) {
        let mut work = self.shared.work.lock().unwrap();
        work.closed = true;
        work.frames.clear();
        if !work.scheduled {
            // No drain in flight to observe `closed`: schedule one purely
            // for teardown, so the NEL is dropped on the pool, not here.
            work.scheduled = true;
            let shared = self.shared.clone();
            poll::offload(Box::new(move || drain_conn(shared)));
        }
    }
}

/// Drain one connection's dispatch queue on an offload worker until it
/// is empty (or the connection closed), then clear `scheduled` so the
/// next frame schedules a fresh drain. Exactly one drain runs per
/// connection at a time.
fn drain_conn(shared: Arc<ConnShared>) {
    loop {
        let frame = {
            let mut work = shared.work.lock().unwrap();
            match work.frames.pop_front() {
                Some(f) if !work.closed => f,
                _ => {
                    let closed = work.closed;
                    work.frames.clear();
                    work.scheduled = false;
                    drop(work);
                    if closed {
                        // Fail any undelivered envelopes, wind the node
                        // down. Off-shard on purpose: Nel teardown joins
                        // its scheduler/device threads.
                        let _ = shared.nel.lock().unwrap().take();
                    }
                    return;
                }
            }
        };
        if process_frame(&shared, &frame) == FrameVerdict::Close {
            shared.work.lock().unwrap().closed = true;
            // Queued responses (the Shutdown ack, a NEL-startup error)
            // still reach the peer before the fd drops.
            shared.handle.close_after_flush();
        }
    }
}

/// Decode and dispatch one queued request frame (offload worker).
fn process_frame(shared: &ConnShared, frame: &[u8]) -> FrameVerdict {
    let (id, req) = match wire::decode_request(frame) {
        Ok(x) => x,
        Err(_) => return FrameVerdict::Close, // unrecoverable framing
    };
    let mut nel = shared.nel.lock().unwrap();
    if nel.is_none() {
        // A link winding down without ever doing work (the idle-bench
        // shape) must not build a NEL just to tear it down.
        if matches!(req, Request::Shutdown) {
            respond(&shared.out, id, Response::One(Ok(Value::Unit)));
            return FrameVerdict::Close;
        }
        match Nel::new(shared.cfg.clone()) {
            Ok(n) => *nel = Some(n),
            Err(e) => {
                respond(
                    &shared.out,
                    id,
                    Response::One(Err(format!("node: NEL startup failed: {e:#}"))),
                );
                return FrameVerdict::Close;
            }
        }
    }
    let nel = nel.as_ref().expect("lazily created above");
    match dispatch_request(nel, &shared.model, &shared.out, id, req) {
        Dispatch::Shutdown => FrameVerdict::Close,
        Dispatch::Continue => FrameVerdict::Continue,
    }
}

/// Register `listener` on the global reactor as an evented accept loop:
/// every accepted connection is multiplexed onto the fixed poll pool, so
/// ONE node holds any number of concurrent client connections without a
/// thread per connection (`listener.accept()` was called exactly once on
/// the threaded path). The listener stays registered for the life of the
/// process; each connection's NEL lives only while that connection does.
pub fn serve_evented(
    listener: TcpListener,
    cfg: NelConfig,
    model: Arc<ModelSpec>,
) -> Result<SocketAddr> {
    let addr = listener.local_addr()?;
    poll::Reactor::global().register_listener(
        listener,
        Box::new(move |stream| {
            stream.set_nodelay(true).ok();
            let cfg = cfg.clone();
            let model = model.clone();
            // Responses go through the connection's outbox handle: the
            // shard flushes them under POLLOUT, so completing a future
            // (from any thread, shards included) never blocks.
            let _ = poll::Reactor::global().register_duplex(stream, move |handle| {
                Box::new(ServerConn {
                    shared: Arc::new(ConnShared {
                        cfg,
                        model,
                        nel: Mutex::new(None),
                        out: Responder::Evented(handle.clone()),
                        handle,
                        work: Mutex::new(ConnWork {
                            frames: VecDeque::new(),
                            scheduled: false,
                            closed: false,
                        }),
                    }),
                })
            });
        }),
    )?;
    Ok(addr)
}

/// Evented sibling of [`spawn_loopback_node`]: bind an ephemeral
/// loopback port and serve any number of concurrent connections off the
/// reactor. Spawns no thread at all.
pub fn spawn_loopback_node_evented(cfg: NelConfig, model: Arc<ModelSpec>) -> Result<SocketAddr> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    serve_evented(listener, cfg, model)
}

/// The model handshake: the client's fabric stamps every CreateSpec with
/// the model name it is training; a node serving a different model (a
/// mis-pointed `push node-worker`) must reject at creation, not surface
/// as a shape error deep inside the NEL.
fn check_model(spec: &CreateSpec, model: &ModelSpec) -> Result<(), PushError> {
    if spec.model != model.name {
        return Err(PushError::new(format!(
            "model mismatch: client is training {:?} but this node serves {:?}",
            spec.model, model.name
        )));
    }
    Ok(())
}

fn create_from_spec(
    nel: &Nel,
    model: &Arc<ModelSpec>,
    spec: CreateSpec,
) -> Result<Value, String> {
    check_model(&spec, model).map_err(|e| e.msg)?;
    let receive = match &spec.program {
        Some((name, cfg)) => {
            programs::build_handlers(name, cfg, model).map_err(|e| e.msg)?
        }
        None => HandlerTable::new(),
    };
    let pid = nel
        .p_create(
            model.clone(),
            CreateOpts {
                pid: Some(spec.pid),
                device: spec.device,
                receive,
                state: spec.state,
                no_params: spec.no_params,
                init_params: spec.init_params,
            },
        )
        .map_err(|e| format!("{e:#}"))?;
    Ok(Value::Usize(pid.0 as usize))
}

fn respond(out: &Responder, id: u64, resp: Response) {
    respond_raw(out, id, &resp);
}

fn respond_raw(out: &Responder, id: u64, resp: &Response) {
    // An unencodable response (e.g. a Value nested past MAX_DEPTH) must
    // still answer the request — as an error — or the client's future for
    // this req_id would wait until the connection dies.
    let buf = wire::encode_response(id, resp).or_else(|e| {
        wire::encode_response(
            id,
            &Response::One(Err(format!("node: response encoding failed: {e:#}"))),
        )
    });
    if let Ok(buf) = buf {
        out.send(buf);
    }
}

/// Answer `id` with `fut`'s result once it resolves — from the
/// completer's thread, never blocking the read side.
fn complete_async(out: &Responder, id: u64, fut: PFuture) {
    let out = out.clone();
    fut.on_ready(move |r| {
        let res = r.clone().map_err(|e| e.msg);
        respond_raw(&out, id, &Response::One(res));
    });
}

/// Aggregate a broadcast's futures into ONE `Response::Many` preserving
/// per-position results (errors included), sent when the last future
/// resolves. This is join_all's countdown shape, but keeping EVERY
/// result instead of collapsing to the first error — the collapse
/// happens client-side so cross-node batches and in-process batches
/// agree on error ordering.
type BatchSlots = Arc<Mutex<Vec<Option<Result<Value, String>>>>>;

fn respond_batch(out: &Responder, id: u64, futs: &[PFuture]) {
    let n = futs.len();
    if n == 0 {
        respond(out, id, Response::Many(Vec::new()));
        return;
    }
    let slots: BatchSlots = Arc::new(Mutex::new(vec![None; n]));
    let remaining = Arc::new(AtomicUsize::new(n));
    for (i, fut) in futs.iter().enumerate() {
        let slots = slots.clone();
        let remaining = remaining.clone();
        let out = out.clone();
        fut.on_ready(move |r| {
            slots.lock().unwrap()[i] = Some(r.clone().map_err(|e| e.msg));
            if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let resolved = std::mem::take(&mut *slots.lock().unwrap());
                let results: Vec<Result<Value, String>> =
                    resolved.into_iter().map(|s| s.expect("all resolved")).collect();
                respond_raw(&out, id, &Response::Many(results));
            }
        });
    }
}

// ---- fault injection ------------------------------------------------------

/// Deterministic fault injection for the wire transport, compiled in only
/// for tests and the `faultinject` feature. Plans are keyed by the peer
/// address a `TcpNode` connects to, so a test can kill PRECISELY one link
/// at a precisely chosen frame — no sleeps, no signal races:
///
/// * `drop_after_frames: Some(n)` severs the connection when the link has
///   already sent `n` data frames (0 = kill on the next send);
/// * `delay` sleeps before every data frame (slow-link simulation);
/// * `refuse_connects` fails that many `connect` attempts first
///   (exercising the startup backoff deterministically).
#[cfg(any(test, feature = "faultinject"))]
pub mod fault {
    use std::collections::HashMap;
    use std::net::SocketAddr;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    #[derive(Debug, Clone, Default)]
    pub struct FaultPlan {
        /// Sever the connection once this many data frames have been sent
        /// on the link (heartbeat probes don't count).
        pub drop_after_frames: Option<u64>,
        /// Sleep this long before every data-frame write.
        pub delay: Option<Duration>,
        /// Fail this many connection attempts with ECONNREFUSED first.
        pub refuse_connects: u32,
    }

    static PLANS: OnceLock<Mutex<HashMap<SocketAddr, FaultPlan>>> = OnceLock::new();

    fn plans() -> &'static Mutex<HashMap<SocketAddr, FaultPlan>> {
        PLANS.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Install `plan` for every future connect/send touching `addr`.
    pub fn set_plan(addr: SocketAddr, plan: FaultPlan) {
        plans().lock().unwrap().insert(addr, plan);
    }

    /// Remove the plan for `addr` (tests clean up after themselves;
    /// loopback ports are ephemeral, so plans never collide anyway).
    pub fn clear(addr: SocketAddr) {
        plans().lock().unwrap().remove(&addr);
    }

    pub(super) fn on_connect(addr: SocketAddr) -> std::io::Result<()> {
        if let Some(plan) = plans().lock().unwrap().get_mut(&addr) {
            if plan.refuse_connects > 0 {
                plan.refuse_connects -= 1;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionRefused,
                    format!("fault injection: connection to {addr} refused"),
                ));
            }
        }
        Ok(())
    }

    #[derive(Debug, Default)]
    pub(super) struct SendVerdict {
        pub delay: Option<Duration>,
        pub kill: bool,
    }

    /// Consulted with the link's data-frame count BEFORE this write.
    pub(super) fn on_send(addr: SocketAddr, frames_sent: u64) -> SendVerdict {
        match plans().lock().unwrap().get(&addr) {
            None => SendVerdict::default(),
            Some(plan) => SendVerdict {
                delay: plan.delay,
                kill: plan.drop_after_frames.map(|n| frames_sent >= n).unwrap_or(false),
            },
        }
    }
}

// ---- loopback convenience -------------------------------------------------

/// Spawn a loopback node server and connect to it: the one-call way to
/// stand up a real-socket node inside this process.
pub fn loopback_node(cfg: NelConfig, model: Arc<ModelSpec>) -> Result<TcpNode> {
    let (addr, _handle) = spawn_loopback_node(cfg, model)?;
    TcpNode::connect(addr).map_err(|e| anyhow!("connecting to loopback node {addr}: {e:#}"))
}

/// [`loopback_node`] with both halves on the evented flavor: an
/// accept-loop server off the reactor plus an evented client link.
pub fn loopback_node_evented(cfg: NelConfig, model: Arc<ModelSpec>) -> Result<TcpNode> {
    let addr = spawn_loopback_node_evented(cfg, model)?;
    TcpNode::connect_evented(addr)
        .map_err(|e| anyhow!("connecting to evented loopback node {addr}: {e:#}"))
}
