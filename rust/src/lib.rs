//! # Push — concurrent probabilistic programming for Bayesian deep learning
//!
//! A from-scratch reproduction of *"Push: Concurrent Probabilistic
//! Programming for Bayesian Deep Learning"* (Huang, Camaño, Tsegaye, Gale;
//! 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: the particle
//!   abstraction ([`particle`]), the node event loop with particle-to-device
//!   mapping and context-switching dispatch ([`nel`], [`device`]), the Push
//!   distribution ([`pd`]), and the BDL inference algorithms written
//!   against them ([`infer`]): deep ensembles, SWAG, multi-SWAG, SVGD.
//! * **L2 (python/compile, build-time only)** — every model as a JAX
//!   function over a flat parameter vector, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels, build-time only)** — Pallas kernels for
//!   the compute hotspots (SVGD kernel-matrix update, fused linear+GELU),
//!   lowered inside the L2 graphs.
//!
//! At run time the [`runtime`] module loads `artifacts/*.hlo.txt` through
//! PJRT (`--features pjrt`; the default build substitutes a hermetic stub)
//! and Python is never on the path. See DESIGN.md for the coordinator's
//! zero-copy/single-authority invariants and EXPERIMENTS.md for measured
//! results.

#[macro_use]
pub mod util;

pub mod baselines;
pub mod bench;
pub mod data;
pub mod device;
pub mod infer;
pub mod nel;
pub mod particle;
pub mod pd;
pub mod runtime;

pub use nel::{CreateOpts, Nel, NelConfig, ParticleCtx};
pub use particle::{handler, PFuture, Pid, PushError, Value};
pub use pd::PushDist;
pub use runtime::{Manifest, Tensor};
