//! `push` — the leader entrypoint / CLI launcher.
//!
//! ```text
//! push info                          manifest + runtime summary
//! push train  --model M --method A   train one configuration
//! push serve                         train WHILE serving posterior queries
//! push bench  fig4|fig7|table1|table2|table3|table4|native-acc|stress
//! push trace                         two-particle Figure-3b timeline
//! ```
//!
//! Every `bench` subcommand regenerates one of the paper's tables/figures
//! (scaled per DESIGN.md §Hardware-Adaptation) and writes JSON under
//! `bench_results/`.
//!
//! There is also a hidden `push node-worker` subcommand: the standalone
//! node server of the distributed NEL (DESIGN.md §Distributed NEL) that
//! `push train --transport tcp` connects to via $PUSH_NODES. Without
//! $PUSH_NODES, `--transport tcp` spawns hermetic loopback node servers
//! in-process (real sockets on 127.0.0.1 ephemeral ports).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use push::bench::report::results_dir;
use push::bench::scaling::ScaleOpts;
use push::bench::{accuracy, depth_width, scaling, Method};
use push::data::{DataLoader, PrefetchLoader};
use push::device::CostModel;
use push::infer::{
    eval, DeepEnsemble, Infer, MultiSwag, PosteriorServer, Schedule, SgMcmc, SgmcmcAlgo,
    SgmcmcConfig, Svgd, SvgdConfig, SwagConfig,
};
use push::nel::CreateOpts;
use push::particle::{handler, Value};
use push::pd::{FabricConfig, Topology, TransportKind};
use push::runtime::{artifacts_dir, Manifest};
use push::util::flags::Flags;
use push::{NelConfig, PushDist, Tensor};

const USAGE: &str = "\
push — concurrent probabilistic programming for Bayesian deep learning

USAGE:
  push info
  push train --model <name> [--algo ensemble|multi_swag|svgd|sgld|sghmc]
             [--particles N] [--devices D] [--epochs E] [--batches B]
             [--lr F] [--cache N] [--seed N] [--workers N]
             [--kernel-threads N]    (math kernel shards; 0 = auto,
                                      env PUSH_KERNEL_THREADS)
             [--nodes N] [--transport inproc|tcp]
             [--heartbeat-every MS] [--dead-after MS] [--recover N]
             [--temp T] [--friction A] [--burn-in N] [--thin N]
             [--samples N] [--serve-every N]    (sgld/sghmc chain options;
                                                 --method is an alias of --algo)
  push serve [--algo sgld|sghmc] [--particles N] [--devices D] [--epochs E]
             [--batches B] [--clients C] [--serve-every N]
             [--deadline-ms MS] [--retries N] [--max-inflight N]
             [--nodes N] [--transport inproc|tcp]
             [--heartbeat-every MS] [--dead-after MS] [... chain options]
  push bench <fig4|fig7|table1|table2|table3|table4|native-acc|stress|ablate>
             [--devices 1,2,4] [--particles 1,2,4,8] [--batches B]
             [--epochs E] [--no-baseline] [--full] [--cache N] [--seed N]
             [--models a,b,c] [--algo <method>]   (figures/tables only)
  push trace [--model <name>]

Native models: linear_native, mlp_native, conv1d_native, and
linear_spiral_native are built in — closed-form grad/forward closures,
no artifacts, no PJRT — and train under every --algo, checkpoint,
migrate, and serve exactly like artifact models. `push bench native-acc`
runs the hermetic model x algorithm accuracy matrix the CI accuracy gate
checks. --models swaps a figure/table's model list (an all-native list
needs no artifacts); --algo picks the depth/width tables' method
(default multi_swag).

Serving: --serve-every N refreshes a PosteriorServer snapshot every N
epochs during `push train` (sgld/sghmc on a native model) and answers a
posterior-predictive probe from it. `push serve` is the full demo: it
trains a hermetic native model through a prefetching loader
while --clients C threads hammer predict_mean concurrently — queries are
answered from versioned reservoir snapshots and never pause training.

Distributed NEL: --nodes N splits particles across N nodes (each with its
own NEL, scheduler, and --devices devices). --transport tcp runs every
node behind a real socket — hermetic 127.0.0.1 loopback servers, or the
addresses in $PUSH_NODES (host:port,host:port — launched via the node
worker). sgld/sghmc span nodes; native models train their closed-form
grad/forward on every node with no artifacts at all.

Serving under failure: a refresh is ONE batched SnapshotNode frame per
node, bounded by --deadline-ms (0 = wait for the transport) and retried
--retries times against surviving links. A node death mid-traffic
degrades the snapshot to the surviving chains (staleness is reported per
refresh and in the final stats) instead of failing the tier; a refresh
after recovery heals back to complete. --max-inflight N sheds queries
beyond N in flight with a typed Overloaded error (0 = admit everything).

Elastic fabric: --heartbeat-every MS pings every node link on that
cadence and declares a link dead after --dead-after MS of silence
(default 4x the cadence), failing its pending futures instead of
hanging. --recover N arms sgld/sghmc with a bounded checkpoint-and-retry
budget: up to N rounds survive a node death by migrating the dead
node's chains onto survivors (original pids — the replayed run is
bit-identical to an uninterrupted one); an exhausted budget fails
loudly naming the dead node.

Artifacts are read from $PUSH_ARTIFACTS or <repo>/artifacts (make artifacts).
Bench JSON is written to $PUSH_BENCH_DIR or <repo>/bench_results.
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let flags = Flags::from_env().map_err(anyhow::Error::msg)?;
    let cmd = flags.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(),
        "train" => train(&flags),
        "serve" => serve(&flags),
        "bench" => bench(&flags),
        "trace" => trace(&flags),
        // hidden: the standalone distributed-NEL node server
        "node-worker" => node_worker(&flags),
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

/// Registered native models (linear/MLP/conv — `infer::models`) are fully
/// hermetic: closed-form grad/forward closures over a flat weight vector,
/// no artifacts, no PJRT. Their manifest is built in-process; everything
/// else reads the AOT artifact manifest from disk.
fn load_manifest(model_name: &str) -> Result<Manifest> {
    if push::infer::native_model(model_name).is_some() {
        Ok(push::infer::native_manifest())
    } else {
        Manifest::load(artifacts_dir())
    }
}

/// Classify tasks probe posterior-predictive accuracy, regression MSE.
fn probe_metric(pred: &Tensor, y: &Tensor, classify: bool) -> String {
    if classify {
        format!("probe acc {:.1}%", 100.0 * eval::batch_accuracy(pred, y))
    } else {
        format!("probe mse {:.4}", eval::batch_mse(pred, y))
    }
}

fn parse_topology(flags: &Flags) -> Result<Topology> {
    let nodes = flags.usize_or("nodes", 1).map_err(anyhow::Error::msg)?;
    if nodes == 0 {
        bail!("--nodes must be >= 1");
    }
    let transport = match flags.str_or("transport", "inproc").as_str() {
        "inproc" => TransportKind::InProc,
        // With $PUSH_NODES set, connect to external node workers; else
        // spawn hermetic loopback nodes. "tcp" is the threaded reference
        // transport, "tcp-evented" multiplexes every link onto the
        // reactor's fixed poll pool (same wire protocol).
        "tcp" => match parse_push_nodes()? {
            Some(addrs) => TransportKind::TcpConnect(addrs),
            None => TransportKind::TcpLoopback,
        },
        "tcp-evented" => match parse_push_nodes()? {
            Some(addrs) => TransportKind::TcpConnectEvented(addrs),
            None => TransportKind::TcpLoopbackEvented,
        },
        other => bail!("--transport must be inproc|tcp|tcp-evented, got {other:?}"),
    };
    Ok(Topology { nodes, transport })
}

fn parse_push_nodes() -> Result<Option<Vec<std::net::SocketAddr>>> {
    match std::env::var("PUSH_NODES") {
        Ok(spec) if !spec.trim().is_empty() => {
            let addrs = spec
                .split(',')
                .map(|a| a.trim().parse().map_err(|e| anyhow!("$PUSH_NODES {a:?}: {e}")))
                .collect::<Result<Vec<_>>>()?;
            Ok(Some(addrs))
        }
        _ => Ok(None),
    }
}

fn scale_opts(flags: &Flags) -> Result<ScaleOpts> {
    let mut o = ScaleOpts::default();
    if let Some(d) = flags.usize_list("devices").map_err(anyhow::Error::msg)? {
        o.devices = d;
    }
    if let Some(p) = flags.usize_list("particles").map_err(anyhow::Error::msg)? {
        o.particles_base = p;
    }
    o.batches = flags.usize_or("batches", o.batches).map_err(anyhow::Error::msg)?;
    o.epochs = flags.usize_or("epochs", o.epochs).map_err(anyhow::Error::msg)?;
    o.cache_size = flags.usize_or("cache", o.cache_size).map_err(anyhow::Error::msg)?;
    o.seed = flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64;
    o.baseline = !flags.has("no-baseline");
    Ok(o)
}

fn info() -> Result<()> {
    let m = Manifest::load(artifacts_dir())?;
    println!("artifacts: {:?}", m.dir);
    println!("{:<12} {:>10} {:>9} {:>10}  entries", "model", "params", "task", "batch");
    for (name, spec) in &m.models {
        println!(
            "{name:<12} {:>10} {:>9} {:>10}  {}",
            spec.param_count,
            spec.task,
            spec.batch(),
            spec.entries.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    println!("\nsvgd kernel artifacts: {} (n, d) specializations", m.svgd.len());
    let mut client = push::runtime::RuntimeClient::cpu()?;
    println!("PJRT platform: {}", client.platform());
    // compile + run one tiny entry as a smoke check
    let tiny = m.model("mlp_tiny")?;
    let key = push::Tensor::u32(vec![2], vec![0, 0]);
    let outs = client.execute(&tiny.entry("init")?.file, &[key])?;
    println!("smoke: mlp_tiny.init -> {} params OK", outs[0].element_count());
    Ok(())
}

fn train(flags: &Flags) -> Result<()> {
    let model_name = flags
        .str("model")
        .ok_or_else(|| anyhow!("--model is required (see `push info`)"))?;
    // --algo is the canonical spelling; --method stays as an alias.
    let algo_name = flags
        .str("algo")
        .or_else(|| flags.str("method"))
        .unwrap_or("ensemble")
        .to_string();
    let method = Method::parse(&algo_name)
        .ok_or_else(|| anyhow!("--algo must be ensemble|multi_swag|svgd|sgld|sghmc"))?;
    let particles = flags.usize_or("particles", 4).map_err(anyhow::Error::msg)?;
    let devices = flags.usize_or("devices", 1).map_err(anyhow::Error::msg)?;
    let epochs = flags.usize_or("epochs", 5).map_err(anyhow::Error::msg)?;
    let batches = flags.usize_or("batches", 8).map_err(anyhow::Error::msg)?;
    let cache = flags.usize_or("cache", 8).map_err(anyhow::Error::msg)?;
    let seed = flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64;
    // 0 = auto (one control worker per available CPU)
    let workers = flags.usize_or("workers", 0).map_err(anyhow::Error::msg)?;
    // kernel-plane sharding: only override when the flag is given so
    // $PUSH_KERNEL_THREADS keeps working as the ambient default (0 = auto)
    if let Some(n) = flags.usize("kernel-threads").map_err(anyhow::Error::msg)? {
        push::runtime::kernels::set_threads(n);
    }
    // 0 = no serving; N refreshes the posterior snapshot every N epochs
    let serve_every = flags.usize_or("serve-every", 0).map_err(anyhow::Error::msg)?;
    // elastic fabric: 0 disables the heartbeat monitor / recovery budget
    let heartbeat_ms = flags.usize_or("heartbeat-every", 0).map_err(anyhow::Error::msg)?;
    let dead_after_ms =
        flags.usize_or("dead-after", heartbeat_ms * 4).map_err(anyhow::Error::msg)?;
    let recover = flags.usize_or("recover", 0).map_err(anyhow::Error::msg)?;

    let topology = parse_topology(flags)?;
    let is_sgmcmc = matches!(method, Method::Sgld | Method::Sghmc);
    let tcp = !matches!(topology.transport, TransportKind::InProc);
    // Which algorithms can span this topology: wire transports need
    // spec-based creation (handler programs), which sgld/sghmc provide;
    // SVGD's leader cross-sends to followers inside handlers, which is
    // node-local by design — route it through the fabric later.
    if tcp && !is_sgmcmc {
        bail!("--transport tcp currently supports --algo sgld|sghmc (spec-based creation)");
    }
    if topology.nodes > 1 && method == Method::Svgd {
        bail!("--nodes > 1 does not support svgd (its leader messages followers directly)");
    }
    // Validate BEFORE building the fabric: serving reads SGMCMC reservoirs
    // through a native forward, so the non-sgmcmc case can never serve.
    if serve_every > 0 && !is_sgmcmc {
        bail!("--serve-every needs --algo sgld|sghmc (posterior serving reads SGMCMC reservoirs)");
    }
    if recover > 0 && !is_sgmcmc {
        bail!("--recover needs --algo sgld|sghmc (chain migration replays SGMCMC rounds)");
    }
    let manifest = load_manifest(model_name)?;
    let cfg = NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::default(),
        control_workers: workers,
        seed,
        ..NelConfig::default()
    };
    let fabric_cfg = if heartbeat_ms > 0 {
        FabricConfig {
            heartbeat_every: Some(std::time::Duration::from_millis(heartbeat_ms as u64)),
            dead_after: std::time::Duration::from_millis(dead_after_ms.max(1) as u64),
        }
    } else {
        FabricConfig::default()
    };
    let pd =
        PushDist::with_topology_and_fabric(&manifest, model_name, cfg, &topology, &fabric_cfg)?;
    let model = pd.model().clone();
    let classify = model.task == "classify";
    let lr = flags
        .f64("lr")
        .map_err(anyhow::Error::msg)?
        .map(|v| v as f32)
        .unwrap_or_else(|| push::bench::lr_for(&model));

    let data = push::bench::data_for(&model, model.batch() * batches, seed + 1)?;
    // Fixed probe batch for --serve-every posterior queries (the first
    // batch-size samples, gathered before the loader takes the data);
    // non-serving runs skip the gather entirely.
    let probe = (serve_every > 0)
        .then(|| data.gather(&(0..model.batch().min(data.n)).collect::<Vec<_>>()));
    // Double-buffered pipeline: batch t+1 materializes on a background
    // producer while the round for batch t runs on the devices; the batch
    // sequence is bit-identical to the synchronous DataLoader.
    let mut loader = PrefetchLoader::new(
        DataLoader::new(data, model.batch(), true, seed + 2).with_max_batches(batches),
    );

    println!(
        "training {model_name} via {} — {particles} particles on {} node(s) x {devices} \
         device(s) ({} transport), lr {lr}",
        method.name(),
        topology.nodes,
        if tcp { "tcp" } else { "inproc" },
    );
    // Registered native models swap the artifact plane for closed-form
    // closures; every family has a `new_native` twin, so any native model
    // trains under any --algo.
    let native = push::infer::native_model(model_name);
    let mut server: Option<PosteriorServer> = None;
    let mut algo: Box<dyn Infer> = match method {
        Method::Ensemble => match &native {
            Some(nm) => Box::new(DeepEnsemble::new_native(
                pd,
                particles,
                lr,
                &nm.source,
                nm.seeded_init(seed),
            )?),
            None => Box::new(DeepEnsemble::new(pd, particles, lr)?),
        },
        Method::MultiSwag => {
            let swag_cfg = SwagConfig { particles, lr, ..SwagConfig::default() };
            match &native {
                Some(nm) => Box::new(MultiSwag::new_native(
                    pd,
                    swag_cfg,
                    &nm.source,
                    nm.seeded_init(seed),
                )?),
                None => Box::new(MultiSwag::new(pd, swag_cfg)?),
            }
        }
        Method::Svgd => {
            let svgd_cfg =
                SvgdConfig { particles, lr, lengthscale: 10.0, ..SvgdConfig::default() };
            match &native {
                Some(nm) => {
                    Box::new(Svgd::new_native(pd, svgd_cfg, &nm.source, nm.seeded_init(seed))?)
                }
                None => Box::new(Svgd::new(pd, svgd_cfg)?),
            }
        }
        Method::Sgld | Method::Sghmc => {
            let algo =
                if method == Method::Sgld { SgmcmcAlgo::Sgld } else { SgmcmcAlgo::Sghmc };
            let temp = flags.f64_or("temp", 1e-4).map_err(anyhow::Error::msg)? as f32;
            let friction = flags.f64_or("friction", 0.1).map_err(anyhow::Error::msg)? as f32;
            let burn_in = flags.usize_or("burn-in", batches).map_err(anyhow::Error::msg)?;
            let thin = flags.usize_or("thin", 2).map_err(anyhow::Error::msg)?;
            let max_samples = flags.usize_or("samples", 32).map_err(anyhow::Error::msg)?;
            let mut chain_cfg = SgmcmcConfig {
                particles,
                algo,
                schedule: Schedule::Constant { eps: lr },
                temperature: temp,
                friction,
                burn_in,
                thin,
                max_samples,
                seed,
                ..SgmcmcConfig::default()
            };
            if let Some(nm) = &native {
                // fully hermetic: native closed-form grad/forward plus
                // explicit init parameters — no artifacts on any node
                chain_cfg.model = nm.source.clone();
                chain_cfg.init = Some(nm.seeded_init(seed));
            }
            let m = SgMcmc::new(pd, chain_cfg)?.with_recovery(recover);
            if serve_every > 0 {
                // errors here name the real constraint: serving needs a
                // native ModelSource (artifact forwards live behind the
                // device layer)
                server = Some(m.serve_handle()?);
            }
            Box::new(m)
        }
    };
    for e in 0..epochs {
        let rep = algo.train(&mut loader, 1)?;
        println!(
            "epoch {e:>3}: loss {:>9.4}  ({:.3}s)",
            rep.final_loss(),
            rep.mean_epoch_secs()
        );
        if let (Some(srv), Some(probe)) = (&server, &probe) {
            if (e + 1) % serve_every == 0 {
                match srv.refresh_at(e + 1) {
                    Ok(snap) => {
                        let stale = if snap.staleness.is_complete() {
                            String::new()
                        } else {
                            format!(
                                ", DEGRADED: {} chain(s) stale, lag {}",
                                snap.staleness.missing.len(),
                                snap.staleness.epoch_lag
                            )
                        };
                        match srv.predict_mean(&probe.x) {
                            Ok(pred) => println!(
                                "  serve: snapshot @epoch {} ({} chains, {} samples{stale}) {}",
                                e + 1,
                                snap.chains.len(),
                                snap.total_samples(),
                                probe_metric(&pred, &probe.y, classify),
                            ),
                            Err(err) => println!("  serve: snapshot @epoch {} — {err}", e + 1),
                        }
                    }
                    // degrade-to-stale: a failed refresh keeps the tier
                    // up on the last good snapshot; report and move on
                    Err(err) => println!("  serve: refresh @epoch {} failed — {err}", e + 1),
                }
            }
        }
    }
    let stats = algo.nel_stats();
    let s = &stats.sched;
    println!(
        "\nmessages: {} ({} cross-device, {} payload bytes)",
        stats.msgs_sent, stats.msgs_cross_device, stats.msg_payload_bytes
    );
    println!(
        "sched: workers {}/{} (peak {}, cap {}), handler runs {} in {} turns, \
         compensations {}, helps {}, steals {}, priority turns {}",
        s.workers_live,
        s.pool_target,
        s.workers_peak,
        s.max_workers,
        s.handler_runs,
        s.turns,
        s.compensations,
        s.helps,
        s.steals,
        s.priority_turns,
    );
    for (i, d) in stats.devices.iter().enumerate() {
        println!("{}", d.summary(i));
    }
    if let Some(diag) = algo.diagnostics() {
        println!(
            "chain diag: R-hat {} | ESS {} ({} chains x {} samples)",
            eval::fmt_diag(diag.r_hat),
            eval::fmt_diag(diag.ess),
            diag.chains,
            diag.samples_per_chain,
        );
    }
    let transport = algo.transport_counters();
    if transport.iter().any(|c| c.frames_sent > 0 || c.frames_received > 0) {
        for (i, c) in transport.iter().enumerate() {
            println!(
                "node {i} transport: {} frames out ({} B), {} frames in ({} B)",
                c.frames_sent, c.bytes_sent, c.frames_received, c.bytes_received,
            );
        }
    }
    if let Some(srv) = &server {
        let st = srv.serve_stats();
        println!(
            "serve: {} snapshot refreshes ({} degraded, {} retries), {} posterior queries \
             ({} served, {} stale, {} shed); latency {}",
            st.refreshes,
            st.degraded_refreshes,
            st.retries,
            st.queries,
            st.served,
            st.stale_served,
            st.shed,
            st.latency.render(),
        );
    }
    Ok(())
}

/// Train a hermetic native model WHILE serving posterior predictions:
/// `--clients C` threads hammer `PosteriorServer::predict_mean` against
/// epoch-stamped reservoir snapshots as training steps — the
/// pipelined-data + serving demo (DESIGN.md §10). Works over every
/// transport (`--nodes`/`--transport` as in train); queries are answered
/// on the client threads, never through the scheduler.
fn serve(flags: &Flags) -> Result<()> {
    let model_name = flags.str_or("model", "linear_native");
    let nm = push::infer::native_model(&model_name).ok_or_else(|| {
        anyhow!(
            "push serve is hermetic: --model must be a native model ({})",
            push::infer::NATIVE_MODEL_NAMES.join("|")
        )
    })?;
    let algo_name = flags.str_or("algo", "sgld");
    let method = Method::parse(&algo_name)
        .filter(|m| matches!(*m, Method::Sgld | Method::Sghmc))
        .ok_or_else(|| anyhow!("push serve needs --algo sgld|sghmc"))?;
    let particles = flags.usize_or("particles", 8).map_err(anyhow::Error::msg)?;
    let devices = flags.usize_or("devices", 1).map_err(anyhow::Error::msg)?;
    let epochs = flags.usize_or("epochs", 6).map_err(anyhow::Error::msg)?;
    let batches = flags.usize_or("batches", 8).map_err(anyhow::Error::msg)?;
    let clients = flags.usize_or("clients", 4).map_err(anyhow::Error::msg)?;
    let serve_every = flags.usize_or("serve-every", 1).map_err(anyhow::Error::msg)?.max(1);
    let seed = flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64;
    let workers = flags.usize_or("workers", 0).map_err(anyhow::Error::msg)?;
    if let Some(n) = flags.usize("kernel-threads").map_err(anyhow::Error::msg)? {
        push::runtime::kernels::set_threads(n);
    }
    // serving policy: 0 = wait for the transport / admit everything
    let deadline_ms = flags.usize_or("deadline-ms", 0).map_err(anyhow::Error::msg)?;
    let retries = flags.usize_or("retries", 2).map_err(anyhow::Error::msg)?;
    let max_inflight = flags.usize_or("max-inflight", 0).map_err(anyhow::Error::msg)?;
    // elastic fabric: 0 disables the heartbeat monitor
    let heartbeat_ms = flags.usize_or("heartbeat-every", 0).map_err(anyhow::Error::msg)?;
    let dead_after_ms =
        flags.usize_or("dead-after", heartbeat_ms * 4).map_err(anyhow::Error::msg)?;
    let mut topology = parse_topology(flags)?;
    // The serving tier defaults its TCP links to the evented transport:
    // parked client connections must not cost parked threads. Training
    // runs keep "tcp" threaded (the reference path); --tcp-threaded
    // opts serving back into it.
    if !flags.has("tcp-threaded") {
        topology.transport = match topology.transport {
            TransportKind::TcpLoopback => TransportKind::TcpLoopbackEvented,
            TransportKind::TcpConnect(addrs) => TransportKind::TcpConnectEvented(addrs),
            t => t,
        };
    }

    let manifest = load_manifest(&model_name)?;
    let cfg = NelConfig {
        num_devices: devices,
        cache_size: flags.usize_or("cache", 8).map_err(anyhow::Error::msg)?,
        cost: CostModel::default(),
        control_workers: workers,
        seed,
        ..NelConfig::default()
    };
    let fabric_cfg = if heartbeat_ms > 0 {
        FabricConfig {
            heartbeat_every: Some(std::time::Duration::from_millis(heartbeat_ms as u64)),
            dead_after: std::time::Duration::from_millis(dead_after_ms.max(1) as u64),
        }
    } else {
        FabricConfig::default()
    };
    let pd =
        PushDist::with_topology_and_fabric(&manifest, &model_name, cfg, &topology, &fabric_cfg)?;
    let model = pd.model().clone();
    let lr = flags
        .f64("lr")
        .map_err(anyhow::Error::msg)?
        .map(|v| v as f32)
        .unwrap_or(1e-2);
    let chain_cfg = SgmcmcConfig {
        particles,
        algo: if method == Method::Sgld { SgmcmcAlgo::Sgld } else { SgmcmcAlgo::Sghmc },
        schedule: Schedule::Constant { eps: lr },
        temperature: flags.f64_or("temp", 1e-4).map_err(anyhow::Error::msg)? as f32,
        friction: flags.f64_or("friction", 0.1).map_err(anyhow::Error::msg)? as f32,
        // serve as early as possible by default: no burn-in, thin 1
        burn_in: flags.usize_or("burn-in", 0).map_err(anyhow::Error::msg)?,
        thin: flags.usize_or("thin", 1).map_err(anyhow::Error::msg)?,
        max_samples: flags.usize_or("samples", 32).map_err(anyhow::Error::msg)?,
        seed,
        model: nm.source.clone(),
        init: Some(nm.seeded_init(seed)),
        ..SgmcmcConfig::default()
    };
    let mut algo = SgMcmc::new(pd, chain_cfg)?;
    let serve_cfg = push::infer::ServeConfig {
        refresh_deadline: (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
        refresh_retries: retries as u32,
        max_inflight,
        ..push::infer::ServeConfig::default()
    };
    let server = Arc::new(algo.serve_handle_with(serve_cfg)?);

    let data = push::bench::data_for(&model, model.batch() * batches, seed + 1)?;
    let probe = data.gather(&(0..model.batch().min(data.n)).collect::<Vec<_>>());
    let mut loader = PrefetchLoader::new(
        DataLoader::new(data, model.batch(), true, seed + 2).with_max_batches(batches),
    );

    println!(
        "serving {model_name} while training via {} — {particles} chains on {} node(s) x \
         {devices} device(s), {clients} client thread(s), snapshot every {serve_every} epoch(s)",
        method.name(),
        topology.nodes,
    );

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let t0 = std::time::Instant::now();
    let client_handles: Vec<_> = (0..clients)
        .map(|_| {
            let server = server.clone();
            let stop = stop.clone();
            let x = probe.x.clone();
            std::thread::spawn(move || {
                let (mut ok, mut empty) = (0u64, 0u64);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    match server.predict_mean(&x) {
                        Ok(_) => ok += 1,
                        Err(_) => empty += 1, // pre-burn-in snapshot
                    }
                }
                (ok, empty)
            })
        })
        .collect();

    for e in 0..epochs {
        // The serving tier outlives training: if a node dies mid-epoch the
        // train step fails, but the tier must keep answering from the last
        // published snapshot (DESIGN.md §12) — log, take one final refresh
        // so the staleness record names the lost chains, drain briefly so
        // in-flight clients observe the degraded snapshot, and exit clean.
        let rep = match algo.train(&mut loader, 1) {
            Ok(rep) => rep,
            Err(err) => {
                println!("epoch {e:>3}: training halted — {err}");
                match server.refresh_at(e + 1) {
                    Ok(snap) if !snap.staleness.is_complete() => println!(
                        "degrading to stale: serving continues, {} chain(s) DEGRADED \
                         ({} epoch lag)",
                        snap.staleness.missing.len(),
                        snap.staleness.epoch_lag
                    ),
                    Ok(_) => println!("degrading to stale: serving continues (snapshot intact)"),
                    Err(rerr) => println!("degrading to stale: refresh also failed — {rerr}"),
                }
                std::thread::sleep(std::time::Duration::from_millis(300));
                break;
            }
        };
        let mut line = format!(
            "epoch {e:>3}: loss {:>9.4}  ({:.3}s)",
            rep.final_loss(),
            rep.mean_epoch_secs()
        );
        if (e + 1) % serve_every == 0 {
            // degrade-to-stale: a refresh against a dead node publishes a
            // partial snapshot (or keeps the last good one) and the tier
            // keeps answering — never take the process down mid-traffic
            match server.refresh_at(e + 1) {
                Ok(snap) => {
                    let stale = if snap.staleness.is_complete() {
                        String::new()
                    } else {
                        format!(
                            ", DEGRADED: {} chain(s) stale ({} epoch lag)",
                            snap.staleness.missing.len(),
                            snap.staleness.epoch_lag
                        )
                    };
                    line.push_str(&format!(
                        "  [snapshot @{}: {} samples across {} chains{stale}]",
                        e + 1,
                        snap.total_samples(),
                        snap.chains.len()
                    ));
                }
                Err(err) => line.push_str(&format!("  [refresh @{} failed: {err}]", e + 1)),
            }
        }
        println!("{line}");
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let elapsed = t0.elapsed().as_secs_f64();
    let (mut ok, mut empty) = (0u64, 0u64);
    for h in client_handles {
        let (o, e) = h.join().map_err(|_| anyhow!("serve client thread panicked"))?;
        ok += o;
        empty += e;
    }
    let st = server.serve_stats();
    println!(
        "\nserved {ok} posterior queries ({empty} errored or shed) in {elapsed:.2}s \
         — {:.0} q/s across {clients} client(s)",
        ok as f64 / elapsed.max(1e-9),
    );
    println!(
        "serve stats: {} refreshes ({} degraded, {} retries), {} admitted ({} served, \
         {} stale, {} shed); latency {}",
        st.refreshes,
        st.degraded_refreshes,
        st.retries,
        st.queries,
        st.served,
        st.stale_served,
        st.shed,
        st.latency.render(),
    );
    let final_snap = server.snapshot();
    if !final_snap.staleness.is_complete() {
        let missing: Vec<String> =
            final_snap.staleness.missing.iter().map(|p| format!("{p}")).collect();
        println!(
            "final snapshot DEGRADED: missing {} (epoch lag {})",
            missing.join(" "),
            final_snap.staleness.epoch_lag
        );
    }
    let classify = model.task == "classify";
    match server.predict_mean(&probe.x) {
        // predictive_std is regression-only by design (class votes have no
        // per-point spread), so classify tasks report the vote accuracy.
        Ok(pred) if classify => {
            println!("final snapshot: {}", probe_metric(&pred, &probe.y, true));
        }
        Ok(pred) => {
            let spread = server.predictive_std(&probe.x)?;
            let mean_std = spread.as_f32().iter().map(|v| *v as f64).sum::<f64>()
                / spread.element_count() as f64;
            println!(
                "final snapshot: {}, mean epistemic std {mean_std:.4}",
                probe_metric(&pred, &probe.y, false),
            );
        }
        Err(err) => println!("final snapshot answered no queries: {err}"),
    }
    let versions = server.snapshot().versions();
    let shown: Vec<String> =
        versions.iter().take(4).map(|(p, s)| format!("{p}:{s}")).collect();
    println!(
        "reservoir versions (pid:seen): {}{}",
        shown.join(" "),
        if versions.len() > 4 { " …" } else { "" }
    );
    Ok(())
}

/// Hidden subcommand: one distributed-NEL node server. Binds
/// --host:--port (default 127.0.0.1, ephemeral), prints the address, and
/// serves connections — one NEL per connection — until killed (or after
/// one connection with --once). The default is the evented accept loop
/// (any number of concurrent connections off the reactor's poll pool);
/// --once and --threaded use the one-connection-per-loop reference
/// server. `push train --transport tcp` reaches workers via
/// $PUSH_NODES=host:port,host:port.
fn node_worker(flags: &Flags) -> Result<()> {
    let model_name = flags.str_or("model", "linear_native");
    let manifest = load_manifest(&model_name)?;
    let model = Arc::new(manifest.model(&model_name)?.clone());
    let host = flags.str_or("host", "127.0.0.1");
    let port = flags.usize_or("port", 0).map_err(anyhow::Error::msg)? as u16;
    let cfg = NelConfig {
        num_devices: flags.usize_or("devices", 1).map_err(anyhow::Error::msg)?,
        cache_size: flags.usize_or("cache", 8).map_err(anyhow::Error::msg)?,
        control_workers: flags.usize_or("workers", 0).map_err(anyhow::Error::msg)?,
        seed: flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64,
        node: flags.usize("node").map_err(anyhow::Error::msg)?,
        cost: CostModel::default(),
        ..NelConfig::default()
    };
    let listener = std::net::TcpListener::bind((host.as_str(), port))?;
    println!("node-worker listening on {} (model {model_name})", listener.local_addr()?);
    if flags.has("once") || flags.has("threaded") {
        loop {
            push::pd::transport::serve_one(&listener, cfg.clone(), model.clone())?;
            if flags.has("once") {
                return Ok(());
            }
        }
    }
    push::pd::transport::serve_evented(listener, cfg, model)?;
    // The reactor owns the accept loop now; this thread just has to stay
    // alive (the worker runs until killed).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn bench(flags: &Flags) -> Result<()> {
    let which = flags
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| {
            anyhow!("bench needs a target (fig4|fig7|table1..table4|native-acc|stress|ablate)")
        })?;
    // Hermetic native-model matrix: every native model x every family,
    // no artifacts required — this is what the CI accuracy gate runs, so
    // it must not touch the artifact manifest at all.
    if which == "native-acc" {
        let mut o = accuracy::AccOpts::native();
        o.devices = flags.usize_or("devices-n", o.devices).map_err(anyhow::Error::msg)?;
        o.batches = flags.usize_or("batches", o.batches).map_err(anyhow::Error::msg)?;
        o.epochs = flags.usize_or("epochs", o.epochs).map_err(anyhow::Error::msg)?;
        o.pretrain_epochs = (o.epochs * 7) / 10;
        o.seed = flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64;
        let report = accuracy::run_native(&o)?;
        report.print();
        let path = report.save(results_dir())?;
        println!("\nsaved {path:?}");
        return Ok(());
    }
    // --models a,b,c overrides a figure/table's model list. An all-native
    // list runs against the hermetic in-process manifest (no artifacts);
    // mixing native and artifact models has no single manifest to run on.
    let models: Option<Vec<String>> = flags.str("models").map(|s| {
        s.split(',').map(|m| m.trim().to_string()).filter(|m| !m.is_empty()).collect()
    });
    let n_native = models
        .as_ref()
        .map(|ms| ms.iter().filter(|m| push::infer::native_model(m).is_some()).count())
        .unwrap_or(0);
    let all_native = models.as_ref().map(|ms| n_native == ms.len()).unwrap_or(false);
    if n_native > 0 && !all_native {
        bail!("--models mixes native and artifact models; run them as separate invocations");
    }
    let manifest =
        if all_native { push::infer::native_manifest() } else { Manifest::load(artifacts_dir())? };
    let opts = scale_opts(flags)?;
    let full = flags.has("full");
    let figure_archs = |defaults: &[&str]| -> Vec<String> {
        models.clone().unwrap_or_else(|| defaults.iter().map(|s| s.to_string()).collect())
    };
    let sweep_rows = |defaults: Vec<depth_width::SweepRow>| -> Vec<depth_width::SweepRow> {
        match &models {
            Some(ms) => ms
                .iter()
                .map(|m| depth_width::SweepRow { model: m.clone(), base_particles: 4 })
                .collect(),
            None => defaults,
        }
    };
    // the depth/width tables default to the paper's multi-SWAG protocol
    let dw_method = match flags.str("algo").or_else(|| flags.str("method")) {
        Some(a) => Method::parse(a)
            .ok_or_else(|| anyhow!("--algo must be ensemble|multi_swag|svgd|sgld|sghmc"))?,
        None => Method::MultiSwag,
    };

    let report = match which {
        "fig4" => {
            let archs = figure_archs(&["vit_fig4", "cgcnn_fig4", "unet_fig4"]);
            let archs: Vec<&str> = archs.iter().map(String::as_str).collect();
            scaling::run_figure(&manifest, "fig4_scaling", &archs, &Method::all(), &opts)?
        }
        "fig7" => {
            let archs = figure_archs(&["resnet_fig7", "schnet_fig7"]);
            let archs: Vec<&str> = archs.iter().map(String::as_str).collect();
            scaling::run_figure(&manifest, "fig7_scaling", &archs, &Method::all(), &opts)?
        }
        "table1" => depth_width::run(
            &manifest,
            "table1_depth",
            &sweep_rows(depth_width::table1_rows()),
            dw_method,
            &opts.devices.clone(),
            &opts,
        )?,
        "table2" => depth_width::run(
            &manifest,
            "table2_width",
            &sweep_rows(depth_width::table2_rows(full)),
            dw_method,
            &opts.devices.clone(),
            &opts,
        )?,
        "table3" => {
            let rows = depth_width::table1_rows();
            accuracy::run(&manifest, "table3_depth_acc", &rows, &acc_opts(flags)?)?
        }
        "table4" => {
            let rows = depth_width::table2_rows(full);
            accuracy::run(&manifest, "table4_width_acc", &rows, &acc_opts(flags)?)?
        }
        "ablate" => {
            let mut combined = push::bench::report::Report::new("ablations");
            for rep in [
                push::bench::ablate::cache_size_sweep(
                    &manifest, "mlp_small", 8, &[1, 2, 4, 8], opts.batches, opts.epochs,
                )?,
                push::bench::ablate::svgd_kernel_ablation(
                    &manifest, "mlp_small", &[4, 8, 16], opts.batches,
                )?,
                push::bench::ablate::cost_model_ablation(&manifest, "mlp_small", 4, opts.batches)?,
            ] {
                rep.print();
                let p = rep.save(results_dir())?;
                println!("saved {p:?}\n");
                combined.rows.extend(rep.rows);
            }
            combined
        }
        "stress" => {
            let bases = flags
                .usize_list("particles")
                .map_err(anyhow::Error::msg)?
                .unwrap_or_else(|| vec![16, 32, 64]);
            scaling::run_stress(&manifest, "mlp_small", &opts.devices.clone(), &bases, &opts)?
        }
        other => bail!("unknown bench target {other:?}"),
    };
    report.print();
    let path = report.save(results_dir())?;
    println!("\nsaved {path:?}");
    Ok(())
}

fn acc_opts(flags: &Flags) -> Result<accuracy::AccOpts> {
    let mut o = accuracy::AccOpts::default();
    o.devices = flags.usize_or("devices-n", o.devices).map_err(anyhow::Error::msg)?;
    o.batches = flags.usize_or("batches", o.batches).map_err(anyhow::Error::msg)?;
    o.epochs = flags.usize_or("epochs", o.epochs).map_err(anyhow::Error::msg)?;
    o.pretrain_epochs = (o.epochs * 7) / 10;
    o.seed = flags.usize_or("seed", 0).map_err(anyhow::Error::msg)? as u64;
    Ok(o)
}

/// Two interacting particles with the trace on — regenerates the paper's
/// Figure 3b timeline qualitatively.
fn trace(flags: &Flags) -> Result<()> {
    let model_name = flags.str_or("model", "mlp_tiny");
    let manifest = Manifest::load(artifacts_dir())?;
    let cfg = NelConfig {
        num_devices: 2,
        cache_size: 2,
        cost: CostModel::default(),
        trace: true,
        seed: 0,
        ..NelConfig::default()
    };
    let pd = PushDist::new(&manifest, &model_name, cfg)?;

    // P_j sends MSG to P_k; P_k computes (a forward pass) and replies.
    let compute = handler(|ctx, args| {
        let x = args[0].as_tensor()?.clone();
        ctx.forward(x).wait()
    });
    let relay = handler(|ctx, args| {
        let target = push::Pid(args[0].usize()? as u32);
        let x = args[1].as_tensor()?.clone();
        // send -> receive a future -> wait (Figure 3b labels 1-7)
        let fut = ctx.send(target, "COMPUTE", vec![Value::Tensor(x)]);
        let pred = fut.wait()?;
        Ok(pred)
    });
    let pj = pd.p_create(CreateOpts {
        device: Some(0),
        receive: [("RELAY".to_string(), relay)].into_iter().collect(),
        ..CreateOpts::default()
    })?;
    let pk = pd.p_create(CreateOpts {
        device: Some(1),
        receive: [("COMPUTE".to_string(), compute)].into_iter().collect(),
        ..CreateOpts::default()
    })?;

    let model = pd.model().clone();
    let xn: usize = model.x_shape.iter().product();
    let x = push::Tensor::f32(model.x_shape.clone(), vec![0.1; xn]);
    pd.p_launch(pj, "RELAY", vec![Value::Usize(pk.0 as usize), Value::Tensor(x)])
        .wait()
        .map_err(|e| anyhow!("{e}"))?;

    println!("Figure-3b timeline for two interacting particles ({pj} on dev0, {pk} on dev1):\n");
    print!("{}", pd.nel().trace().to_text());
    Ok(())
}
