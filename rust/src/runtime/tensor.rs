//! Host-side tensor: the only value type that crosses thread boundaries.
//!
//! PJRT objects (clients, buffers, literals) are not Send and stay pinned to
//! their device thread (see device::worker); everything the coordinator
//! routes between particles is a plain `Tensor` — shape + contiguous host
//! data. Conversion to/from `xla::Literal` happens inside the device worker.
//!
//! # Zero-copy storage (DESIGN.md §Zero-copy parameter plane)
//!
//! Storage is `Arc`-backed with copy-on-write semantics:
//!
//! * `Tensor::clone()` is a refcount bump — parameter views, host-store
//!   snapshots, future results, and message payloads share one buffer.
//! * `as_*_mut` detaches first (`Arc::make_mut`), so mutating any clone
//!   never aliases its siblings. Read paths never copy; the first write
//!   after a share pays one buffer copy, and a uniquely-owned tensor
//!   mutates strictly in place.
//! * A tensor may be a *view*: a `[offset, offset+len)` window into a
//!   larger shared buffer (`row_view`/`unstack_rows`). Views read
//!   zero-copy; writing to a view first materializes just the window.

use std::fmt;
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "u32" => Some(DType::U32),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Shared, immutable-until-detached backing buffer. Cloning bumps a
/// refcount; `Tensor::as_*_mut` is the only detach point.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Arc<Vec<f32>>),
    I32(Arc<Vec<i32>>),
    U32(Arc<Vec<u32>>),
}

impl TensorData {
    pub fn f32(v: Vec<f32>) -> TensorData {
        TensorData::F32(Arc::new(v))
    }

    pub fn i32(v: Vec<i32>) -> TensorData {
        TensorData::I32(Arc::new(v))
    }

    pub fn u32(v: Vec<u32>) -> TensorData {
        TensorData::U32(Arc::new(v))
    }

    /// Length of the *backing buffer* (>= the logical element count of a
    /// view into it).
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }

    fn ptr_eq(&self, other: &TensorData) -> bool {
        match (self, other) {
            (TensorData::F32(a), TensorData::F32(b)) => Arc::ptr_eq(a, b),
            (TensorData::I32(a), TensorData::I32(b)) => Arc::ptr_eq(a, b),
            (TensorData::U32(a), TensorData::U32(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// A dense host tensor. Shape `[]` is a scalar with one element. Cheap to
/// clone (refcount bump); see the module docs for the COW contract.
#[derive(Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    data: TensorData,
    /// Element offset of this tensor's window into the backing buffer.
    /// 0 for ordinary tensors; nonzero only for row views.
    off: usize,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: TensorData) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs {} elements", data.len());
        Tensor { shape, data, off: 0 }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor::new(shape, TensorData::f32(data))
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        Tensor::new(shape, TensorData::i32(data))
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Tensor {
        Tensor::new(shape, TensorData::u32(data))
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype().size_bytes()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    /// True if both tensors read from the same backing buffer — i.e. one is
    /// a zero-copy clone or view of the other. Used by the COW tests and
    /// the cache's no-copy-swap assertions.
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        self.data.ptr_eq(&other.data)
    }

    /// Borrow as f32 slice; panics on dtype mismatch (programming error).
    pub fn as_f32(&self) -> &[f32] {
        let n = self.element_count();
        match &self.data {
            TensorData::F32(v) => &v[self.off..self.off + n],
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Mutable borrow with copy-on-write: detaches from any sharers (and
    /// materializes a view's window) before handing out `&mut`. A uniquely
    /// owned, non-view tensor is mutated in place with zero copies.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        let n = self.element_count();
        match &mut self.data {
            TensorData::F32(a) => {
                if self.off != 0 || a.len() != n {
                    let window: Vec<f32> = a[self.off..self.off + n].to_vec();
                    *a = Arc::new(window);
                    self.off = 0;
                }
                Arc::make_mut(a).as_mut_slice()
            }
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        let n = self.element_count();
        match &self.data {
            TensorData::I32(v) => &v[self.off..self.off + n],
            other => panic!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_u32(&self) -> &[u32] {
        let n = self.element_count();
        match &self.data {
            TensorData::U32(v) => &v[self.off..self.off + n],
            other => panic!("expected u32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Scalar extraction for loss values.
    pub fn scalar(&self) -> f32 {
        assert_eq!(self.element_count(), 1, "scalar() on shape {:?}", self.shape);
        self.as_f32()[0]
    }

    /// Zero-copy view of row `i` of a 2-D tensor: shares the backing
    /// buffer, shape `[d]`. Reading is free; writing materializes only the
    /// row (COW).
    pub fn row_view(&self, i: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2, "row_view on shape {:?}", self.shape);
        let (n, d) = (self.shape[0], self.shape[1]);
        assert!(i < n, "row {i} out of {n}");
        Tensor { shape: vec![d], data: self.data.clone(), off: self.off + i * d }
    }

    /// Stack 1-D f32 tensors of equal length into an [n, d] tensor —
    /// the layout the SVGD kernel artifact takes. One allocation; the only
    /// full copy left on the SVGD leader's gather path.
    pub fn stack_rows(rows: &[&Tensor]) -> Tensor {
        assert!(!rows.is_empty());
        let d = rows[0].element_count();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.element_count(), d, "ragged stack");
            data.extend_from_slice(r.as_f32());
        }
        Tensor::f32(vec![rows.len(), d], data)
    }

    /// Split an [n, d] f32 tensor into n zero-copy row views of d.
    pub fn unstack_rows(&self) -> Vec<Tensor> {
        assert_eq!(self.shape.len(), 2, "unstack on shape {:?}", self.shape);
        (0..self.shape[0]).map(|i| self.row_view(i)).collect()
    }
}

/// Logical equality: same shape and same window contents, regardless of
/// whether the buffers are shared or where a view's window starts.
impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        if self.shape != other.shape || self.dtype() != other.dtype() {
            return false;
        }
        match self.dtype() {
            DType::F32 => self.as_f32() == other.as_f32(),
            DType::I32 => self.as_i32() == other.as_i32(),
            DType::U32 => self.as_u32() == other.as_u32(),
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor<{}>{:?}", self.dtype().name(), self.shape)?;
        if self.element_count() <= 8 {
            match self.dtype() {
                DType::F32 => write!(f, "{:?}", self.as_f32())?,
                DType::I32 => write!(f, "{:?}", self.as_i32())?,
                DType::U32 => write!(f, "{:?}", self.as_u32())?,
            }
        }
        Ok(())
    }
}

/// Axpy-style helpers used by the SWAG moment tracker and optimizers.
/// All write through `as_f32_mut`, so they are COW-safe: a shared `y`
/// detaches once; a uniquely-owned `y` updates strictly in place.
pub mod ops {
    //! Tensor-level wrappers over the kernel plane
    //! ([`crate::runtime::kernels`]): shape checks here, math there. All
    //! of these are zero-allocation — reductions use the kernel plane's
    //! stack-resident partials and elementwise ops mutate in place (after
    //! `as_f32_mut`'s usual COW discipline).
    use super::Tensor;
    use crate::runtime::kernels;

    /// y += alpha * x (elementwise, f32).
    pub fn axpy(y: &mut Tensor, alpha: f32, x: &Tensor) {
        let n = x.element_count();
        assert_eq!(n, y.element_count());
        kernels::axpy(y.as_f32_mut(), alpha, x.as_f32());
    }

    /// y *= a (elementwise, f32).
    pub fn scale(y: &mut Tensor, a: f32) {
        kernels::scale(y.as_f32_mut(), a);
    }

    /// y = a*y + b*x.
    pub fn scale_add(y: &mut Tensor, a: f32, b: f32, x: &Tensor) {
        assert_eq!(x.element_count(), y.element_count());
        kernels::scale_add(y.as_f32_mut(), a, b, x.as_f32());
    }

    /// Elementwise square accumulate: y = a*y + b*x^2.
    pub fn scale_add_sq(y: &mut Tensor, a: f32, b: f32, x: &Tensor) {
        assert_eq!(x.element_count(), y.element_count());
        kernels::scale_add_sq(y.as_f32_mut(), a, b, x.as_f32());
    }

    pub fn l2_norm(x: &Tensor) -> f32 {
        kernels::l2_norm(x.as_f32())
    }

    pub fn mean(x: &Tensor) -> f32 {
        kernels::mean(x.as_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(Tensor::scalar_f32(2.5).scalar(), 2.5);
    }

    #[test]
    fn stack_unstack() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![4.0, 5.0, 6.0]);
        let s = Tensor::stack_rows(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 3]);
        let rows = s.unstack_rows();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn clone_is_zero_copy_until_mutated() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(a.shares_storage(&b), "clone must share the buffer");
        b.as_f32_mut()[0] = 9.0;
        assert!(!a.shares_storage(&b), "first write must detach");
        assert_eq!(a.as_f32(), &[1.0, 2.0, 3.0], "source unchanged");
        assert_eq!(b.as_f32(), &[9.0, 2.0, 3.0]);
    }

    #[test]
    fn unique_tensor_mutates_in_place() {
        let mut a = Tensor::f32(vec![2], vec![1.0, 2.0]);
        let before = a.as_f32().as_ptr();
        a.as_f32_mut()[0] = 5.0;
        assert_eq!(a.as_f32().as_ptr(), before, "no sharers -> no copy");
    }

    #[test]
    fn unstack_rows_are_views() {
        let s = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let rows = s.unstack_rows();
        assert!(rows[0].shares_storage(&s));
        assert!(rows[1].shares_storage(&s));
        assert_eq!(rows[1].as_f32(), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn view_write_materializes_window_only() {
        let s = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut r1 = s.row_view(1);
        r1.as_f32_mut()[0] = 9.0;
        assert!(!r1.shares_storage(&s), "write detaches the view");
        assert_eq!(r1.as_f32(), &[9.0, 4.0]);
        assert_eq!(s.as_f32(), &[1.0, 2.0, 3.0, 4.0], "matrix untouched");
    }

    #[test]
    fn view_equality_is_logical() {
        let s = Tensor::f32(vec![2, 2], vec![7.0, 8.0, 7.0, 8.0]);
        assert_eq!(s.row_view(0), s.row_view(1));
        assert_eq!(s.row_view(0), Tensor::f32(vec![2], vec![7.0, 8.0]));
    }

    #[test]
    fn axpy_works() {
        let mut y = Tensor::f32(vec![2], vec![1.0, 2.0]);
        let x = Tensor::f32(vec![2], vec![10.0, 20.0]);
        ops::axpy(&mut y, 0.5, &x);
        assert_eq!(y.as_f32(), &[6.0, 12.0]);
    }

    #[test]
    fn axpy_on_shared_detaches() {
        let mut y = Tensor::f32(vec![2], vec![1.0, 2.0]);
        let snapshot = y.clone();
        let x = Tensor::f32(vec![2], vec![1.0, 1.0]);
        ops::axpy(&mut y, 1.0, &x);
        assert_eq!(snapshot.as_f32(), &[1.0, 2.0], "snapshot immune");
        assert_eq!(y.as_f32(), &[2.0, 3.0]);
    }

    #[test]
    fn scale_add_sq_works() {
        let mut y = Tensor::f32(vec![2], vec![1.0, 1.0]);
        let x = Tensor::f32(vec![2], vec![2.0, 3.0]);
        ops::scale_add_sq(&mut y, 0.5, 0.5, &x);
        assert_eq!(y.as_f32(), &[2.5, 5.0]);
    }
}
