//! Host-side tensor: the only value type that crosses thread boundaries.
//!
//! PJRT objects (clients, buffers, literals) are not Send and stay pinned to
//! their device thread (see device::worker); everything the coordinator
//! routes between particles is a plain `Tensor` — shape + contiguous host
//! data. Conversion to/from `xla::Literal` happens inside the device worker.

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "u32" => Some(DType::U32),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }
}

/// A dense host tensor. Shape `[]` is a scalar with one element.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: TensorData) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs {} elements", data.len());
        Tensor { shape, data }
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor::new(shape, TensorData::F32(data))
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        Tensor::new(shape, TensorData::I32(data))
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Tensor {
        Tensor::new(shape, TensorData::U32(data))
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * self.dtype().size_bytes()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    /// Borrow as f32 slice; panics on dtype mismatch (programming error).
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            other => panic!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_u32(&self) -> &[u32] {
        match &self.data {
            TensorData::U32(v) => v,
            other => panic!("expected u32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Scalar extraction for loss values.
    pub fn scalar(&self) -> f32 {
        assert_eq!(self.element_count(), 1, "scalar() on shape {:?}", self.shape);
        self.as_f32()[0]
    }

    /// Stack 1-D f32 tensors of equal length into an [n, d] tensor —
    /// the layout the SVGD kernel artifact takes.
    pub fn stack_rows(rows: &[&Tensor]) -> Tensor {
        assert!(!rows.is_empty());
        let d = rows[0].element_count();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.element_count(), d, "ragged stack");
            data.extend_from_slice(r.as_f32());
        }
        Tensor::f32(vec![rows.len(), d], data)
    }

    /// Split an [n, d] f32 tensor back into n rows of d.
    pub fn unstack_rows(&self) -> Vec<Tensor> {
        assert_eq!(self.shape.len(), 2, "unstack on shape {:?}", self.shape);
        let (n, d) = (self.shape[0], self.shape[1]);
        let data = self.as_f32();
        (0..n)
            .map(|i| Tensor::f32(vec![d], data[i * d..(i + 1) * d].to_vec()))
            .collect()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor<{}>{:?}", self.dtype().name(), self.shape)?;
        if self.element_count() <= 8 {
            match &self.data {
                TensorData::F32(v) => write!(f, "{v:?}")?,
                TensorData::I32(v) => write!(f, "{v:?}")?,
                TensorData::U32(v) => write!(f, "{v:?}")?,
            }
        }
        Ok(())
    }
}

/// Axpy-style helpers used by the SWAG moment tracker and optimizers.
pub mod ops {
    use super::Tensor;

    /// y += alpha * x (elementwise, f32).
    pub fn axpy(y: &mut Tensor, alpha: f32, x: &Tensor) {
        let xs = x.as_f32();
        let ys = y.as_f32_mut();
        assert_eq!(xs.len(), ys.len());
        for (yi, xi) in ys.iter_mut().zip(xs) {
            *yi += alpha * xi;
        }
    }

    /// y = a*y + b*x.
    pub fn scale_add(y: &mut Tensor, a: f32, b: f32, x: &Tensor) {
        let xs = x.as_f32();
        let ys = y.as_f32_mut();
        assert_eq!(xs.len(), ys.len());
        for (yi, xi) in ys.iter_mut().zip(xs) {
            *yi = a * *yi + b * xi;
        }
    }

    /// Elementwise square accumulate: y = a*y + b*x^2.
    pub fn scale_add_sq(y: &mut Tensor, a: f32, b: f32, x: &Tensor) {
        let xs = x.as_f32();
        let ys = y.as_f32_mut();
        assert_eq!(xs.len(), ys.len());
        for (yi, xi) in ys.iter_mut().zip(xs) {
            *yi = a * *yi + b * xi * xi;
        }
    }

    pub fn l2_norm(x: &Tensor) -> f32 {
        x.as_f32().iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn mean(x: &Tensor) -> f32 {
        let v = x.as_f32();
        v.iter().sum::<f32>() / v.len().max(1) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(Tensor::scalar_f32(2.5).scalar(), 2.5);
    }

    #[test]
    fn stack_unstack() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![4.0, 5.0, 6.0]);
        let s = Tensor::stack_rows(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 3]);
        let rows = s.unstack_rows();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn axpy_works() {
        let mut y = Tensor::f32(vec![2], vec![1.0, 2.0]);
        let x = Tensor::f32(vec![2], vec![10.0, 20.0]);
        ops::axpy(&mut y, 0.5, &x);
        assert_eq!(y.as_f32(), &[6.0, 12.0]);
    }

    #[test]
    fn scale_add_sq_works() {
        let mut y = Tensor::f32(vec![2], vec![1.0, 1.0]);
        let x = Tensor::f32(vec![2], vec![2.0, 3.0]);
        ops::scale_add_sq(&mut y, 0.5, 0.5, &x);
        assert_eq!(y.as_f32(), &[2.5, 5.0]);
    }
}
