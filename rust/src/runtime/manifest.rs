//! `artifacts/manifest.json` — the L2/L3 contract, parsed with util::json.
//!
//! The manifest is produced by `python -m compile.aot` and maps every model
//! to its four entry artifacts (init/fwd/grad/step) plus the SVGD kernel
//! artifacts, each with full argument/output signatures so the Rust side can
//! validate shapes before handing tensors to PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .and_then(DType::parse)
            .ok_or_else(|| anyhow!("spec missing/bad dtype"))?;
        Ok(TensorSpec { shape, dtype })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry: an HLO-text file plus its typed signature.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: PathBuf,
    pub args: Vec<TensorSpec>,
    pub outs: Vec<TensorSpec>,
}

impl EntrySpec {
    fn parse(dir: &Path, j: &Json) -> Result<EntrySpec> {
        let file = j
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("entry missing file"))?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry missing {key}"))?
                .iter()
                .map(TensorSpec::parse)
                .collect()
        };
        Ok(EntrySpec {
            file: dir.join(file),
            args: specs("args")?,
            outs: specs("outs")?,
        })
    }
}

/// A model's artifact set + metadata.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub param_count: usize,
    pub task: String,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub y_dtype: DType,
    pub arch: String,
    pub meta: BTreeMap<String, Json>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl ModelSpec {
    pub fn batch(&self) -> usize {
        self.x_shape[0]
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no entry {name}", self.name))
    }

    /// Number of classes for classify tasks (from the fwd output).
    pub fn n_classes(&self) -> Option<usize> {
        if self.task != "classify" {
            return None;
        }
        self.entries
            .get("fwd")
            .and_then(|e| e.outs.first())
            .and_then(|o| o.shape.last())
            .copied()
    }
}

/// SVGD kernel artifact, shape-specialized per (n particles, d params).
#[derive(Debug, Clone)]
pub struct SvgdSpec {
    pub n: usize,
    pub d: usize,
    pub file: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub svgd: Vec<SvgdSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`. `dir` is typically `artifacts/`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let spec = Self::parse_model(&dir, name, mj)
                .with_context(|| format!("model {name}"))?;
            models.insert(name.clone(), spec);
        }

        let mut svgd = Vec::new();
        for sj in j
            .get("svgd")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing svgd"))?
        {
            let n = sj.get("n").and_then(Json::as_usize).ok_or_else(|| anyhow!("svgd n"))?;
            let d = sj.get("d").and_then(Json::as_usize).ok_or_else(|| anyhow!("svgd d"))?;
            let file = sj
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("svgd file"))?;
            svgd.push(SvgdSpec { n, d, file: dir.join(file) });
        }
        Ok(Manifest { dir, models, svgd })
    }

    fn parse_model(dir: &Path, name: &str, j: &Json) -> Result<ModelSpec> {
        let usize_of = |key: &str| {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing {key}"))
        };
        let dims_of = |key: &str| -> Result<Vec<usize>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {key}"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {key}")))
                .collect()
        };
        let meta = j
            .get("meta")
            .and_then(Json::as_obj)
            .cloned()
            .unwrap_or_default();
        let mut entries = BTreeMap::new();
        for (ename, ej) in j
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing entries"))?
        {
            entries.insert(ename.clone(), EntrySpec::parse(dir, ej)?);
        }
        for required in ["init", "fwd", "grad", "step"] {
            if !entries.contains_key(required) {
                bail!("model {name} missing required entry {required}");
            }
        }
        Ok(ModelSpec {
            name: name.to_string(),
            param_count: usize_of("param_count")?,
            task: j
                .get("task")
                .and_then(Json::as_str)
                .unwrap_or("regress")
                .to_string(),
            x_shape: dims_of("x_shape")?,
            y_shape: dims_of("y_shape")?,
            y_dtype: j
                .get("y_dtype")
                .and_then(Json::as_str)
                .and_then(DType::parse)
                .ok_or_else(|| anyhow!("bad y_dtype"))?,
            arch: meta
                .get("arch")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            meta,
            entries,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name:?} (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    /// The SVGD artifact for exactly (n, d), if it was AOT-compiled.
    pub fn svgd_for(&self, n: usize, d: usize) -> Option<&SvgdSpec> {
        self.svgd.iter().find(|s| s.n == n && s.d == d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let entry = |f: &str| {
            format!(
                r#"{{"file": "{f}", "args": [{{"shape": [4], "dtype": "f32"}}],
                     "outs": [{{"shape": [], "dtype": "f32"}}]}}"#
            )
        };
        let text = format!(
            r#"{{"models": {{"m": {{
                  "param_count": 4, "task": "regress",
                  "x_shape": [2, 3], "y_shape": [2], "y_dtype": "f32",
                  "meta": {{"arch": "mlp"}},
                  "entries": {{"init": {e0}, "fwd": {e1}, "grad": {e2}, "step": {e3}}}
               }}}},
               "svgd": [{{"n": 2, "d": 4, "file": "svgd_n2_d4.hlo.txt"}}]}}"#,
            e0 = entry("m.init.hlo.txt"),
            e1 = entry("m.fwd.hlo.txt"),
            e2 = entry("m.grad.hlo.txt"),
            e3 = entry("m.step.hlo.txt"),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parse_fake() {
        let dir = std::env::temp_dir().join(format!("push-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let spec = m.model("m").unwrap();
        assert_eq!(spec.param_count, 4);
        assert_eq!(spec.batch(), 2);
        assert_eq!(spec.arch, "mlp");
        assert_eq!(spec.entry("init").unwrap().args[0].shape, vec![4]);
        assert!(m.svgd_for(2, 4).is_some());
        assert!(m.svgd_for(3, 4).is_none());
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_entry_rejected() {
        let dir = std::env::temp_dir().join(format!("push-manifest2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": {"m": {"param_count": 1, "x_shape": [1], "y_shape": [1],
                 "y_dtype": "f32", "entries": {}}}, "svgd": []}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
