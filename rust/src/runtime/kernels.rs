//! The kernel plane: vectorized + data-parallel primitives for the
//! per-step hot loops (DESIGN.md §14).
//!
//! Every per-step inner loop in the native plane — axpy applies, the MLP /
//! conv GEMV scatters, SVGD's RBF row kernels, the eval reductions — funnels
//! through this module. Three dispatch tiers share one math shape:
//!
//! * **scalar** — always compiled; the correctness oracle.
//! * **SIMD** — `--features simd`: explicit SSE2/AVX intrinsics on x86_64
//!   with runtime width detection ([`backend`]). Other targets fall back to
//!   the scalar tier. No FMA anywhere: every vector op is the same
//!   mul-then-add the scalar tier performs, so lanes are bit-exact.
//! * **threaded** — a fixed-size worker pool shards large operations
//!   (`len >= PAR_MIN`) across threads. Sized once from
//!   `PUSH_KERNEL_THREADS` / [`set_threads`] (`push train --kernel-threads`);
//!   0 = auto.
//!
//! **Bit-reproducibility is the hard invariant.** Reductions run a
//! fixed-shape tree keyed by `(len, LANES, shard plan)`:
//!
//! 1. the input splits into `shard_plan(len)` contiguous shards — a
//!    function of `len` only, never of the thread count;
//! 2. each shard accumulates into [`LANES`] independent lane accumulators
//!    (lane `j` sees elements `j, j+LANES, j+2·LANES, …` in order — exactly
//!    what an 8-wide vector register computes);
//! 3. the 8 lanes collapse through a fixed pairwise tree;
//! 4. shard partials combine sequentially in shard order.
//!
//! Scalar, SIMD, and threaded paths all execute this same shape, so the f32
//! result is byte-identical at any thread count and lane width — the
//! placement-invariance and migration bit-identity suites hold with every
//! tier enabled. Elementwise kernels are bit-stable by construction (each
//! output element is an independent mul/add chain).
//!
//! Kernels never allocate on the hot path: reduction partials live in a
//! stack array of [`PAR_SHARDS`] slots and elementwise kernels write in
//! place.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logical lane width of the reduction shape (f32 lanes in an AVX
/// register). All tiers accumulate into this many independent lanes, so
/// the width is part of the result's identity, not an optimization knob.
pub const LANES: usize = 8;

/// Below this element count an operation is always a single shard (no
/// threading) — the fixed point of the shard plan for small tensors.
pub const PAR_MIN: usize = 1 << 15;

/// Shard count for large operations. Fixed (never derived from the thread
/// count) so the reduction shape is a function of `len` alone.
pub const PAR_SHARDS: usize = 16;

// ---- dispatch configuration ---------------------------------------------

/// Requested worker count. `usize::MAX` = unset (read `PUSH_KERNEL_THREADS`
/// on first use), `0` = auto.
static THREADS_CFG: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Test hook: 0 = auto-detect, 1 = force scalar, 2 = force SSE2,
/// 3 = force AVX (clamped to what the CPU supports).
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Vector instruction set a kernel range executes with. Which one runs
/// never changes results — that is the bit-identity invariant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Sse2,
    Avx,
}

/// Set the kernel-plane thread target (`push train --kernel-threads N`).
/// 0 = auto (`available_parallelism`, capped). The pool itself is built
/// once, on first parallel dispatch; later calls only gate whether large
/// ops run inline or on the pool. Results are identical either way.
pub fn set_threads(n: usize) {
    THREADS_CFG.store(n, Ordering::Relaxed);
}

/// Effective thread target (>= 1). Resolves `PUSH_KERNEL_THREADS` on first
/// call; 0/unset means auto.
pub fn threads() -> usize {
    let mut t = THREADS_CFG.load(Ordering::Relaxed);
    if t == usize::MAX {
        t = std::env::var("PUSH_KERNEL_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        THREADS_CFG.store(t, Ordering::Relaxed);
    }
    if t == 0 {
        auto_threads()
    } else {
        t
    }
}

fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    })
}

/// Test hook: pin the vector tier (None = auto-detect). Forcing a wider
/// backend than the CPU supports clamps down; forcing anything without the
/// `simd` feature is a no-op (the scalar tier is all there is).
pub fn force_backend(b: Option<Backend>) {
    let v = match b {
        None => 0,
        Some(Backend::Scalar) => 1,
        Some(Backend::Sse2) => 2,
        Some(Backend::Avx) => 3,
    };
    FORCED.store(v, Ordering::Relaxed);
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detected() -> Backend {
    static DET: OnceLock<Backend> = OnceLock::new();
    *DET.get_or_init(|| {
        if is_x86_feature_detected!("avx") {
            Backend::Avx
        } else {
            // SSE2 is the x86_64 baseline — always present.
            Backend::Sse2
        }
    })
}

/// The vector tier ranges execute with right now (runtime width
/// detection, or the [`force_backend`] override clamped to the CPU).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn backend() -> Backend {
    let b = match FORCED.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Sse2,
        3 => Backend::Avx,
        _ => detected(),
    };
    if b == Backend::Avx && detected() != Backend::Avx {
        return Backend::Sse2;
    }
    b
}

/// Without the `simd` feature (or off x86_64) the scalar oracle is the
/// only tier.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn backend() -> Backend {
    Backend::Scalar
}

/// Every tier this build + CPU can execute (the property suite's axis).
pub fn available_backends() -> Vec<Backend> {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        let mut v = vec![Backend::Scalar, Backend::Sse2];
        if detected() == Backend::Avx {
            v.push(Backend::Avx);
        }
        v
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        vec![Backend::Scalar]
    }
}

// ---- the fixed reduction shape ------------------------------------------

/// Shard plan: `(shards, chunk)`, a function of `len` only.
#[inline]
fn shard_plan(len: usize) -> (usize, usize) {
    if len >= PAR_MIN {
        (PAR_SHARDS, (len + PAR_SHARDS - 1) / PAR_SHARDS)
    } else {
        (1, len)
    }
}

#[inline]
fn shard_range(s: usize, chunk: usize, len: usize) -> (usize, usize) {
    let lo = (s * chunk).min(len);
    let hi = (lo + chunk).min(len);
    (lo, hi)
}

/// Reduction kinds sharing the lane-blocked shape. `Max` has no intrinsic
/// path (x86 `maxps` NaN semantics differ from `f32::max`); it still lane-
/// blocks and shards, so every tier folds identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RKind {
    Sum,
    SumSq,
    Dot,
    SqDist,
    Max,
}

impl RKind {
    #[inline]
    fn identity(self) -> f32 {
        match self {
            RKind::Max => f32::NEG_INFINITY,
            _ => 0.0,
        }
    }
}

/// One element's contribution. The SIMD tiers compute this exact
/// expression per lane (mul then add — never FMA).
#[inline(always)]
fn term(kind: RKind, av: f32, bv: f32) -> f32 {
    match kind {
        RKind::Sum => av,
        RKind::SumSq => av * av,
        RKind::Dot => av * bv,
        RKind::SqDist => {
            let d = av - bv;
            d * d
        }
        RKind::Max => av,
    }
}

/// Collapse the 8 lane accumulators through the fixed pairwise tree.
#[inline]
fn tree8(kind: RKind, l: [f32; LANES]) -> f32 {
    match kind {
        RKind::Max => {
            (l[0].max(l[1]).max(l[2].max(l[3]))).max(l[4].max(l[5]).max(l[6].max(l[7])))
        }
        _ => ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7])),
    }
}

/// Combine shard partials sequentially in shard order.
#[inline]
fn combine(kind: RKind, partials: &[f32]) -> f32 {
    let mut acc = partials[0];
    for &p in &partials[1..] {
        acc = match kind {
            RKind::Max => acc.max(p),
            _ => acc + p,
        };
    }
    acc
}

// ---- scalar tier (the oracle) -------------------------------------------

mod scalar {
    use super::{term, RKind, LANES};

    /// Lane-blocked reduction over one contiguous range: lane `j`
    /// accumulates elements `j, j+LANES, …`, tail elements land on lanes
    /// `0..tail_len` in order — the exact shape a vector register computes.
    pub(super) fn lanes(kind: RKind, a: &[f32], b: &[f32]) -> [f32; LANES] {
        let mut acc = [kind.identity(); LANES];
        let blocks = a.len() / LANES;
        for blk in 0..blocks {
            let base = blk * LANES;
            for (j, slot) in acc.iter_mut().enumerate() {
                let t = term(kind, a[base + j], b[base + j]);
                *slot = match kind {
                    RKind::Max => slot.max(t),
                    _ => *slot + t,
                };
            }
        }
        let tail = blocks * LANES;
        for (j, &av) in a[tail..].iter().enumerate() {
            let t = term(kind, av, b[tail + j]);
            acc[j] = match kind {
                RKind::Max => acc[j].max(t),
                _ => acc[j] + t,
            };
        }
        acc
    }

    pub(super) fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    pub(super) fn scale(y: &mut [f32], a: f32) {
        for v in y.iter_mut() {
            *v *= a;
        }
    }

    pub(super) fn div_scale(y: &mut [f32], d: f32) {
        for v in y.iter_mut() {
            *v /= d;
        }
    }

    pub(super) fn scale_add(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi = a * *yi + b * xi;
        }
    }

    pub(super) fn scale_add_sq(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi = a * *yi + b * xi * xi;
        }
    }

    /// SVGD row accumulate: u += kg·g + kr·(pj − pi).
    pub(super) fn rbf_accum(u: &mut [f32], kg: f32, g: &[f32], kr: f32, pj: &[f32], pi: &[f32]) {
        for (t, ut) in u.iter_mut().enumerate() {
            *ut += kg * g[t] + kr * (pj[t] - pi[t]);
        }
    }
}

// ---- SIMD tier (x86_64 SSE2 / AVX) --------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! Explicit-intrinsic twins of the scalar kernels. Per lane they
    //! perform the identical mul/add/sub/div sequence (no FMA, no
    //! reassociation), so results are bit-equal to the scalar tier.
    use super::{term, RKind, LANES};
    use core::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn lanes_avx(kind: RKind, a: &[f32], b: &[f32]) -> [f32; LANES] {
        let mut acc = _mm256_setzero_ps();
        let blocks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        for blk in 0..blocks {
            let va = _mm256_loadu_ps(pa.add(blk * LANES));
            let t = match kind {
                RKind::Sum => va,
                RKind::SumSq => _mm256_mul_ps(va, va),
                RKind::Dot => _mm256_mul_ps(va, _mm256_loadu_ps(pb.add(blk * LANES))),
                RKind::SqDist => {
                    let d = _mm256_sub_ps(va, _mm256_loadu_ps(pb.add(blk * LANES)));
                    _mm256_mul_ps(d, d)
                }
                RKind::Max => unreachable!("max reduces on the scalar lane path"),
            };
            acc = _mm256_add_ps(acc, t);
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let tail = blocks * LANES;
        for (j, &av) in a[tail..].iter().enumerate() {
            lanes[j] += term(kind, av, b[tail + j]);
        }
        lanes
    }

    /// # Safety
    /// SSE2 is the x86_64 baseline; callers reach here via [`super::backend`].
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn lanes_sse2(kind: RKind, a: &[f32], b: &[f32]) -> [f32; LANES] {
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        let blocks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        for blk in 0..blocks {
            let base = blk * LANES;
            let va0 = _mm_loadu_ps(pa.add(base));
            let va1 = _mm_loadu_ps(pa.add(base + 4));
            let (t0, t1) = match kind {
                RKind::Sum => (va0, va1),
                RKind::SumSq => (_mm_mul_ps(va0, va0), _mm_mul_ps(va1, va1)),
                RKind::Dot => (
                    _mm_mul_ps(va0, _mm_loadu_ps(pb.add(base))),
                    _mm_mul_ps(va1, _mm_loadu_ps(pb.add(base + 4))),
                ),
                RKind::SqDist => {
                    let d0 = _mm_sub_ps(va0, _mm_loadu_ps(pb.add(base)));
                    let d1 = _mm_sub_ps(va1, _mm_loadu_ps(pb.add(base + 4)));
                    (_mm_mul_ps(d0, d0), _mm_mul_ps(d1, d1))
                }
                RKind::Max => unreachable!("max reduces on the scalar lane path"),
            };
            acc0 = _mm_add_ps(acc0, t0);
            acc1 = _mm_add_ps(acc1, t1);
        }
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc0);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), acc1);
        let tail = blocks * LANES;
        for (j, &av) in a[tail..].iter().enumerate() {
            lanes[j] += term(kind, av, b[tail + j]);
        }
        lanes
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn axpy_avx(y: &mut [f32], a: f32, x: &[f32]) {
        let av = _mm256_set1_ps(a);
        let blocks = y.len() / LANES;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * LANES;
            let yv = _mm256_loadu_ps(py.add(base));
            let xv = _mm256_loadu_ps(px.add(base));
            _mm256_storeu_ps(py.add(base), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
        }
        let tail = blocks * LANES;
        super::scalar::axpy(&mut y[tail..], a, &x[tail..]);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn axpy_sse2(y: &mut [f32], a: f32, x: &[f32]) {
        let av = _mm_set1_ps(a);
        let blocks = y.len() / 4;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * 4;
            let yv = _mm_loadu_ps(py.add(base));
            let xv = _mm_loadu_ps(px.add(base));
            _mm_storeu_ps(py.add(base), _mm_add_ps(yv, _mm_mul_ps(av, xv)));
        }
        let tail = blocks * 4;
        super::scalar::axpy(&mut y[tail..], a, &x[tail..]);
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn scale_avx(y: &mut [f32], a: f32) {
        let av = _mm256_set1_ps(a);
        let blocks = y.len() / LANES;
        let py = y.as_mut_ptr();
        for blk in 0..blocks {
            let base = blk * LANES;
            let yv = _mm256_loadu_ps(py.add(base));
            _mm256_storeu_ps(py.add(base), _mm256_mul_ps(yv, av));
        }
        let tail = blocks * LANES;
        super::scalar::scale(&mut y[tail..], a);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn scale_sse2(y: &mut [f32], a: f32) {
        let av = _mm_set1_ps(a);
        let blocks = y.len() / 4;
        let py = y.as_mut_ptr();
        for blk in 0..blocks {
            let base = blk * 4;
            let yv = _mm_loadu_ps(py.add(base));
            _mm_storeu_ps(py.add(base), _mm_mul_ps(yv, av));
        }
        let tail = blocks * 4;
        super::scalar::scale(&mut y[tail..], a);
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn div_scale_avx(y: &mut [f32], d: f32) {
        let dv = _mm256_set1_ps(d);
        let blocks = y.len() / LANES;
        let py = y.as_mut_ptr();
        for blk in 0..blocks {
            let base = blk * LANES;
            let yv = _mm256_loadu_ps(py.add(base));
            _mm256_storeu_ps(py.add(base), _mm256_div_ps(yv, dv));
        }
        let tail = blocks * LANES;
        super::scalar::div_scale(&mut y[tail..], d);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn div_scale_sse2(y: &mut [f32], d: f32) {
        let dv = _mm_set1_ps(d);
        let blocks = y.len() / 4;
        let py = y.as_mut_ptr();
        for blk in 0..blocks {
            let base = blk * 4;
            let yv = _mm_loadu_ps(py.add(base));
            _mm_storeu_ps(py.add(base), _mm_div_ps(yv, dv));
        }
        let tail = blocks * 4;
        super::scalar::div_scale(&mut y[tail..], d);
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn scale_add_avx(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        let av = _mm256_set1_ps(a);
        let bv = _mm256_set1_ps(b);
        let blocks = y.len() / LANES;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * LANES;
            let yv = _mm256_loadu_ps(py.add(base));
            let xv = _mm256_loadu_ps(px.add(base));
            let r = _mm256_add_ps(_mm256_mul_ps(av, yv), _mm256_mul_ps(bv, xv));
            _mm256_storeu_ps(py.add(base), r);
        }
        let tail = blocks * LANES;
        super::scalar::scale_add(&mut y[tail..], a, b, &x[tail..]);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn scale_add_sse2(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        let av = _mm_set1_ps(a);
        let bv = _mm_set1_ps(b);
        let blocks = y.len() / 4;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * 4;
            let yv = _mm_loadu_ps(py.add(base));
            let xv = _mm_loadu_ps(px.add(base));
            _mm_storeu_ps(py.add(base), _mm_add_ps(_mm_mul_ps(av, yv), _mm_mul_ps(bv, xv)));
        }
        let tail = blocks * 4;
        super::scalar::scale_add(&mut y[tail..], a, b, &x[tail..]);
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn scale_add_sq_avx(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        let av = _mm256_set1_ps(a);
        let bv = _mm256_set1_ps(b);
        let blocks = y.len() / LANES;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * LANES;
            let yv = _mm256_loadu_ps(py.add(base));
            let xv = _mm256_loadu_ps(px.add(base));
            // a*y + (b*x)*x — the scalar tier's exact association
            let r = _mm256_add_ps(
                _mm256_mul_ps(av, yv),
                _mm256_mul_ps(_mm256_mul_ps(bv, xv), xv),
            );
            _mm256_storeu_ps(py.add(base), r);
        }
        let tail = blocks * LANES;
        super::scalar::scale_add_sq(&mut y[tail..], a, b, &x[tail..]);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn scale_add_sq_sse2(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
        let av = _mm_set1_ps(a);
        let bv = _mm_set1_ps(b);
        let blocks = y.len() / 4;
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for blk in 0..blocks {
            let base = blk * 4;
            let yv = _mm_loadu_ps(py.add(base));
            let xv = _mm_loadu_ps(px.add(base));
            let r = _mm_add_ps(_mm_mul_ps(av, yv), _mm_mul_ps(_mm_mul_ps(bv, xv), xv));
            _mm_storeu_ps(py.add(base), r);
        }
        let tail = blocks * 4;
        super::scalar::scale_add_sq(&mut y[tail..], a, b, &x[tail..]);
    }

    /// # Safety
    /// Caller must have verified AVX via [`super::backend`].
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn rbf_accum_avx(
        u: &mut [f32],
        kg: f32,
        g: &[f32],
        kr: f32,
        pj: &[f32],
        pi: &[f32],
    ) {
        let kgv = _mm256_set1_ps(kg);
        let krv = _mm256_set1_ps(kr);
        let blocks = u.len() / LANES;
        let (pu, pg, ppj, ppi) = (u.as_mut_ptr(), g.as_ptr(), pj.as_ptr(), pi.as_ptr());
        for blk in 0..blocks {
            let base = blk * LANES;
            let uv = _mm256_loadu_ps(pu.add(base));
            let gv = _mm256_loadu_ps(pg.add(base));
            let dv = _mm256_sub_ps(_mm256_loadu_ps(ppj.add(base)), _mm256_loadu_ps(ppi.add(base)));
            let r = _mm256_add_ps(uv, _mm256_add_ps(_mm256_mul_ps(kgv, gv), _mm256_mul_ps(krv, dv)));
            _mm256_storeu_ps(pu.add(base), r);
        }
        let tail = blocks * LANES;
        super::scalar::rbf_accum(&mut u[tail..], kg, &g[tail..], kr, &pj[tail..], &pi[tail..]);
    }

    /// # Safety
    /// SSE2 baseline (see [`lanes_sse2`]).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn rbf_accum_sse2(
        u: &mut [f32],
        kg: f32,
        g: &[f32],
        kr: f32,
        pj: &[f32],
        pi: &[f32],
    ) {
        let kgv = _mm_set1_ps(kg);
        let krv = _mm_set1_ps(kr);
        let blocks = u.len() / 4;
        let (pu, pg, ppj, ppi) = (u.as_mut_ptr(), g.as_ptr(), pj.as_ptr(), pi.as_ptr());
        for blk in 0..blocks {
            let base = blk * 4;
            let uv = _mm_loadu_ps(pu.add(base));
            let gv = _mm_loadu_ps(pg.add(base));
            let dv = _mm_sub_ps(_mm_loadu_ps(ppj.add(base)), _mm_loadu_ps(ppi.add(base)));
            let r = _mm_add_ps(uv, _mm_add_ps(_mm_mul_ps(kgv, gv), _mm_mul_ps(krv, dv)));
            _mm_storeu_ps(pu.add(base), r);
        }
        let tail = blocks * 4;
        super::scalar::rbf_accum(&mut u[tail..], kg, &g[tail..], kr, &pj[tail..], &pi[tail..]);
    }
}

// ---- range dispatch (one contiguous shard) ------------------------------

fn lanes_range(kind: RKind, a: &[f32], b: &[f32]) -> [f32; LANES] {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if kind != RKind::Max {
        match backend() {
            // Safety: the backend was runtime-detected (or clamped to it).
            Backend::Avx => return unsafe { x86::lanes_avx(kind, a, b) },
            Backend::Sse2 => return unsafe { x86::lanes_sse2(kind, a, b) },
            Backend::Scalar => {}
        }
    }
    scalar::lanes(kind, a, b)
}

macro_rules! ew_dispatch {
    ($avx:path, $sse2:path, $scalar:path, ($($arg:expr),*)) => {{
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        match backend() {
            // Safety: the backend was runtime-detected (or clamped to it).
            Backend::Avx => return unsafe { $avx($($arg),*) },
            Backend::Sse2 => return unsafe { $sse2($($arg),*) },
            Backend::Scalar => {}
        }
        $scalar($($arg),*)
    }};
}

fn axpy_range(y: &mut [f32], a: f32, x: &[f32]) {
    ew_dispatch!(x86::axpy_avx, x86::axpy_sse2, scalar::axpy, (y, a, x))
}

fn scale_range(y: &mut [f32], a: f32) {
    ew_dispatch!(x86::scale_avx, x86::scale_sse2, scalar::scale, (y, a))
}

fn div_scale_range(y: &mut [f32], d: f32) {
    ew_dispatch!(x86::div_scale_avx, x86::div_scale_sse2, scalar::div_scale, (y, d))
}

fn scale_add_range(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
    ew_dispatch!(x86::scale_add_avx, x86::scale_add_sse2, scalar::scale_add, (y, a, b, x))
}

fn scale_add_sq_range(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
    ew_dispatch!(
        x86::scale_add_sq_avx,
        x86::scale_add_sq_sse2,
        scalar::scale_add_sq,
        (y, a, b, x)
    )
}

fn rbf_accum_range(u: &mut [f32], kg: f32, g: &[f32], kr: f32, pj: &[f32], pi: &[f32]) {
    ew_dispatch!(
        x86::rbf_accum_avx,
        x86::rbf_accum_sse2,
        scalar::rbf_accum,
        (u, kg, g, kr, pj, pi)
    )
}

// ---- public kernels ------------------------------------------------------

/// y += a·x (elementwise).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "axpy: length mismatch");
    let (shards, chunk) = shard_plan(y.len());
    if shards > 1
        && threads() > 1
        && pool::run(pool::Job::axpy(y, a, x), shards, chunk, y.len())
    {
        return;
    }
    axpy_range(y, a, x);
}

/// y *= a (elementwise).
pub fn scale(y: &mut [f32], a: f32) {
    let (shards, chunk) = shard_plan(y.len());
    if shards > 1 && threads() > 1 && pool::run(pool::Job::scale(y, a), shards, chunk, y.len()) {
        return;
    }
    scale_range(y, a);
}

/// y /= d (elementwise; true division, not multiply-by-reciprocal, so the
/// result matches the scalar `/=` it replaced bit for bit).
pub fn div_scale(y: &mut [f32], d: f32) {
    let (shards, chunk) = shard_plan(y.len());
    if shards > 1
        && threads() > 1
        && pool::run(pool::Job::div_scale(y, d), shards, chunk, y.len())
    {
        return;
    }
    div_scale_range(y, d);
}

/// y = a·y + b·x (elementwise).
pub fn scale_add(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "scale_add: length mismatch");
    let (shards, chunk) = shard_plan(y.len());
    if shards > 1
        && threads() > 1
        && pool::run(pool::Job::scale_add(y, a, b, x), shards, chunk, y.len())
    {
        return;
    }
    scale_add_range(y, a, b, x);
}

/// y = a·y + b·x² (elementwise).
pub fn scale_add_sq(y: &mut [f32], a: f32, b: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "scale_add_sq: length mismatch");
    let (shards, chunk) = shard_plan(y.len());
    if shards > 1
        && threads() > 1
        && pool::run(pool::Job::scale_add_sq(y, a, b, x), shards, chunk, y.len())
    {
        return;
    }
    scale_add_sq_range(y, a, b, x);
}

/// SVGD row accumulate: u += kg·g + kr·(pj − pi) (elementwise).
pub fn rbf_accum(u: &mut [f32], kg: f32, g: &[f32], kr: f32, pj: &[f32], pi: &[f32]) {
    assert!(
        g.len() == u.len() && pj.len() == u.len() && pi.len() == u.len(),
        "rbf_accum: length mismatch"
    );
    let (shards, chunk) = shard_plan(u.len());
    if shards > 1
        && threads() > 1
        && pool::run(pool::Job::rbf_accum(u, kg, g, kr, pj, pi), shards, chunk, u.len())
    {
        return;
    }
    rbf_accum_range(u, kg, g, kr, pj, pi);
}

fn reduce(kind: RKind, a: &[f32], b: &[f32]) -> f32 {
    let len = a.len();
    let (shards, chunk) = shard_plan(len);
    let mut partials = [0.0f32; PAR_SHARDS];
    let pooled = shards > 1
        && threads() > 1
        && pool::run(pool::Job::reduce(kind, a, b, &mut partials), shards, chunk, len);
    if !pooled {
        for (s, slot) in partials.iter_mut().enumerate().take(shards) {
            let (lo, hi) = shard_range(s, chunk, len);
            *slot = if lo >= hi {
                kind.identity()
            } else {
                tree8(kind, lanes_range(kind, &a[lo..hi], &b[lo..hi]))
            };
        }
    }
    combine(kind, &partials[..shards])
}

/// Σ x, fixed-shape. 0.0 for an empty slice.
pub fn sum(x: &[f32]) -> f32 {
    reduce(RKind::Sum, x, x)
}

/// Σ x², fixed-shape.
pub fn sum_sq(x: &[f32]) -> f32 {
    reduce(RKind::SumSq, x, x)
}

/// Σ x·y, fixed-shape.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len(), "dot: length mismatch");
    reduce(RKind::Dot, x, y)
}

/// Σ (a−b)², fixed-shape.
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "sq_dist: length mismatch");
    reduce(RKind::SqDist, a, b)
}

/// Max via `f32::max` folds (NaN-ignoring unless all-NaN). No intrinsic
/// path — `maxps` treats NaN differently — but still lane-blocked and
/// thread-shardable. `NEG_INFINITY` for an empty slice.
pub fn max(x: &[f32]) -> f32 {
    reduce(RKind::Max, x, x)
}

/// Mean with the historical `len.max(1)` guard (0.0 for empty).
pub fn mean(x: &[f32]) -> f32 {
    sum(x) / x.len().max(1) as f32
}

/// √(Σ x²).
pub fn l2_norm(x: &[f32]) -> f32 {
    sum_sq(x).sqrt()
}

/// First-max-wins argmax (the vote/accuracy tie-break). 0 for an empty row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    best
}

/// Row-max-stabilized softmax in place; returns `(row_max, z)` where `z`
/// is the pre-normalization Σ exp(v − max) — the pieces the CE loss needs.
pub fn softmax(row: &mut [f32]) -> (f32, f32) {
    let m = max(row);
    for v in row.iter_mut() {
        *v = (*v - m).exp();
    }
    let z = sum(row);
    div_scale(row, z);
    (m, z)
}

/// Fused GEMV scatter: out += x[k]·w_row(k) for each input k, where `w` is
/// row-major `[din, dout]`. The affine microkernel behind the MLP / conv
/// head layers (bias is pre-copied into `out` by the caller).
pub fn gemv_scatter(out: &mut [f32], x: &[f32], w: &[f32]) {
    let dout = out.len();
    assert_eq!(x.len() * dout, w.len(), "gemv_scatter: shape mismatch");
    for (k, &xk) in x.iter().enumerate() {
        axpy_range(out, xk, &w[k * dout..(k + 1) * dout]);
    }
}

/// Fused activation pass: applies `act` in place and returns the smallest
/// |pre-activation| seen (`INFINITY` for an empty row) — the gradcheck
/// kink margin.
pub fn act_margin(row: &mut [f32], act: impl Fn(f32) -> f32) -> f32 {
    let mut margin = f32::INFINITY;
    for v in row.iter_mut() {
        margin = margin.min(v.abs());
        *v = act(*v);
    }
    margin
}

// ---- the fixed-size worker pool -----------------------------------------

mod pool {
    //! A fixed-size shard pool. Tasks publish through an epoch-stamped
    //! slot; workers (and the caller) drain shard indices from a shared
    //! counter. Shard geometry comes from `shard_plan`, never from the
    //! worker count, so helping threads change wall-clock, not bits.

    use super::{lanes_range, shard_range, tree8, RKind};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex, OnceLock};

    #[derive(Clone, Copy)]
    pub(super) struct ConstPtr(*const f32);
    // Safety: raw views into caller buffers; the caller blocks until
    // `pending == 0`, keeping them alive, and shards never overlap.
    unsafe impl Send for ConstPtr {}
    unsafe impl Sync for ConstPtr {}

    #[derive(Clone, Copy)]
    pub(super) struct MutPtr(*mut f32);
    // Safety: as above — disjoint shard ranges, caller outlives the task.
    unsafe impl Send for MutPtr {}
    unsafe impl Sync for MutPtr {}

    #[derive(Clone, Copy)]
    pub(super) enum Job {
        Axpy { y: MutPtr, x: ConstPtr, a: f32 },
        Scale { y: MutPtr, a: f32 },
        DivScale { y: MutPtr, d: f32 },
        ScaleAdd { y: MutPtr, x: ConstPtr, a: f32, b: f32 },
        ScaleAddSq { y: MutPtr, x: ConstPtr, a: f32, b: f32 },
        RbfAccum { u: MutPtr, g: ConstPtr, pj: ConstPtr, pi: ConstPtr, kg: f32, kr: f32 },
        Reduce { kind: RKind, a: ConstPtr, b: ConstPtr, partials: MutPtr },
    }

    impl Job {
        pub(super) fn axpy(y: &mut [f32], a: f32, x: &[f32]) -> Job {
            Job::Axpy { y: MutPtr(y.as_mut_ptr()), x: ConstPtr(x.as_ptr()), a }
        }
        pub(super) fn scale(y: &mut [f32], a: f32) -> Job {
            Job::Scale { y: MutPtr(y.as_mut_ptr()), a }
        }
        pub(super) fn div_scale(y: &mut [f32], d: f32) -> Job {
            Job::DivScale { y: MutPtr(y.as_mut_ptr()), d }
        }
        pub(super) fn scale_add(y: &mut [f32], a: f32, b: f32, x: &[f32]) -> Job {
            Job::ScaleAdd { y: MutPtr(y.as_mut_ptr()), x: ConstPtr(x.as_ptr()), a, b }
        }
        pub(super) fn scale_add_sq(y: &mut [f32], a: f32, b: f32, x: &[f32]) -> Job {
            Job::ScaleAddSq { y: MutPtr(y.as_mut_ptr()), x: ConstPtr(x.as_ptr()), a, b }
        }
        pub(super) fn rbf_accum(
            u: &mut [f32],
            kg: f32,
            g: &[f32],
            kr: f32,
            pj: &[f32],
            pi: &[f32],
        ) -> Job {
            Job::RbfAccum {
                u: MutPtr(u.as_mut_ptr()),
                g: ConstPtr(g.as_ptr()),
                pj: ConstPtr(pj.as_ptr()),
                pi: ConstPtr(pi.as_ptr()),
                kg,
                kr,
            }
        }
        pub(super) fn reduce(kind: RKind, a: &[f32], b: &[f32], partials: &mut [f32]) -> Job {
            Job::Reduce {
                kind,
                a: ConstPtr(a.as_ptr()),
                b: ConstPtr(b.as_ptr()),
                partials: MutPtr(partials.as_mut_ptr()),
            }
        }

        /// Run shard `s`.
        ///
        /// # Safety
        /// `Pool::execute` guarantees the backing buffers outlive the task
        /// (the caller blocks on `pending`) and `(s, chunk, len)` ranges
        /// are disjoint across shards.
        unsafe fn run_shard(&self, s: usize, chunk: usize, len: usize) {
            let (lo, hi) = shard_range(s, chunk, len);
            let n = hi.saturating_sub(lo);
            match *self {
                Job::Axpy { y, x, a } => {
                    if n == 0 {
                        return;
                    }
                    super::axpy_range(
                        std::slice::from_raw_parts_mut(y.0.add(lo), n),
                        a,
                        std::slice::from_raw_parts(x.0.add(lo), n),
                    );
                }
                Job::Scale { y, a } => {
                    if n == 0 {
                        return;
                    }
                    super::scale_range(std::slice::from_raw_parts_mut(y.0.add(lo), n), a);
                }
                Job::DivScale { y, d } => {
                    if n == 0 {
                        return;
                    }
                    super::div_scale_range(std::slice::from_raw_parts_mut(y.0.add(lo), n), d);
                }
                Job::ScaleAdd { y, x, a, b } => {
                    if n == 0 {
                        return;
                    }
                    super::scale_add_range(
                        std::slice::from_raw_parts_mut(y.0.add(lo), n),
                        a,
                        b,
                        std::slice::from_raw_parts(x.0.add(lo), n),
                    );
                }
                Job::ScaleAddSq { y, x, a, b } => {
                    if n == 0 {
                        return;
                    }
                    super::scale_add_sq_range(
                        std::slice::from_raw_parts_mut(y.0.add(lo), n),
                        a,
                        b,
                        std::slice::from_raw_parts(x.0.add(lo), n),
                    );
                }
                Job::RbfAccum { u, g, pj, pi, kg, kr } => {
                    if n == 0 {
                        return;
                    }
                    super::rbf_accum_range(
                        std::slice::from_raw_parts_mut(u.0.add(lo), n),
                        kg,
                        std::slice::from_raw_parts(g.0.add(lo), n),
                        kr,
                        std::slice::from_raw_parts(pj.0.add(lo), n),
                        std::slice::from_raw_parts(pi.0.add(lo), n),
                    );
                }
                Job::Reduce { kind, a, b, partials } => {
                    let part = if n == 0 {
                        kind.identity()
                    } else {
                        tree8(
                            kind,
                            lanes_range(
                                kind,
                                std::slice::from_raw_parts(a.0.add(lo), n),
                                std::slice::from_raw_parts(b.0.add(lo), n),
                            ),
                        )
                    };
                    *partials.0.add(s) = part;
                }
            }
        }
    }

    struct Task {
        job: Job,
        shards: usize,
        chunk: usize,
        len: usize,
        next: AtomicUsize,
        pending: AtomicUsize,
    }

    impl Task {
        fn drain(&self) {
            loop {
                let s = self.next.fetch_add(1, Ordering::Relaxed);
                if s >= self.shards {
                    break;
                }
                // Safety: see `Job::run_shard` — disjoint shards, caller
                // keeps buffers alive until `pending` hits zero.
                unsafe { self.job.run_shard(s, self.chunk, self.len) };
                self.pending.fetch_sub(1, Ordering::Release);
            }
        }
    }

    #[allow(clippy::type_complexity)]
    struct Shared {
        cur: Mutex<(u64, Option<Arc<Task>>)>,
        cv: Condvar,
    }

    struct Pool {
        shared: Arc<Shared>,
        workers: usize,
    }

    impl Pool {
        fn build() -> Pool {
            let shared = Arc::new(Shared { cur: Mutex::new((0, None)), cv: Condvar::new() });
            // Fixed size: the thread target at first parallel dispatch,
            // minus the calling thread (which always helps drain).
            let target = super::threads().clamp(1, 16) - 1;
            let mut workers = 0;
            for i in 0..target {
                let sh = shared.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("push-kernel-{i}"))
                    .spawn(move || worker_loop(sh));
                if spawned.is_ok() {
                    workers += 1;
                }
            }
            Pool { shared, workers }
        }

        fn execute(&self, job: Job, shards: usize, chunk: usize, len: usize) {
            let task = Arc::new(Task {
                job,
                shards,
                chunk,
                len,
                next: AtomicUsize::new(0),
                pending: AtomicUsize::new(shards),
            });
            {
                let mut g = self.shared.cur.lock().unwrap_or_else(|e| e.into_inner());
                g.0 = g.0.wrapping_add(1);
                g.1 = Some(task.clone());
            }
            self.shared.cv.notify_all();
            task.drain();
            while task.pending.load(Ordering::Acquire) != 0 {
                std::thread::yield_now();
            }
        }
    }

    fn worker_loop(shared: Arc<Shared>) {
        let mut seen = 0u64;
        loop {
            let task = {
                let mut g = shared.cur.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if g.0 != seen {
                        seen = g.0;
                        if let Some(t) = g.1.clone() {
                            break t;
                        }
                    }
                    g = shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            };
            task.drain();
        }
    }

    fn pool() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(Pool::build)
    }

    /// Run `job` over `shards` on the pool (caller helps). Returns false
    /// when no worker could be spawned, so the caller falls back inline.
    pub(super) fn run(job: Job, shards: usize, chunk: usize, len: usize) -> bool {
        let p = pool();
        if p.workers == 0 {
            return false;
        }
        p.execute(job, shards, chunk, len);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Kernel tests mutate the global dispatch knobs; serialize them.
    /// (Bit-identity means concurrent *users* of the kernels are unaffected
    /// by whatever a test forces — only tests comparing tiers need the
    /// lock.)
    fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
        static M: Mutex<()> = Mutex::new(());
        M.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn fill(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect()
    }

    #[test]
    fn shard_plan_is_len_keyed() {
        assert_eq!(shard_plan(0), (1, 0));
        assert_eq!(shard_plan(PAR_MIN - 1), (1, PAR_MIN - 1));
        let (s, c) = shard_plan(PAR_MIN);
        assert_eq!(s, PAR_SHARDS);
        assert_eq!(c, PAR_MIN / PAR_SHARDS);
        // ragged: the last shard is short but the plan still covers len
        let (s2, c2) = shard_plan(PAR_MIN + 1);
        assert_eq!(s2, PAR_SHARDS);
        assert!(c2 * s2 >= PAR_MIN + 1);
    }

    #[test]
    fn reduction_matches_naive_within_tolerance() {
        let x = fill(7, 1003);
        let naive: f32 = x.iter().sum();
        assert!((sum(&x) - naive).abs() < 1e-3 * naive.abs().max(1.0));
        assert_eq!(max(&x), x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)));
    }

    #[test]
    fn empty_and_single_element_identities() {
        assert_eq!(sum(&[]), 0.0);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(l2_norm(&[]), 0.0);
        assert_eq!(max(&[]), f32::NEG_INFINITY);
        assert_eq!(sum(&[3.5]), 3.5);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn threaded_reduction_is_bit_identical() {
        let _g = dispatch_lock();
        let x = fill(11, 50_000);
        let y = fill(13, 50_000);
        set_threads(1);
        let inline = (sum(&x), dot(&x, &y), sum_sq(&x), sq_dist(&x, &y), max(&x));
        set_threads(4);
        let pooled = (sum(&x), dot(&x, &y), sum_sq(&x), sq_dist(&x, &y), max(&x));
        set_threads(0);
        assert_eq!(inline.0.to_bits(), pooled.0.to_bits());
        assert_eq!(inline.1.to_bits(), pooled.1.to_bits());
        assert_eq!(inline.2.to_bits(), pooled.2.to_bits());
        assert_eq!(inline.3.to_bits(), pooled.3.to_bits());
        assert_eq!(inline.4.to_bits(), pooled.4.to_bits());
    }

    #[test]
    fn threaded_elementwise_is_bit_identical() {
        let _g = dispatch_lock();
        let x = fill(17, 50_000);
        let mut a = fill(19, 50_000);
        let mut b = a.clone();
        set_threads(1);
        axpy(&mut a, 0.37, &x);
        set_threads(4);
        axpy(&mut b, 0.37, &x);
        set_threads(0);
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn backends_agree_bitwise() {
        let _g = dispatch_lock();
        let x = fill(23, 517);
        let y = fill(29, 517);
        let mut results: Vec<(u32, u32)> = Vec::new();
        for be in available_backends() {
            force_backend(Some(be));
            results.push((sum(&x).to_bits(), dot(&x, &y).to_bits()));
        }
        force_backend(None);
        for w in results.windows(2) {
            assert_eq!(w[0], w[1]);
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut row = vec![1.0f32, 2.0, 3.0];
        let (m, _z) = softmax(&mut row);
        assert_eq!(m, 3.0);
        assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn gemv_scatter_matches_manual() {
        // out = x · W with W row-major [2, 3]
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = [10.0f32, 100.0];
        let mut out = [0.0f32; 3];
        gemv_scatter(&mut out, &x, &w);
        assert_eq!(out, [410.0, 520.0, 630.0]);
    }

    #[test]
    fn act_margin_tracks_preactivation() {
        let mut row = vec![-0.5f32, 2.0, 0.25];
        let margin = act_margin(&mut row, |v| v.max(0.0));
        assert_eq!(margin, 0.25);
        assert_eq!(row, vec![0.0, 2.0, 0.25]);
    }
}
