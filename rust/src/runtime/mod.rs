//! Runtime layer: host tensors, the artifact manifest (L2/L3 contract), and
//! the per-device PJRT client that loads and executes `artifacts/*.hlo.txt`.

pub mod client;
pub mod kernels;
pub mod manifest;
pub mod tensor;

pub use client::{ArtifactId, ClientStats, RuntimeClient};
#[cfg(feature = "pjrt")]
pub use client::{literal_to_tensor, tensor_to_literal};
pub use manifest::{EntrySpec, Manifest, ModelSpec, SvgdSpec, TensorSpec};
pub use tensor::{DType, Tensor, TensorData};

use std::path::PathBuf;

/// Default artifacts directory: `$PUSH_ARTIFACTS` or `<repo>/artifacts`.
/// Falls back to walking up from the executable for `cargo run --example`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PUSH_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // CARGO_MANIFEST_DIR is compiled in for tests/examples built in-repo.
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    repo.join("artifacts")
}
