//! PJRT runtime client: load HLO-text artifacts, compile once, execute many.
//!
//! One `RuntimeClient` per simulated device thread (see device::worker). The
//! underlying `xla` crate types wrap raw C++ pointers and are deliberately
//! kept !Send — a client is created ON its device thread and never leaves
//! it; only host `Tensor`s cross threads.
//!
//! The `xla` crate is a heavy native dependency (it links the xla_extension
//! C++ runtime), so it is gated behind the `pjrt` cargo feature. The
//! default build compiles a native stub with the same API that errors at
//! artifact-execution time — everything that doesn't touch PJRT (the NEL,
//! cache, tensor plane, native SVGD math, benches over them) stays fully
//! functional and hermetic.
//!
//! Executables are keyed by *interned artifact id*: the first `load` of a
//! path assigns a dense `ArtifactId` index, and the hot path (`execute`)
//! does exactly one `HashMap<PathBuf>` probe to resolve it, then indexes a
//! `Vec` — the previous path-keyed cache probed the map three times per
//! job. Hot loops that hold an `ArtifactId` can call `execute_id` and skip
//! the path probe entirely.
//!
//! Artifacts are HLO *text* (jax >= 0.5 serialized protos use 64-bit ids
//! that xla_extension 0.5.1 rejects); `HloModuleProto::from_text_file`
//! reassigns ids. All entries are lowered with return_tuple=True, so every
//! execution result is a tuple literal that we decompose positionally.

/// Cumulative execution counters, used by the perf pass and device stats.
#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
}

/// Dense per-client handle for a loaded artifact. Only meaningful for the
/// `RuntimeClient` that issued it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactId(pub(crate) u32);

impl ArtifactId {
    fn index(self) -> usize {
        self.0 as usize
    }
}

/// Path -> dense-id interner shared by both backends, so their
/// `ArtifactId` assignment can never drift apart.
#[derive(Default)]
struct PathInterner {
    ids: std::collections::HashMap<std::path::PathBuf, ArtifactId>,
    paths: Vec<std::path::PathBuf>,
}

// Which accessors are live depends on the active backend.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
impl PathInterner {
    /// One map probe; assigns the next dense id on first sight.
    fn intern(&mut self, path: &std::path::Path) -> ArtifactId {
        if let Some(id) = self.ids.get(path) {
            return *id;
        }
        let id = ArtifactId(self.paths.len() as u32);
        self.ids.insert(path.to_path_buf(), id);
        self.paths.push(path.to_path_buf());
        id
    }

    fn get(&self, path: &std::path::Path) -> Option<ArtifactId> {
        self.ids.get(path).copied()
    }

    fn path(&self, id: ArtifactId) -> &std::path::Path {
        &self.paths[id.index()]
    }

    fn len(&self) -> usize {
        self.paths.len()
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::path::Path;
    use std::time::Instant;

    use anyhow::{anyhow, bail, Context, Result};
    use xla::{
        ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation,
    };

    use super::{ArtifactId, ClientStats};
    use crate::runtime::tensor::{DType, Tensor, TensorData};

    fn element_type(dt: DType) -> ElementType {
        match dt {
            DType::F32 => ElementType::F32,
            DType::I32 => ElementType::S32,
            DType::U32 => ElementType::U32,
        }
    }

    /// Reinterpret the tensor's logical window as raw bytes. All contract
    /// dtypes are 4-byte plain-old-data; this also works for zero-copy row
    /// views (the slice accessors apply the view offset).
    fn to_bytes(t: &Tensor) -> &[u8] {
        unsafe {
            match t.dtype() {
                DType::F32 => {
                    let v = t.as_f32();
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
                DType::I32 => {
                    let v = t.as_i32();
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
                DType::U32 => {
                    let v = t.as_u32();
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
            }
        }
    }

    pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            element_type(t.dtype()),
            &t.shape,
            to_bytes(t),
        )
        .map_err(|e| anyhow!("literal from tensor {:?}: {e:?}", t.shape))
    }

    pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow!("literal ty: {e:?}"))?;
        let data = match ty {
            ElementType::F32 => TensorData::f32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
            ),
            ElementType::S32 => TensorData::i32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?,
            ),
            ElementType::U32 => TensorData::u32(
                lit.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e:?}"))?,
            ),
            other => bail!("dtype {other:?} outside the L2/L3 contract"),
        };
        Ok(Tensor::new(dims, data))
    }

    /// A per-device PJRT CPU client with an executable cache keyed by
    /// interned artifact id. NOT Send/Sync by construction — lives on one
    /// device thread.
    pub struct RuntimeClient {
        client: PjRtClient,
        interner: super::PathInterner,
        /// Compiled executables, indexed by `ArtifactId` (parallel to the
        /// interner's dense ids).
        exes: Vec<Option<PjRtLoadedExecutable>>,
        pub stats: ClientStats,
    }

    impl RuntimeClient {
        pub fn cpu() -> Result<RuntimeClient> {
            let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(RuntimeClient {
                client,
                interner: super::PathInterner::default(),
                exes: Vec::new(),
                stats: ClientStats::default(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Intern `path` into a dense artifact id (no compilation yet).
        /// The single `HashMap` probe on the execute hot path lives here.
        pub fn intern(&mut self, path: &Path) -> ArtifactId {
            let id = self.interner.intern(path);
            if self.exes.len() < self.interner.len() {
                self.exes.resize_with(self.interner.len(), || None);
            }
            id
        }

        fn ensure_compiled(&mut self, id: ArtifactId) -> Result<()> {
            if self.exes[id.index()].is_some() {
                return Ok(());
            }
            let path = self.interner.path(id).to_path_buf();
            let t0 = Instant::now();
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            self.exes[id.index()] = Some(exe);
            Ok(())
        }

        /// Compile (or fetch from cache) the artifact at `path`, returning
        /// its interned id for probe-free `execute_id` calls.
        pub fn load(&mut self, path: &Path) -> Result<ArtifactId> {
            let id = self.intern(path);
            self.ensure_compiled(id)?;
            Ok(id)
        }

        /// Execute the artifact at `path` with host tensors, returning host
        /// tensors. One map probe (intern), then index by id.
        pub fn execute(&mut self, path: &Path, args: &[Tensor]) -> Result<Vec<Tensor>> {
            let id = self.intern(path);
            self.execute_id(id, args)
        }

        /// Execute a previously interned artifact. No `HashMap` probes.
        /// The artifact's return_tuple=True output is decomposed.
        pub fn execute_id(&mut self, id: ArtifactId, args: &[Tensor]) -> Result<Vec<Tensor>> {
            self.ensure_compiled(id)?;
            let path = self.interner.path(id);
            let lits: Vec<Literal> = args
                .iter()
                .map(tensor_to_literal)
                .collect::<Result<_>>()
                .with_context(|| format!("args for {path:?}"))?;
            let exe = self.exes[id.index()].as_ref().expect("compiled above");
            let t0 = Instant::now();
            let outs = exe
                .execute::<Literal>(&lits)
                .map_err(|e| anyhow!("executing {path:?}: {e:?}"))?;
            let result = outs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {path:?}: {e:?}"))?;
            self.stats.executions += 1;
            self.stats.execute_secs += t0.elapsed().as_secs_f64();
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow!("decomposing tuple: {e:?}"))?;
            parts.iter().map(literal_to_tensor).collect()
        }

        /// Drop a cached executable (used by cache-pressure tests). The
        /// interned id stays valid and recompiles on next use.
        pub fn evict(&mut self, path: &Path) -> bool {
            match self.interner.get(path) {
                Some(id) => self.exes[id.index()].take().is_some(),
                None => false,
            }
        }

        pub fn cached_executables(&self) -> usize {
            self.exes.iter().filter(|e| e.is_some()).count()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{literal_to_tensor, tensor_to_literal, RuntimeClient};

#[cfg(not(feature = "pjrt"))]
mod native_backend {
    use std::path::Path;

    use anyhow::Result;

    use super::{ArtifactId, ClientStats};
    use crate::runtime::tensor::Tensor;

    fn unavailable(path: &Path) -> anyhow::Error {
        anyhow::anyhow!(
            "cannot execute artifact {path:?}: push was built without the `pjrt` \
             feature. Rebuild with `cargo build --features pjrt` (after `make \
             artifacts`) to enable the XLA PJRT runtime."
        )
    }

    /// Hermetic stand-in for the PJRT client: same API (including artifact
    /// interning), no native deps. Artifact execution fails with a clear
    /// message; everything else works so the NEL/device machinery and the
    /// micro-benches can run without XLA.
    pub struct RuntimeClient {
        interner: super::PathInterner,
        pub stats: ClientStats,
    }

    impl RuntimeClient {
        pub fn cpu() -> Result<RuntimeClient> {
            Ok(RuntimeClient {
                interner: super::PathInterner::default(),
                stats: ClientStats::default(),
            })
        }

        pub fn platform(&self) -> String {
            "native-stub (built without the `pjrt` feature)".to_string()
        }

        pub fn intern(&mut self, path: &Path) -> ArtifactId {
            self.interner.intern(path)
        }

        /// Artifact compilation always fails in the stub.
        pub fn load(&mut self, path: &Path) -> Result<ArtifactId> {
            Err(unavailable(path))
        }

        pub fn execute(&mut self, path: &Path, _args: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(unavailable(path))
        }

        pub fn execute_id(&mut self, id: ArtifactId, _args: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(unavailable(self.interner.path(id)))
        }

        pub fn evict(&mut self, _path: &Path) -> bool {
            false
        }

        pub fn cached_executables(&self) -> usize {
            0
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn intern_is_stable_and_dense() {
            let mut c = RuntimeClient::cpu().unwrap();
            let a = c.intern(Path::new("/tmp/a.hlo.txt"));
            let b = c.intern(Path::new("/tmp/b.hlo.txt"));
            assert_ne!(a, b);
            assert_eq!(a, c.intern(Path::new("/tmp/a.hlo.txt")));
            assert_eq!(c.cached_executables(), 0);
            assert!(c.execute(Path::new("/tmp/a.hlo.txt"), &[]).is_err());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use native_backend::RuntimeClient;
