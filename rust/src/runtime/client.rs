//! PJRT runtime client: load HLO-text artifacts, compile once, execute many.
//!
//! One `RuntimeClient` per simulated device thread (see device::worker). The
//! underlying `xla` crate types wrap raw C++ pointers and are deliberately
//! kept !Send — a client is created ON its device thread and never leaves
//! it; only host `Tensor`s cross threads.
//!
//! The `xla` crate is a heavy native dependency (it links the xla_extension
//! C++ runtime), so it is gated behind the `pjrt` cargo feature. The
//! default build compiles a native stub with the same API that errors at
//! artifact-execution time — everything that doesn't touch PJRT (the NEL,
//! cache, tensor plane, native SVGD math, benches over them) stays fully
//! functional and hermetic.
//!
//! Artifacts are HLO *text* (jax >= 0.5 serialized protos use 64-bit ids
//! that xla_extension 0.5.1 rejects); `HloModuleProto::from_text_file`
//! reassigns ids. All entries are lowered with return_tuple=True, so every
//! execution result is a tuple literal that we decompose positionally.

/// Cumulative execution counters, used by the perf pass and device stats.
#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
}

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::time::Instant;

    use anyhow::{anyhow, bail, Context, Result};
    use xla::{
        ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation,
    };

    use super::ClientStats;
    use crate::runtime::tensor::{DType, Tensor, TensorData};

    fn element_type(dt: DType) -> ElementType {
        match dt {
            DType::F32 => ElementType::F32,
            DType::I32 => ElementType::S32,
            DType::U32 => ElementType::U32,
        }
    }

    fn to_bytes(data: &TensorData) -> &[u8] {
        // All contract dtypes are 4-byte plain-old-data; reinterpret in place.
        unsafe {
            match data {
                TensorData::F32(v) => {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
                TensorData::I32(v) => {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
                TensorData::U32(v) => {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }
            }
        }
    }

    pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            element_type(t.dtype()),
            &t.shape,
            to_bytes(&t.data),
        )
        .map_err(|e| anyhow!("literal from tensor {:?}: {e:?}", t.shape))
    }

    pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow!("literal ty: {e:?}"))?;
        let data = match ty {
            ElementType::F32 => TensorData::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
            ),
            ElementType::S32 => TensorData::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?,
            ),
            ElementType::U32 => TensorData::U32(
                lit.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e:?}"))?,
            ),
            other => bail!("dtype {other:?} outside the L2/L3 contract"),
        };
        Ok(Tensor::new(dims, data))
    }

    /// A per-device PJRT CPU client with an executable cache keyed by artifact
    /// path. NOT Send/Sync by construction — lives on one device thread.
    pub struct RuntimeClient {
        client: PjRtClient,
        cache: HashMap<PathBuf, PjRtLoadedExecutable>,
        pub stats: ClientStats,
    }

    impl RuntimeClient {
        pub fn cpu() -> Result<RuntimeClient> {
            let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(RuntimeClient { client, cache: HashMap::new(), stats: ClientStats::default() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the artifact at `path`.
        pub fn load(&mut self, path: &Path) -> Result<&PjRtLoadedExecutable> {
            if !self.cache.contains_key(path) {
                let t0 = Instant::now();
                let proto = HloModuleProto::from_text_file(path)
                    .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
                let comp = XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
                self.stats.compiles += 1;
                self.stats.compile_secs += t0.elapsed().as_secs_f64();
                self.cache.insert(path.to_path_buf(), exe);
            }
            Ok(&self.cache[path])
        }

        /// Execute the artifact at `path` with host tensors, returning host
        /// tensors. The artifact's return_tuple=True output is decomposed.
        pub fn execute(&mut self, path: &Path, args: &[Tensor]) -> Result<Vec<Tensor>> {
            let lits: Vec<Literal> = args
                .iter()
                .map(tensor_to_literal)
                .collect::<Result<_>>()
                .with_context(|| format!("args for {path:?}"))?;
            // `load` hands back the cached executable directly; the borrow
            // ends once the (owned) result literal is fetched, so the stats
            // update below needs no second cache probe. Compile time (first
            // call) is charged to compile_secs inside `load`, not here.
            let exe = self.load(path)?;
            let t0 = Instant::now();
            let outs = exe
                .execute::<Literal>(&lits)
                .map_err(|e| anyhow!("executing {path:?}: {e:?}"))?;
            let result = outs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {path:?}: {e:?}"))?;
            self.stats.executions += 1;
            self.stats.execute_secs += t0.elapsed().as_secs_f64();
            let parts = result
                .to_tuple()
                .map_err(|e| anyhow!("decomposing tuple of {path:?}: {e:?}"))?;
            parts.iter().map(literal_to_tensor).collect()
        }

        /// Drop a cached executable (used by cache-pressure tests).
        pub fn evict(&mut self, path: &Path) -> bool {
            self.cache.remove(path).is_some()
        }

        pub fn cached_executables(&self) -> usize {
            self.cache.len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{literal_to_tensor, tensor_to_literal, RuntimeClient};

#[cfg(not(feature = "pjrt"))]
mod native_backend {
    use std::path::Path;

    use anyhow::Result;

    use super::ClientStats;
    use crate::runtime::tensor::Tensor;

    fn unavailable(path: &Path) -> anyhow::Error {
        anyhow::anyhow!(
            "cannot execute artifact {path:?}: push was built without the `pjrt` \
             feature. Rebuild with `cargo build --features pjrt` (after `make \
             artifacts`) to enable the XLA PJRT runtime."
        )
    }

    /// Hermetic stand-in for the PJRT client: same API, no native deps.
    /// Artifact execution fails with a clear message; everything else is a
    /// no-op so the NEL/device machinery can be exercised without XLA.
    pub struct RuntimeClient {
        pub stats: ClientStats,
    }

    impl RuntimeClient {
        pub fn cpu() -> Result<RuntimeClient> {
            Ok(RuntimeClient { stats: ClientStats::default() })
        }

        pub fn platform(&self) -> String {
            "native-stub (built without the `pjrt` feature)".to_string()
        }

        /// Artifact loading always fails in the stub.
        pub fn load(&mut self, path: &Path) -> Result<()> {
            Err(unavailable(path))
        }

        pub fn execute(&mut self, path: &Path, _args: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(unavailable(path))
        }

        pub fn evict(&mut self, _path: &Path) -> bool {
            false
        }

        pub fn cached_executables(&self) -> usize {
            0
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use native_backend::RuntimeClient;
