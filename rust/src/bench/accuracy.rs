//! Tables 3 & 4 (Appendix C.4): multi-SWAG accuracy vs standard training
//! at constant effective parameter count.
//!
//! Paper protocol (§C.4): Adam with lr 1e-3 everywhere. Standard training
//! = 1 network, 10 epochs, argmax of its logits. Multi-SWAG = P particles
//! (P doubling as the model shrinks), 7 pretrain + 3 SWAG epochs,
//! predictions by majority vote over 5 posterior draws per particle with
//! tiny variance scale.

use anyhow::Result;

use crate::bench::depth_width::SweepRow;
use crate::bench::report::{Report, Row};
use crate::bench::{data_for, lr_for, Method};
use crate::data::DataLoader;
use crate::device::CostModel;
use crate::infer::eval::{dataset_accuracy, dataset_mse};
use crate::infer::{
    DeepEnsemble, Infer, MultiSwag, Schedule, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Svgd,
    SvgdConfig, SwagConfig,
};
use crate::nel::NelConfig;
use crate::pd::PushDist;
use crate::runtime::Manifest;

#[derive(Debug, Clone)]
pub struct AccOpts {
    pub devices: usize,
    pub cache_size: usize,
    /// Training batches per epoch.
    pub batches: usize,
    /// Test-set batches.
    pub test_batches: usize,
    pub epochs: usize,
    pub pretrain_epochs: usize,
    pub n_samples: usize,
    pub scale: f32,
    /// Adam learning rate (paper: 1e-3).
    pub lr: f32,
    pub seed: u64,
}

impl Default for AccOpts {
    fn default() -> Self {
        AccOpts {
            devices: 2,
            cache_size: 8,
            batches: 6,
            test_batches: 3,
            epochs: 10,
            pretrain_epochs: 7,
            n_samples: 5,
            scale: 1e-30,
            lr: 1e-3,
            seed: 0,
        }
    }
}

impl AccOpts {
    /// Defaults for the hermetic native-model matrix (`push bench
    /// native-acc`): ~640 closed-form SGD steps per cell, sized so the CI
    /// accuracy-gate job trains every (model, method) pair in seconds.
    pub fn native() -> AccOpts {
        AccOpts {
            batches: 8,
            test_batches: 4,
            epochs: 80,
            pretrain_epochs: 56,
            ..AccOpts::default()
        }
    }
}

fn cfg(opts: &AccOpts) -> NelConfig {
    NelConfig {
        num_devices: opts.devices,
        cache_size: opts.cache_size,
        cost: CostModel::default(),
        seed: opts.seed,
        ..NelConfig::default()
    }
}

/// Accuracy sweep over (model, particles) rows.
pub fn run(
    manifest: &Manifest,
    name: &str,
    rows: &[SweepRow],
    opts: &AccOpts,
) -> Result<Report> {
    let mut rep = Report::new(name);
    for row in rows {
        let model = manifest.model(&row.model)?.clone();
        let lr = opts.lr;
        let bsz = model.batch();
        let n_train = bsz * opts.batches;
        let n_test = bsz * opts.test_batches;
        let all = data_for(&model, n_train + n_test, opts.seed + 10)?;
        let (train, test) = all.split(n_test as f32 / (n_train + n_test) as f32);

        // --- standard training: one particle, plain SGD, argmax logits ---
        let pd = PushDist::new(manifest, &row.model, cfg(opts))?;
        let mut std_algo = DeepEnsemble::new(pd, 1, lr)?.with_adam();
        let mut loader = DataLoader::new(train.clone(), bsz, true, opts.seed + 11)
            .with_max_batches(opts.batches);
        std_algo.train(&mut loader, opts.epochs)?;
        let std_acc = dataset_accuracy(&test, bsz, |x| std_algo.predict_mean(x))?;

        // --- multi-SWAG: P particles, 7+3, majority vote over draws ------
        let particles = row.base_particles;
        let pd = PushDist::new(manifest, &row.model, cfg(opts))?;
        let mut ms = MultiSwag::new(
            pd,
            SwagConfig {
                particles,
                lr,
                pretrain_epochs: opts.pretrain_epochs,
                n_samples: opts.n_samples,
                scale: opts.scale,
                adam: true,
                seed: opts.seed,
            },
        )?;
        let mut loader = DataLoader::new(train, bsz, true, opts.seed + 12)
            .with_max_batches(opts.batches);
        ms.train(&mut loader, opts.epochs)?;
        let ms_acc = dataset_accuracy(&test, bsz, |x| ms.predict_swag(x))?;

        crate::log_info!(
            "{name}: {} std={:.2}% mswag(P={particles})={:.2}%",
            row.model,
            100.0 * std_acc,
            100.0 * ms_acc
        );
        rep.push(
            Row::new()
                .str("model", &row.model)
                .int("params", model.param_count)
                .num("standard_acc", 100.0 * std_acc)
                .int("particles", particles)
                .num("multiswag_acc", 100.0 * ms_acc),
        );
    }
    Ok(rep)
}

/// The hermetic Table-1 matrix over the native model zoo: every registered
/// native model x every algorithm family, closed-form grad/forward only —
/// no AOT artifacts, so it runs on a bare CI runner. Classify rows report
/// accuracy (%), regression rows MSE. The CI accuracy-gate job checks the
/// saved JSON against ACC_GATES.json via tools/check_accuracy_gates.py.
pub fn run_native(opts: &AccOpts) -> Result<Report> {
    let manifest = crate::infer::native_manifest();
    let mut rep = Report::new("native_acc");
    let particles = 4usize;
    for name in ["linear_spiral_native", "mlp_native", "conv1d_native"] {
        let nm = crate::infer::native_model(name)
            .ok_or_else(|| anyhow::anyhow!("{name} is not a registered native model"))?;
        let model = manifest.model(name)?.clone();
        let classify = model.task == "classify";
        let lr = lr_for(&model);
        let bsz = model.batch();
        let n_train = bsz * opts.batches;
        let n_test = bsz * opts.test_batches;
        let all = data_for(&model, n_train + n_test, opts.seed + 10)?;
        let (train, test) = all.split(n_test as f32 / (n_train + n_test) as f32);
        for method in Method::all() {
            let pd = PushDist::new(&manifest, name, cfg(opts))?;
            let init = nm.seeded_init(opts.seed);
            let mut algo: Box<dyn Infer> = match method {
                Method::Ensemble => {
                    Box::new(DeepEnsemble::new_native(pd, particles, lr, &nm.source, init)?)
                }
                Method::MultiSwag => Box::new(MultiSwag::new_native(
                    pd,
                    SwagConfig {
                        particles,
                        lr,
                        pretrain_epochs: opts.pretrain_epochs,
                        n_samples: opts.n_samples,
                        scale: opts.scale,
                        adam: false, // there is no native Adam
                        seed: opts.seed,
                    },
                    &nm.source,
                    init,
                )?),
                Method::Svgd => Box::new(Svgd::new_native(
                    pd,
                    SvgdConfig { particles, lr, lengthscale: 10.0, ..SvgdConfig::default() },
                    &nm.source,
                    init,
                )?),
                Method::Sgld | Method::Sghmc => {
                    let algo =
                        if method == Method::Sgld { SgmcmcAlgo::Sgld } else { SgmcmcAlgo::Sghmc };
                    Box::new(SgMcmc::new(
                        pd,
                        SgmcmcConfig {
                            particles,
                            algo,
                            schedule: Schedule::Constant { eps: lr },
                            temperature: 1e-4,
                            // explore for the first half, sample the rest
                            burn_in: opts.batches * opts.epochs / 2,
                            thin: 1,
                            max_samples: 32,
                            seed: opts.seed,
                            model: nm.source.clone(),
                            init: Some(init),
                            ..SgmcmcConfig::default()
                        },
                    )?)
                }
            };
            let mut loader = DataLoader::new(train.clone(), bsz, true, opts.seed + 11)
                .with_max_batches(opts.batches);
            algo.train(&mut loader, opts.epochs)?;
            let mut row = Row::new()
                .str("model", name)
                .str("method", method.name())
                .str("task", &model.task)
                .int("params", model.param_count)
                .int("particles", particles);
            if classify {
                let acc = 100.0 * dataset_accuracy(&test, bsz, |x| algo.predict_mean(x))?;
                crate::log_info!("native_acc: {name} {} acc={acc:.2}%", method.name());
                row = row.num("accuracy", acc);
            } else {
                let mse = dataset_mse(&test, bsz, |x| algo.predict_mean(x))?;
                crate::log_info!("native_acc: {name} {} mse={mse:.4}", method.name());
                row = row.num("mse", mse);
            }
            rep.push(row);
        }
    }
    Ok(rep)
}
