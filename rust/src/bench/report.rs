//! Structured bench output: aligned console tables + JSON files under
//! `bench_results/` (consumed by EXPERIMENTS.md).

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// A generic result row: ordered (key, value) pairs.
#[derive(Debug, Clone)]
pub struct Row(pub Vec<(String, Json)>);

impl Row {
    pub fn new() -> Row {
        Row(Vec::new())
    }

    pub fn str(mut self, k: &str, v: &str) -> Row {
        self.0.push((k.to_string(), Json::Str(v.to_string())));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Row {
        self.0.push((k.to_string(), Json::Num(v)));
        self
    }

    pub fn int(mut self, k: &str, v: usize) -> Row {
        self.0.push((k.to_string(), Json::Num(v as f64)));
        self
    }

    fn cell(&self, k: &str) -> String {
        for (key, v) in &self.0 {
            if key == k {
                return match v {
                    Json::Str(s) => s.clone(),
                    Json::Num(n) if n.fract() == 0.0 && n.abs() < 1e12 => {
                        format!("{}", *n as i64)
                    }
                    Json::Num(n) => format!("{n:.4}"),
                    other => other.pretty(),
                };
            }
        }
        "-".to_string()
    }
}

impl Default for Row {
    fn default() -> Self {
        Self::new()
    }
}

/// Print rows as an aligned table using the union of keys in first-seen
/// order, then persist them as JSON.
pub struct Report {
    pub name: String,
    pub rows: Vec<Row>,
}

impl Report {
    pub fn new(name: &str) -> Report {
        Report { name: name.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    fn columns(&self) -> Vec<String> {
        let mut cols: Vec<String> = Vec::new();
        for r in &self.rows {
            for (k, _) in &r.0 {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        cols
    }

    pub fn print(&self) {
        let cols = self.columns();
        if cols.is_empty() {
            println!("[{}] (no rows)", self.name);
            return;
        }
        let mut widths: Vec<usize> = cols.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| cols.iter().map(|c| r.cell(c)).collect())
            .collect();
        for row in &cells {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("== {} ==", self.name);
        let header: Vec<String> = cols
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        for row in &cells {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }

    /// Write `bench_results/<name>.json`.
    pub fn save(&self, dir: impl Into<PathBuf>) -> Result<PathBuf> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let path = dir.join(format!("{}.json", self.name));
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Obj(r.0.iter().cloned().collect()))
            .collect();
        let j = obj(vec![
            ("experiment", Json::Str(self.name.clone())),
            ("rows", Json::Arr(rows)),
        ]);
        let mut f = std::fs::File::create(&path)?;
        f.write_all(j.pretty().as_bytes())?;
        Ok(path)
    }
}

/// Default results directory: `$PUSH_BENCH_DIR` or `<repo>/bench_results`.
pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PUSH_BENCH_DIR") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_align_and_save() {
        let mut rep = Report::new("unit_test_report");
        rep.push(Row::new().str("arch", "vit").int("particles", 4).num("secs", 1.25));
        rep.push(Row::new().str("arch", "unet").int("particles", 16).num("secs", 0.5));
        rep.print();
        let dir = std::env::temp_dir().join(format!("push-bench-{}", std::process::id()));
        let p = rep.save(&dir).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
