//! Structured bench output: aligned console tables + JSON files under
//! `bench_results/` (consumed by EXPERIMENTS.md).

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// A generic result row: ordered (key, value) pairs.
#[derive(Debug, Clone)]
pub struct Row(pub Vec<(String, Json)>);

impl Row {
    pub fn new() -> Row {
        Row(Vec::new())
    }

    pub fn str(mut self, k: &str, v: &str) -> Row {
        self.0.push((k.to_string(), Json::Str(v.to_string())));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Row {
        self.0.push((k.to_string(), Json::Num(v)));
        self
    }

    pub fn int(mut self, k: &str, v: usize) -> Row {
        self.0.push((k.to_string(), Json::Num(v as f64)));
        self
    }

    fn cell(&self, k: &str) -> String {
        for (key, v) in &self.0 {
            if key == k {
                return match v {
                    Json::Str(s) => s.clone(),
                    // NaN means "this metric was never measured" (e.g.
                    // TrainReport::final_loss of an empty report) — render
                    // it honestly instead of a bare "NaN" leaking into
                    // tables.
                    Json::Num(n) if n.is_nan() => "n/a".to_string(),
                    Json::Num(n) if n.fract() == 0.0 && n.abs() < 1e12 => {
                        format!("{}", *n as i64)
                    }
                    Json::Num(n) => format!("{n:.4}"),
                    other => other.pretty(),
                };
            }
        }
        "-".to_string()
    }
}

impl Default for Row {
    fn default() -> Self {
        Self::new()
    }
}

/// Print rows as an aligned table using the union of keys in first-seen
/// order, then persist them as JSON.
pub struct Report {
    pub name: String,
    pub rows: Vec<Row>,
    /// When set, `print` renders a per-column mean row with this label
    /// under the table and `save` writes it as a separate top-level
    /// `aggregate` object — NEVER as a data row, so grid consumers don't
    /// pick up a bogus point whose axis columns are averaged coordinates.
    pub aggregate_label: Option<String>,
}

impl Report {
    pub fn new(name: &str) -> Report {
        Report { name: name.to_string(), rows: Vec::new(), aggregate_label: None }
    }

    /// Enable the aggregate mean row (see `aggregate_label`).
    pub fn with_aggregate(mut self, label: &str) -> Report {
        self.aggregate_label = Some(label.to_string());
        self
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    fn columns(&self) -> Vec<String> {
        let mut cols: Vec<String> = Vec::new();
        for r in &self.rows {
            for (k, _) in &r.0 {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        cols
    }

    pub fn print(&self) {
        let cols = self.columns();
        if cols.is_empty() {
            println!("[{}] (no rows)", self.name);
            return;
        }
        let mut widths: Vec<usize> = cols.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| cols.iter().map(|c| r.cell(c)).collect())
            .collect();
        for row in &cells {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let agg_cells: Option<Vec<String>> = self.aggregate_label.as_ref().map(|label| {
            let agg = self.aggregate_row(label);
            cols.iter().map(|c| agg.cell(c)).collect()
        });
        if let Some(row) = &agg_cells {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("== {} ==", self.name);
        let fmt_line = |row: &[String]| {
            row.iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_line(&cols));
        for row in &cells {
            println!("{}", fmt_line(row));
        }
        if let Some(row) = &agg_cells {
            println!("{}", fmt_line(row));
        }
    }

    /// Column-wise mean over all rows, skipping NaN (and non-finite)
    /// cells per column instead of letting one unmeasured value poison
    /// the aggregate. String columns are skipped except the first, which
    /// carries `label`; columns with no finite values come out NaN (and
    /// render as "n/a").
    pub fn aggregate_row(&self, label: &str) -> Row {
        let mut agg = Row::new();
        let mut labeled = false;
        for col in self.columns() {
            let mut sum = 0.0f64;
            let mut n = 0usize;
            let mut numeric = false;
            for r in &self.rows {
                for (k, v) in &r.0 {
                    if k == &col {
                        if let Json::Num(x) = v {
                            numeric = true;
                            if x.is_finite() {
                                sum += x;
                                n += 1;
                            }
                        }
                    }
                }
            }
            if numeric {
                agg = agg.num(&col, if n > 0 { sum / n as f64 } else { f64::NAN });
            } else if !labeled {
                agg = agg.str(&col, label);
                labeled = true;
            }
        }
        agg
    }

    /// Write `bench_results/<name>.json`. NaN cells are serialized as
    /// `null` (bare NaN is not valid JSON and used to silently corrupt
    /// the output file).
    pub fn save(&self, dir: impl Into<PathBuf>) -> Result<PathBuf> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let path = dir.join(format!("{}.json", self.name));
        let sanitize = |v: &Json| match v {
            Json::Num(n) if !n.is_finite() => Json::Null,
            other => other.clone(),
        };
        let to_obj = |r: &Row| {
            Json::Obj(r.0.iter().map(|(k, v)| (k.clone(), sanitize(v))).collect())
        };
        let rows: Vec<Json> = self.rows.iter().map(to_obj).collect();
        let mut fields = vec![
            ("experiment", Json::Str(self.name.clone())),
            ("rows", Json::Arr(rows)),
        ];
        if let Some(label) = &self.aggregate_label {
            fields.push(("aggregate", to_obj(&self.aggregate_row(label))));
        }
        let j = obj(fields);
        let mut f = std::fs::File::create(&path)?;
        f.write_all(j.pretty().as_bytes())?;
        Ok(path)
    }
}

/// Default results directory: `$PUSH_BENCH_DIR` or `<repo>/bench_results`.
pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PUSH_BENCH_DIR") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_results")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_align_and_save() {
        let mut rep = Report::new("unit_test_report");
        rep.push(Row::new().str("arch", "vit").int("particles", 4).num("secs", 1.25));
        rep.push(Row::new().str("arch", "unet").int("particles", 16).num("secs", 0.5));
        rep.print();
        let dir = std::env::temp_dir().join(format!("push-bench-{}", std::process::id()));
        let p = rep.save(&dir).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_renders_na_and_saves_as_null() {
        let mut rep = Report::new("unit_test_nan");
        rep.push(Row::new().str("arch", "vit").num("loss", f64::NAN).num("secs", 1.0));
        assert_eq!(rep.rows[0].cell("loss"), "n/a");
        assert_eq!(rep.rows[0].cell("secs"), "1");
        let dir = std::env::temp_dir().join(format!("push-bench-nan-{}", std::process::id()));
        let p = rep.save(&dir).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        // Bare NaN is not valid JSON — the file must still parse, with the
        // unmeasured cell as null.
        let j = Json::parse(&text).expect("NaN must not corrupt the JSON output");
        let row = &j.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("loss"), Some(&Json::Null));
        assert_eq!(row.get("secs"), Some(&Json::Num(1.0)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn aggregate_skips_nan_cells() {
        let mut rep = Report::new("unit_test_agg");
        rep.push(Row::new().str("arch", "a").num("loss", 1.0).num("secs", 2.0));
        rep.push(Row::new().str("arch", "b").num("loss", f64::NAN).num("secs", 4.0));
        rep.push(Row::new().str("arch", "c").num("loss", 3.0).num("secs", f64::NAN));
        let agg = rep.aggregate_row("mean");
        assert_eq!(agg.cell("arch"), "mean");
        assert_eq!(agg.cell("loss"), "2", "NaN excluded: (1 + 3) / 2");
        assert_eq!(agg.cell("secs"), "3", "NaN excluded: (2 + 4) / 2");
        // a column that is all-NaN aggregates to n/a, not a poisoned mean
        let mut rep2 = Report::new("unit_test_agg2");
        rep2.push(Row::new().str("arch", "a").num("loss", f64::NAN));
        assert_eq!(rep2.aggregate_row("mean").cell("loss"), "n/a");
    }

    #[test]
    fn aggregate_saves_separately_not_as_a_row() {
        let mut rep = Report::new("unit_test_agg_save").with_aggregate("mean");
        rep.push(Row::new().str("arch", "a").int("particles", 2).num("secs", 1.0));
        rep.push(Row::new().str("arch", "b").int("particles", 4).num("secs", 3.0));
        rep.print();
        let dir = std::env::temp_dir().join(format!("push-bench-agg-{}", std::process::id()));
        let p = rep.save(&dir).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        // data rows stay clean (no synthetic "mean" grid point)...
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        // ...and the aggregate lands in its own top-level object
        let agg = j.get("aggregate").expect("aggregate object present");
        assert_eq!(agg.get("arch").unwrap().as_str(), Some("mean"));
        assert_eq!(agg.get("secs").unwrap().as_f64(), Some(2.0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
