//! Benchmark harness: one driver per paper table/figure (DESIGN.md §5).
//!
//! Every driver returns structured rows, prints a paper-style table, and
//! writes JSON under `bench_results/` so EXPERIMENTS.md can cite exact
//! numbers. Absolute seconds differ from the paper (simulated devices on a
//! CPU host — DESIGN.md §Hardware-Adaptation); the *shape* (who wins, the
//! scaling multipliers, where SVGD saturates) is what the harness checks.
//!
//! | Driver                  | Paper artifact          |
//! |-------------------------|-------------------------|
//! | [`scaling::run_figure`] | Figures 4 and 7         |
//! | [`scaling::run_stress`] | Appendix C.3 (Table 2)  |
//! | [`depth_width::run`]    | Tables 1 and 2          |
//! | [`accuracy::run`]       | Tables 3 and 4 (App C.4)|
//! | [`ablate`]              | DESIGN.md ablations     |

pub mod ablate;
pub mod accuracy;
pub mod depth_width;
pub mod harness;
pub mod report;
pub mod scaling;

use anyhow::Result;

use crate::data::{synth, Dataset};
use crate::runtime::ModelSpec;

/// Inference method selector shared by the drivers. Covers the four
/// algorithm families: deep ensembles, (multi-)SWAG, SVGD, and the SGMCMC
/// chains (SGLD / SGHMC).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Ensemble,
    MultiSwag,
    Svgd,
    Sgld,
    Sghmc,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Ensemble => "ensemble",
            Method::MultiSwag => "multi_swag",
            Method::Svgd => "svgd",
            Method::Sgld => "sgld",
            Method::Sghmc => "sghmc",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "ensemble" => Some(Method::Ensemble),
            "multi_swag" | "multiswag" | "swag" => Some(Method::MultiSwag),
            "svgd" => Some(Method::Svgd),
            "sgld" => Some(Method::Sgld),
            "sghmc" => Some(Method::Sghmc),
            _ => None,
        }
    }

    /// One representative per algorithm family (the scaling figures'
    /// method axis): ensemble, multi-SWAG, SVGD, and SGLD + SGHMC for the
    /// SGMCMC family.
    pub fn all() -> [Method; 5] {
        [
            Method::Ensemble,
            Method::MultiSwag,
            Method::Svgd,
            Method::Sgld,
            Method::Sghmc,
        ]
    }
}

/// Generate the substitute dataset matching a model's task/shape contract
/// (DESIGN.md §Dataset-substitutions), sized for `n_samples`.
pub fn data_for(model: &ModelSpec, n_samples: usize, seed: u64) -> Result<Dataset> {
    let meta_usize = |key: &str| {
        model
            .meta
            .get(key)
            .and_then(crate::util::json::Json::as_usize)
    };
    let ds = match model.arch.as_str() {
        "vit" | "resnet" => synth::mnist_like(n_samples, 0.35, seed),
        "cgcnn" => {
            let atoms = meta_usize("atoms").unwrap_or(8);
            let species = meta_usize("species").unwrap_or(4);
            synth::md17_like(n_samples, atoms, species, seed)
        }
        "schnet" => {
            let atoms = meta_usize("atoms").unwrap_or(8);
            let species = meta_usize("species").unwrap_or(4);
            synth::md17_energy(n_samples, atoms, species, seed)
        }
        "unet1d" => {
            let nx = meta_usize("nx").unwrap_or(64);
            synth::advection(n_samples, nx, 1.0, 0.2, 6, seed)
        }
        "mlp" => synth::linear(n_samples, model.x_shape[1], 0.1, seed),
        // native-model tasks: the two-class spiral a linear cut provably
        // cannot fit (data/synth.rs proves best-cut accuracy < 0.8) and
        // the nonlinear 1-D wave-energy regression
        "spiral" => synth::spiral(n_samples, 1.5, 0.02, seed),
        "wave1d" => synth::wave_energy(n_samples, model.x_shape[1], 4, 0.05, seed),
        other => anyhow::bail!("no dataset substitute for arch {other:?}"),
    };
    // shape sanity against the manifest contract
    anyhow::ensure!(
        ds.x_dims == model.x_shape[1..],
        "dataset x {:?} vs model {:?}",
        ds.x_dims,
        &model.x_shape[1..]
    );
    Ok(ds)
}

/// Learning rate defaults per architecture (kept small: synthetic targets
/// are normalized but CGCNN's force term amplifies gradients).
pub fn lr_for(model: &ModelSpec) -> f32 {
    match model.arch.as_str() {
        "cgcnn" => 1e-4,
        "schnet" => 1e-3,
        // plain-SGD transformers/CNNs on the synthetic vision task train
        // comfortably at 5e-2 (validated in tests/infer_integration.rs)
        "vit" | "resnet" => 5e-2,
        // the native spiral MLP needs a hot rate to clear the softmax
        // plateau inside a CI-sized budget; wave1d is a shallow conv net
        "spiral" => 1e-1,
        "wave1d" => 2e-2,
        _ => 1e-2,
    }
}
