//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **Active-set size** — the paper's context-switch knob (§4.2): time an
//!   ensemble epoch as `cache_size` shrinks below the particle count,
//!   exposing swap cost.
//! * **SVGD kernel path** — AOT Pallas artifact vs native Rust loops for
//!   the leader's O(n^2 d) update.
//! * **Transfer cost model** — account-only vs simulated PCIe sleeps,
//!   quantifying what the virtual clock claims the schedule would cost.

use anyhow::Result;
use std::time::Instant;

use crate::bench::report::{Report, Row};
use crate::bench::{data_for, lr_for};
use crate::data::DataLoader;
use crate::device::CostModel;
use crate::infer::{DeepEnsemble, Infer, Svgd, SvgdConfig};
use crate::nel::NelConfig;
use crate::pd::PushDist;
use crate::runtime::Manifest;

fn cfg(devices: usize, cache: usize, cost: CostModel, seed: u64) -> NelConfig {
    NelConfig { num_devices: devices, cache_size: cache, cost, seed, ..NelConfig::default() }
}

/// Ensemble epoch time vs active-set size (particles fixed).
pub fn cache_size_sweep(
    manifest: &Manifest,
    model_name: &str,
    particles: usize,
    cache_sizes: &[usize],
    batches: usize,
    epochs: usize,
) -> Result<Report> {
    let mut rep = Report::new("ablate_cache_size");
    for &cache in cache_sizes {
        let pd = PushDist::new(manifest, model_name, cfg(1, cache, CostModel::free(), 0))?;
        let model = pd.model().clone();
        let lr = lr_for(&model);
        let data = data_for(&model, model.batch() * batches, 1)?;
        let mut loader = DataLoader::new(data, model.batch(), true, 2).with_max_batches(batches);
        let mut algo = DeepEnsemble::new(pd, particles, lr)?;
        let report = algo.train(&mut loader, epochs)?;
        let secs = if report.epochs.len() > 1 {
            report.epochs[1..].iter().map(|e| e.secs).sum::<f64>()
                / (report.epochs.len() - 1) as f64
        } else {
            report.mean_epoch_secs()
        };
        let stats = algo.pd().stats();
        let d0 = &stats.devices[0];
        crate::log_info!(
            "ablate cache={cache}: {secs:.3}s/epoch (hit rate {:.0}%)",
            100.0 * d0.cache_hit_rate()
        );
        rep.push(
            Row::new()
                .str("model", model_name)
                .int("particles", particles)
                .int("cache_size", cache)
                .num("secs_per_epoch", secs)
                .num("cache_hit_rate", d0.cache_hit_rate())
                .int("swaps", (d0.swaps_in + d0.swaps_out) as usize)
                .int("swap_mb", (d0.swap_bytes >> 20) as usize),
        );
    }
    Ok(rep)
}

/// SVGD leader kernel: Pallas artifact vs native Rust, same workload.
pub fn svgd_kernel_ablation(
    manifest: &Manifest,
    model_name: &str,
    particle_counts: &[usize],
    batches: usize,
) -> Result<Report> {
    let mut rep = Report::new("ablate_svgd_kernel");
    for &n in particle_counts {
        for force_native in [false, true] {
            let pd = PushDist::new(manifest, model_name, cfg(2, n.max(4), CostModel::free(), 0))?;
            if !force_native && pd.svgd_artifact(n).is_none() {
                crate::log_warn!("no svgd artifact for n={n}; skipping artifact arm");
                continue;
            }
            let model = pd.model().clone();
            let data = data_for(&model, model.batch() * batches, 1)?;
            let mut loader =
                DataLoader::new(data, model.batch(), true, 2).with_max_batches(batches);
            let mut algo = Svgd::new(
                pd,
                SvgdConfig {
                    particles: n,
                    lr: 1e-3,
                    lengthscale: 10.0,
                    force_native,
                    ..SvgdConfig::default()
                },
            )?;
            // warmup epoch compiles; measure the second
            algo.train(&mut loader, 1)?;
            let t0 = Instant::now();
            algo.train(&mut loader, 1)?;
            let secs = t0.elapsed().as_secs_f64();
            crate::log_info!(
                "ablate svgd n={n} kernel={}: {secs:.3}s/epoch",
                if force_native { "native" } else { "pallas" }
            );
            rep.push(
                Row::new()
                    .str("model", model_name)
                    .int("particles", n)
                    .str("kernel", if force_native { "native" } else { "pallas" })
                    .num("secs_per_epoch", secs),
            );
        }
    }
    Ok(rep)
}

/// Transfer-cost model: account-only vs simulated sleeps.
pub fn cost_model_ablation(
    manifest: &Manifest,
    model_name: &str,
    particles: usize,
    batches: usize,
) -> Result<Report> {
    let mut rep = Report::new("ablate_cost_model");
    for (label, cost) in [
        ("free", CostModel::free()),
        ("account_only", CostModel::default()),
        (
            "simulated_pcie",
            CostModel { simulate: true, ..CostModel::default() },
        ),
    ] {
        let pd = PushDist::new(manifest, model_name, cfg(2, 4, cost, 0))?;
        let model = pd.model().clone();
        let lr = lr_for(&model);
        let data = data_for(&model, model.batch() * batches, 1)?;
        let mut loader = DataLoader::new(data, model.batch(), true, 2).with_max_batches(batches);
        let mut algo = DeepEnsemble::new(pd, particles, lr)?;
        algo.train(&mut loader, 1)?; // warmup/compile
        let t0 = Instant::now();
        algo.train(&mut loader, 1)?;
        let secs = t0.elapsed().as_secs_f64();
        let stats = algo.pd().stats();
        let vclock: f64 = stats
            .devices
            .iter()
            .map(|d| d.modeled_swap_secs + d.modeled_transfer_secs)
            .sum();
        crate::log_info!("ablate cost={label}: {secs:.3}s/epoch vclock={vclock:.5}s");
        rep.push(
            Row::new()
                .str("cost_model", label)
                .int("particles", particles)
                .num("secs_per_epoch", secs)
                .num("virtual_clock_secs", vclock),
        );
    }
    Ok(rep)
}
