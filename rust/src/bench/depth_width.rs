//! Tables 1 & 2: depth/width vs number-of-particles tradeoff at constant
//! effective parameter count (multi-SWAG on the ViT sweep).
//!
//! Paper protocol: hold `param_count x particles` ~ constant down each
//! column; doubling the device count doubles both the particle count and
//! the effective parameter count. Ideal scaling is a 1.0x multiple of the
//! 1-device time in each row; the paper reports how the multiple grows as
//! particles shrink (Table 1) and under width scaling (Table 2).

use anyhow::Result;

use crate::bench::report::{Report, Row};
use crate::bench::scaling::{run_one, ScaleOpts};
use crate::bench::Method;
use crate::runtime::Manifest;

/// One sweep row: a model variant and its 1-device particle count.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub model: String,
    pub base_particles: usize,
}

/// The Table-1 sweep scaled to this testbed: depth halves, particles
/// double (paper: depths 64..1 / particles 1..64).
pub fn table1_rows() -> Vec<SweepRow> {
    vec![
        SweepRow { model: "vit_d8".into(), base_particles: 2 },
        SweepRow { model: "vit_d4".into(), base_particles: 4 },
        SweepRow { model: "vit_d2".into(), base_particles: 8 },
        SweepRow { model: "vit_d1".into(), base_particles: 16 },
    ]
}

/// The Table-2 sweep: width shrinks (params ~ width^2), particles grow
/// (paper: 8..256 on 1 device).
pub fn table2_rows(full: bool) -> Vec<SweepRow> {
    let mut rows = vec![
        SweepRow { model: "vit_w64".into(), base_particles: 2 },
        SweepRow { model: "vit_w48".into(), base_particles: 4 },
        SweepRow { model: "vit_w32".into(), base_particles: 8 },
        SweepRow { model: "vit_w24".into(), base_particles: 16 },
    ];
    if full {
        rows.push(SweepRow { model: "vit_w16".into(), base_particles: 32 });
        rows.push(SweepRow { model: "vit_w8".into(), base_particles: 128 });
    }
    rows
}

/// Run a depth/width sweep with `method` (the paper uses multi-SWAG)
/// across `devices`, reporting the paper's T_k time multiples.
pub fn run(
    manifest: &Manifest,
    name: &str,
    rows: &[SweepRow],
    method: Method,
    devices: &[usize],
    opts: &ScaleOpts,
) -> Result<Report> {
    let mut rep = Report::new(name);
    let mut t1: Option<f64> = None; // first row, 1 device (the paper's T_1)
    for row in rows {
        let params = manifest.model(&row.model)?.param_count;
        let mut one_dev_secs: Option<f64> = None;
        for &dev in devices {
            let particles = row.base_particles * dev;
            let pt = run_one(manifest, &row.model, method, dev, particles, opts)?;
            // The paper's multiples compare times that would overlap across
            // devices — use the modeled parallel makespan (1-core host;
            // see ScalePoint docs).
            let secs = pt.modeled_secs;
            crate::log_info!(
                "{name}: {} dev={dev} P={particles}: wall {:.3}s modeled {secs:.3}s",
                row.model,
                pt.wall_secs
            );
            if dev == 1 {
                one_dev_secs = Some(secs);
                if t1.is_none() {
                    t1 = Some(secs);
                }
            }
            let vs_one_dev = one_dev_secs.map(|t| secs / t).unwrap_or(f64::NAN);
            let vs_t1 = t1.map(|t| secs / t).unwrap_or(f64::NAN);
            rep.push(
                Row::new()
                    .str("model", &row.model)
                    .str("method", method.name())
                    .int("params", params)
                    .int("effective_params", params * particles)
                    .int("devices", dev)
                    .int("particles", particles)
                    .num("wall_secs_per_epoch", pt.wall_secs)
                    .num("modeled_secs_per_epoch", secs)
                    .num("x_vs_1dev", vs_one_dev)
                    .num("x_vs_T1", vs_t1),
            );
        }
    }
    Ok(rep)
}
