//! Figures 4 & 7 (particle scaling across devices/architectures/methods)
//! and the Appendix C.3 stress test.

use anyhow::Result;

use crate::bench::report::{Report, Row};
use crate::bench::{data_for, lr_for, Method};
use crate::data::DataLoader;
use crate::device::CostModel;
use crate::infer::{
    DeepEnsemble, Infer, MultiSwag, Schedule, SgMcmc, SgmcmcAlgo, SgmcmcConfig, Svgd,
    SvgdConfig, SwagConfig,
};
use crate::nel::NelConfig;
use crate::pd::PushDist;
use crate::runtime::Manifest;

#[derive(Debug, Clone)]
pub struct ScaleOpts {
    /// Device counts to sweep (paper: 1, 2, 4).
    pub devices: Vec<usize>,
    /// Particle counts for ONE device; d devices run `base * d` particles
    /// (the paper's {1,2,4,8} x devices grid).
    pub particles_base: Vec<usize>,
    /// Batches per epoch (paper: 40).
    pub batches: usize,
    /// Epochs per configuration; the first is warmup (compile) and is
    /// excluded from the mean when more than one runs (paper averages 10).
    pub epochs: usize,
    /// Active-set slots per device (paper default: 4, or 8 to fit the
    /// 8-particles-per-device grid point).
    pub cache_size: usize,
    /// Also run the handwritten 1-device baselines (paper §5.1).
    pub baseline: bool,
    pub seed: u64,
}

impl Default for ScaleOpts {
    fn default() -> Self {
        ScaleOpts {
            devices: vec![1, 2, 4],
            particles_base: vec![1, 2, 4, 8],
            batches: 4,
            epochs: 2,
            cache_size: 8,
            baseline: true,
            seed: 0,
        }
    }
}

fn mk_config(devices: usize, cache: usize, seed: u64) -> NelConfig {
    NelConfig {
        num_devices: devices,
        cache_size: cache,
        cost: CostModel::default(),
        // 1-core host: measure in discrete-event mode so the modeled
        // makespan (max per-device busy) is contention-free.
        serialize_streams: true,
        seed,
        ..NelConfig::default()
    }
}

/// One scaling measurement.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    /// Wall seconds per epoch. NOTE: on a 1-core host the simulated
    /// devices' thread-level concurrency serializes, so wall time cannot
    /// show multi-device speedup — use `modeled_secs` for the paper's
    /// scaling shape (DESIGN.md §Hardware-Adaptation).
    pub wall_secs: f64,
    /// Modeled parallel makespan per epoch: max over devices of that
    /// device's REAL busy time plus its virtual transfer/swap clock —
    /// what the same schedule costs when devices truly overlap.
    pub modeled_secs: f64,
    pub final_loss: f64,
    /// Cross-chain diagnostics (SGMCMC methods only; None otherwise).
    /// NaN fields serialize as JSON null and render "n/a".
    pub diag: Option<crate::infer::eval::ChainDiag>,
}

/// Train `method` with `particles` on `devices`. Uses the substitute
/// dataset for the model's architecture.
pub fn run_one(
    manifest: &Manifest,
    model_name: &str,
    method: Method,
    devices: usize,
    particles: usize,
    opts: &ScaleOpts,
) -> Result<ScalePoint> {
    let pd = PushDist::new(manifest, model_name, mk_config(devices, opts.cache_size, opts.seed))?;
    let model = pd.model().clone();
    let lr = lr_for(&model);
    let n_samples = model.batch() * opts.batches;
    let data = data_for(&model, n_samples, opts.seed + 1)?;
    let mut loader =
        DataLoader::new(data, model.batch(), true, opts.seed + 2).with_max_batches(opts.batches);

    // Registered native models swap the AOT artifact plane for closed-form
    // grad/forward closures; every family has a `new_native` twin.
    let native = crate::infer::native_model(model_name);
    let mut algo: Box<dyn Infer> = match method {
        Method::Ensemble => match &native {
            Some(nm) => Box::new(DeepEnsemble::new_native(
                pd,
                particles,
                lr,
                &nm.source,
                nm.seeded_init(opts.seed),
            )?),
            None => Box::new(DeepEnsemble::new(pd, particles, lr)?),
        },
        Method::MultiSwag => {
            let cfg = SwagConfig {
                particles,
                lr,
                pretrain_epochs: 0, // every measured epoch does moment work
                ..SwagConfig::default()
            };
            match &native {
                Some(nm) => {
                    Box::new(MultiSwag::new_native(pd, cfg, &nm.source, nm.seeded_init(opts.seed))?)
                }
                None => Box::new(MultiSwag::new(pd, cfg)?),
            }
        }
        Method::Svgd => {
            let cfg = SvgdConfig { particles, lr, lengthscale: 10.0, ..SvgdConfig::default() };
            match &native {
                Some(nm) => {
                    Box::new(Svgd::new_native(pd, cfg, &nm.source, nm.seeded_init(opts.seed))?)
                }
                None => Box::new(Svgd::new(pd, cfg)?),
            }
        }
        Method::Sgld | Method::Sghmc => {
            let algo = if method == Method::Sgld { SgmcmcAlgo::Sgld } else { SgmcmcAlgo::Sghmc };
            let mut cfg = SgmcmcConfig {
                particles,
                algo,
                schedule: Schedule::Constant { eps: lr },
                temperature: 1e-4,
                burn_in: opts.batches, // one epoch of burn-in
                thin: 1,
                max_samples: 16,
                seed: opts.seed,
                ..SgmcmcConfig::default()
            };
            if let Some(nm) = &native {
                cfg.model = nm.source.clone();
                cfg.init = Some(nm.seeded_init(opts.seed));
            }
            Box::new(SgMcmc::new(pd, cfg)?)
        }
    };
    // warmup epoch (PJRT compiles) excluded from both metrics
    let (warmup, measured) = if opts.epochs > 1 { (1, opts.epochs - 1) } else { (0, opts.epochs) };
    if warmup > 0 {
        algo.train(&mut loader, warmup)?;
    }
    let before = algo.pids().len(); // force algo borrow shape
    let _ = before;
    let stats0 = stats_snapshot(algo.as_ref());
    let report = algo.train(&mut loader, measured)?;
    let stats1 = stats_snapshot(algo.as_ref());
    let wall = report.mean_epoch_secs();
    let modeled = stats1
        .iter()
        .zip(&stats0)
        .map(|(a, b)| {
            (a.busy_secs - b.busy_secs)
                + (a.modeled_swap_secs - b.modeled_swap_secs)
                + (a.modeled_transfer_secs - b.modeled_transfer_secs)
        })
        .fold(0.0f64, f64::max)
        / measured as f64;
    Ok(ScalePoint {
        wall_secs: wall,
        modeled_secs: modeled,
        final_loss: report.final_loss(),
        diag: algo.diagnostics(),
    })
}

fn stats_snapshot(algo: &dyn Infer) -> Vec<crate::device::DeviceStats> {
    algo.nel_stats().devices
}

/// The handwritten 1-device baseline for the same (method, particles).
pub fn run_baseline(
    manifest: &Manifest,
    model_name: &str,
    method: Method,
    particles: usize,
    opts: &ScaleOpts,
) -> Result<ScalePoint> {
    let model = manifest.model(model_name)?.clone();
    let lr = lr_for(&model);
    let n_samples = model.batch() * opts.batches;
    let data = data_for(&model, n_samples, opts.seed + 1)?;
    let mut loader =
        DataLoader::new(data, model.batch(), true, opts.seed + 2).with_max_batches(opts.batches);
    let mut b = crate::baselines::Baseline::new(manifest, model_name, particles, opts.seed)?;
    let report = match method {
        Method::Ensemble => b.train_ensemble(&mut loader, opts.epochs, lr)?,
        Method::MultiSwag => b.train_multiswag(&mut loader, opts.epochs, 0, lr)?.0,
        Method::Svgd => b.train_svgd(&mut loader, opts.epochs, lr, 10.0)?,
        Method::Sgld => b.train_sgmcmc(
            &mut loader,
            opts.epochs,
            SgmcmcAlgo::Sgld,
            &Schedule::Constant { eps: lr },
            1e-4,
            0.1,
            opts.seed,
        )?,
        Method::Sghmc => b.train_sgmcmc(
            &mut loader,
            opts.epochs,
            SgmcmcAlgo::Sghmc,
            &Schedule::Constant { eps: lr },
            1e-4,
            0.1,
            opts.seed,
        )?,
    };
    let secs = if report.epochs.len() > 1 {
        report.epochs[1..].iter().map(|e| e.secs).sum::<f64>() / (report.epochs.len() - 1) as f64
    } else {
        report.mean_epoch_secs()
    };
    // The baseline is a single sequential stream: modeled == wall.
    Ok(ScalePoint {
        wall_secs: secs,
        modeled_secs: secs,
        final_loss: report.final_loss(),
        diag: None,
    })
}

/// Figure 4 / Figure 7 grid: archs x methods x devices x particles.
pub fn run_figure(
    manifest: &Manifest,
    name: &str,
    archs: &[&str],
    methods: &[Method],
    opts: &ScaleOpts,
) -> Result<Report> {
    // The per-column mean (NaN cells skipped) renders under the table and
    // saves as a separate "aggregate" object — not as a data row.
    let mut rep = Report::new(name).with_aggregate("mean");
    for arch in archs {
        for method in methods {
            for &dev in &opts.devices {
                for &base in &opts.particles_base {
                    let particles = base * dev;
                    let pt = run_one(manifest, arch, *method, dev, particles, opts)?;
                    crate::log_info!(
                        "{name}: {arch} {} dev={dev} P={particles}: wall {:.3}s modeled {:.3}s",
                        method.name(),
                        pt.wall_secs,
                        pt.modeled_secs
                    );
                    let mut row = Row::new()
                        .str("arch", arch)
                        .str("method", method.name())
                        .int("devices", dev)
                        .int("particles", particles)
                        .num("wall_secs_per_epoch", pt.wall_secs)
                        .num("modeled_secs_per_epoch", pt.modeled_secs)
                        .num("final_loss", pt.final_loss);
                    if let Some(diag) = &pt.diag {
                        // NaN (undiagnosable) saves as null, renders n/a
                        row = row.num("r_hat", diag.r_hat).num("ess", diag.ess);
                    }
                    rep.push(row);
                }
            }
            // The handwritten baselines drive the AOT artifact plane
            // directly, which native models don't have — skip them.
            if opts.baseline && crate::infer::native_model(arch).is_none() {
                for &base in &opts.particles_base {
                    let pt = run_baseline(manifest, arch, *method, base, opts)?;
                    crate::log_info!(
                        "{name}: {arch} {} baseline P={base}: {:.3}s/epoch",
                        method.name(),
                        pt.wall_secs
                    );
                    rep.push(
                        Row::new()
                            .str("arch", arch)
                            .str("method", &format!("{}_baseline", method.name()))
                            .int("devices", 1)
                            .int("particles", base)
                            .num("wall_secs_per_epoch", pt.wall_secs)
                            .num("modeled_secs_per_epoch", pt.modeled_secs)
                            .num("final_loss", pt.final_loss),
                    );
                }
            }
        }
    }
    Ok(rep)
}

/// Appendix C.3 stress test: saturate device caches with many small
/// particles (ensemble; the point is scheduler/swap behaviour, not math).
pub fn run_stress(
    manifest: &Manifest,
    model_name: &str,
    devices: &[usize],
    particles_base: &[usize],
    opts: &ScaleOpts,
) -> Result<Report> {
    let mut rep = Report::new("stress_c3").with_aggregate("mean");
    for &dev in devices {
        for &base in particles_base {
            let particles = base * dev;
            let pt = run_one(manifest, model_name, Method::Ensemble, dev, particles, opts)?;
            crate::log_info!(
                "stress: dev={dev} P={particles}: wall {:.3}s modeled {:.3}s",
                pt.wall_secs,
                pt.modeled_secs
            );
            rep.push(
                Row::new()
                    .str("arch", model_name)
                    .int("devices", dev)
                    .int("particles", particles)
                    .num("wall_secs_per_epoch", pt.wall_secs)
                    .num("modeled_secs_per_epoch", pt.modeled_secs),
            );
        }
    }
    Ok(rep)
}
