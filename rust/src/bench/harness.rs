//! Criterion-less micro/macro benchmark utilities (criterion is not in the
//! vendored crate set; `cargo bench` targets use this instead).

use std::time::Instant;

use crate::util::stats::Summary;

/// Measure `f` for `iters` iterations after `warmup` unmeasured ones.
/// Returns per-iteration seconds.
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Run, summarize, and print one named micro-benchmark.
pub fn bench(name: &str, warmup: usize, iters: usize, f: impl FnMut()) -> Summary {
    let samples = time_iters(warmup, iters, f);
    let s = Summary::of(&samples);
    println!(
        "{name:<44} {:>10} {:>10} {:>10} {:>10}   n={}",
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p90),
        fmt_secs(s.max),
        s.n
    );
    s
}

pub fn bench_header() {
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p90", "max"
    );
}

/// Human-scale duration formatting. NaN (an unmeasured duration, e.g.
/// `TrainReport::mean_epoch_secs` of an empty report) renders as "n/a".
pub fn fmt_secs(s: f64) -> String {
    if s.is_nan() {
        "n/a".to_string()
    } else if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_iters_counts() {
        let mut calls = 0;
        let samples = time_iters(2, 5, || calls += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
        assert_eq!(fmt_secs(f64::NAN), "n/a");
    }
}
