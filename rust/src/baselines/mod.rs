//! Handwritten (particle-free) baseline implementations — the paper's
//! §5.1 comparison curves.
//!
//! These are what a practitioner would write without Push: a single thread,
//! one `RuntimeClient`, parameters in a plain `Vec<Tensor>`, strictly
//! sequential loops over ensemble members. Differences that the paper calls
//! out and that we preserve:
//!
//! * **Ensemble / multi-SWAG**: identical math to the Push versions, no
//!   concurrency — Push's 1-device overhead is measured against these.
//! * **SVGD**: "we store the kernel matrix and then update all the
//!   parameters after the kernel matrix has been computed since we only
//!   keep one copy of each NN" — i.e. fully synchronous, no read-only
//!   views, native kernel math (no L1 artifact).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::{BatchSource, DataLoader};
use crate::infer::sgmcmc::{noise_rng, Schedule, SgmcmcAlgo};
use crate::infer::svgd::svgd_update_native;
use crate::infer::TrainReport;
use crate::runtime::tensor::ops;
use crate::runtime::{Manifest, ModelSpec, RuntimeClient, Tensor};

/// Shared state of a sequential baseline run.
pub struct Baseline {
    client: RuntimeClient,
    model: ModelSpec,
    pub params: Vec<Tensor>,
}

impl Baseline {
    /// Initialize `n` members with the same AOT init entry (and the same
    /// seed/pid scheme) that Push particles use, so trajectories are
    /// comparable.
    pub fn new(manifest: &Manifest, model_name: &str, n: usize, seed: u64) -> Result<Baseline> {
        let model = manifest.model(model_name)?.clone();
        let mut client = RuntimeClient::cpu()?;
        let init = model.entry("init")?.clone();
        let mut params = Vec::with_capacity(n);
        for pid in 0..n {
            let key = Tensor::u32(vec![2], vec![(seed & 0xffff_ffff) as u32, pid as u32]);
            let outs = client.execute(&init.file, &[key])?;
            params.push(outs.into_iter().next().ok_or_else(|| anyhow!("init empty"))?);
        }
        Ok(Baseline { client, model, params })
    }

    pub fn n(&self) -> usize {
        self.params.len()
    }

    fn step_one(&mut self, i: usize, x: &Tensor, y: &Tensor, lr: f32) -> Result<f32> {
        let step = self.model.entry("step")?.clone();
        let args = [
            self.params[i].clone(),
            x.clone(),
            y.clone(),
            Tensor::scalar_f32(lr),
        ];
        let mut outs = self.client.execute(&step.file, &args)?;
        let new_params = outs.remove(1);
        let loss = outs.remove(0).scalar();
        self.params[i] = new_params;
        Ok(loss)
    }

    fn grad_one(&mut self, i: usize, x: &Tensor, y: &Tensor) -> Result<(f32, Tensor)> {
        let grad = self.model.entry("grad")?.clone();
        let args = [self.params[i].clone(), x.clone(), y.clone()];
        let mut outs = self.client.execute(&grad.file, &args)?;
        let g = outs.remove(1);
        Ok((outs.remove(0).scalar(), g))
    }

    pub fn forward_one(&mut self, i: usize, x: &Tensor) -> Result<Tensor> {
        let fwd = self.model.entry("fwd")?.clone();
        let args = [self.params[i].clone(), x.clone()];
        let mut outs = self.client.execute(&fwd.file, &args)?;
        Ok(outs.remove(0))
    }

    /// Sequential deep ensemble: every member steps on every batch, one
    /// after another.
    pub fn train_ensemble(
        &mut self,
        loader: &mut DataLoader,
        epochs: usize,
        lr: f32,
    ) -> Result<TrainReport> {
        let mut report = TrainReport::new("baseline_ensemble");
        for _ in 0..epochs {
            // Stream batches inside the timed region, exactly like the
            // Infer train loops — both sides of every push-vs-baseline
            // comparison charge batch materialization the same way.
            let stream = loader.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0f64;
            let mut nb = 0usize;
            for b in stream {
                for i in 0..self.n() {
                    loss += self.step_one(i, &b.x, &b.y, lr)? as f64;
                }
                nb += 1;
            }
            report.push(
                loss / (nb * self.n()).max(1) as f64,
                t0.elapsed().as_secs_f64(),
            );
        }
        Ok(report)
    }

    /// Sequential multi-SWAG: ensemble + host-side moment tracking.
    /// Returns (report, per-member (mean, sq_mean) moments).
    pub fn train_multiswag(
        &mut self,
        loader: &mut DataLoader,
        epochs: usize,
        pretrain_epochs: usize,
        lr: f32,
    ) -> Result<(TrainReport, Vec<(Tensor, Tensor)>)> {
        let mut report = TrainReport::new("baseline_multiswag");
        let d = self.model.param_count;
        let mut moments: Vec<(Tensor, Tensor, usize)> = (0..self.n())
            .map(|_| (Tensor::zeros(vec![d]), Tensor::zeros(vec![d]), 0usize))
            .collect();
        for e in 0..epochs {
            let collect = e >= pretrain_epochs;
            let stream = loader.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0f64;
            let mut nb = 0usize;
            for b in stream {
                for i in 0..self.n() {
                    loss += self.step_one(i, &b.x, &b.y, lr)? as f64;
                    if collect {
                        let (mean, sq, n) = &mut moments[i];
                        let w_old = *n as f32 / (*n as f32 + 1.0);
                        let w_new = 1.0 / (*n as f32 + 1.0);
                        ops::scale_add(mean, w_old, w_new, &self.params[i]);
                        ops::scale_add_sq(sq, w_old, w_new, &self.params[i]);
                        *n += 1;
                    }
                }
                nb += 1;
            }
            report.push(
                loss / (nb * self.n()).max(1) as f64,
                t0.elapsed().as_secs_f64(),
            );
        }
        Ok((report, moments.into_iter().map(|(m, s, _)| (m, s)).collect()))
    }

    /// Sequential SVGD, the paper's handwritten variant: all gradients,
    /// THEN the full kernel matrix, THEN all updates — one copy of each NN,
    /// no views, no overlap.
    pub fn train_svgd(
        &mut self,
        loader: &mut DataLoader,
        epochs: usize,
        lr: f32,
        lengthscale: f32,
    ) -> Result<TrainReport> {
        let mut report = TrainReport::new("baseline_svgd");
        for _ in 0..epochs {
            let stream = loader.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0f64;
            let mut nb = 0usize;
            for b in stream {
                let mut grads = Vec::with_capacity(self.n());
                for i in 0..self.n() {
                    let (l, g) = self.grad_one(i, &b.x, &b.y)?;
                    loss += l as f64;
                    grads.push(g);
                }
                let updates = svgd_update_native(&self.params, &grads, lengthscale)?;
                for (p, u) in self.params.iter_mut().zip(&updates) {
                    crate::runtime::tensor::ops::axpy(p, -lr, u);
                }
                nb += 1;
            }
            report.push(
                loss / (nb * self.n()).max(1) as f64,
                t0.elapsed().as_secs_f64(),
            );
        }
        Ok(report)
    }

    /// Sequential SGMCMC (SGLD / SGHMC): one chain per member, host-side
    /// momentum, same update math and noise streams as the Push version
    /// (infer::sgmcmc) with member index as the chain id. The baseline is
    /// a timing control, so it skips the O(1)-per-step reservoir
    /// bookkeeping.
    #[allow(clippy::too_many_arguments)]
    pub fn train_sgmcmc(
        &mut self,
        loader: &mut DataLoader,
        epochs: usize,
        algo: SgmcmcAlgo,
        schedule: &Schedule,
        temperature: f32,
        friction: f32,
        seed: u64,
    ) -> Result<TrainReport> {
        let mut report = TrainReport::new(match algo {
            SgmcmcAlgo::Sgld => "baseline_sgld",
            SgmcmcAlgo::Sghmc => "baseline_sghmc",
        });
        let d = self.model.param_count;
        let mut momenta: Vec<Tensor> = (0..self.n()).map(|_| Tensor::zeros(vec![d])).collect();
        let mut clocks = vec![0usize; self.n()];
        for _ in 0..epochs {
            let stream = loader.epoch_stream();
            let t0 = Instant::now();
            let mut loss = 0.0f64;
            let mut nb = 0usize;
            for b in stream {
                for i in 0..self.n() {
                    let (l, g) = self.grad_one(i, &b.x, &b.y)?;
                    loss += l as f64;
                    let t = clocks[i];
                    let eps = schedule.step_size(t);
                    let mut rng = noise_rng(seed, i as u64, t as u64);
                    // Same operation order as the particle handler:
                    // u = −ε g + noise (then += (1−α) v for SGHMC).
                    let mut u = g;
                    for uv in u.as_f32_mut() {
                        *uv *= -eps;
                    }
                    let sigma = match algo {
                        SgmcmcAlgo::Sgld => (2.0 * eps * temperature).sqrt(),
                        SgmcmcAlgo::Sghmc => (2.0 * friction * temperature * eps).sqrt(),
                    };
                    if sigma > 0.0 {
                        for uv in u.as_f32_mut() {
                            *uv += sigma * rng.normal();
                        }
                    }
                    if algo == SgmcmcAlgo::Sghmc {
                        ops::scale_add(&mut u, 1.0, 1.0 - friction, &momenta[i]);
                        momenta[i] = u.clone();
                    }
                    ops::axpy(&mut self.params[i], 1.0, &u);
                    clocks[i] = t + 1;
                }
                nb += 1;
            }
            report.push(
                loss / (nb * self.n()).max(1) as f64,
                t0.elapsed().as_secs_f64(),
            );
        }
        Ok(report)
    }

    /// Ensemble-mean prediction (sequential).
    pub fn predict_mean(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut acc: Option<Tensor> = None;
        for i in 0..self.n() {
            let p = self.forward_one(i, x)?;
            match &mut acc {
                None => acc = Some(p),
                Some(a) => crate::runtime::tensor::ops::axpy(a, 1.0, &p),
            }
        }
        let mut out = acc.ok_or_else(|| anyhow!("no members"))?;
        let n = self.n() as f32;
        for v in out.as_f32_mut() {
            *v /= n;
        }
        Ok(out)
    }
}
